"""Quality & efficiency observatory (DESIGN.md §17): online recall probes,
compiled-program roofline profiles, and the bench regression sentinel.

Pins the PR's acceptance invariants:
  * probe sampling is a pure function of (seed, ordinal) — the same seed
    over the same traffic reproduces the same probe set across restarts;
  * the windowed Wilson estimate tracks exact recall@k within ±0.05 on a
    seeded synthetic run, and probing changes NO served result ids
    (observe-only, bit-exact);
  * filtered and live queries are judged against the RIGHT sub-corpus
    (predicate-passing rows; alive logical rows via slot_to_logical);
  * a sustained recall breach walks server health to DEGRADED and counts
    quality_degraded_total; recovery returns to SERVING;
  * capture_search profiles every registry engine's whole batched search
    as one compiled program with nonzero flops/bytes and exports
    roofline_* gauges;
  * regress.py rejects unstamped artifacts, passes clean on an exact
    self-comparison, and exits nonzero on an injected 20% p50 regression;
  * migrate_legacy stamps bare-list artifacts in place, folds the orphan
    aggregate into missing per-bench files, and never clobbers a stamped
    artifact.
"""
import json
import math
import os

import numpy as np
import pytest

from benchmarks import migrate_legacy, regress
from repro.core import index as index_lib
from repro.core import probes as probes_lib
from repro.core import profile as profile_lib
from repro.core import scan as scan_lib
from repro.core import telemetry as telem
from repro.launch.serve import SearchServer

N, D, K = 256, 16, 10


@pytest.fixture(autouse=True)
def _clean_registry():
    """Telemetry and the profile registry are process-global: every test
    starts and ends disabled + zeroed."""
    telem.disable()
    telem.reset()
    profile_lib.reset()
    yield
    telem.disable()
    telem.reset()
    profile_lib.reset()


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, D)).astype(np.float32)
    Q = X[:64] + 0.01 * rng.normal(size=(64, D)).astype(np.float32)
    return X, Q


# ---------------------------------------------------------------------------
# probe primitives
# ---------------------------------------------------------------------------

def test_sample_draw_is_pure_and_seed_dependent():
    a = [probes_lib.sample_draw(7, i) for i in range(100)]
    b = [probes_lib.sample_draw(7, i) for i in range(100)]
    c = [probes_lib.sample_draw(8, i) for i in range(100)]
    assert a == b
    assert a != c
    assert all(0.0 <= x < 1.0 for x in a)


def test_sampled_mask_restart_determinism():
    """The same seed over the same ordinal stream reproduces the same
    probe set, regardless of how the stream is chunked (a restart replays
    the ordinals, not the batches)."""
    whole = probes_lib.sampled_mask(3, 0.25, 0, 300)
    chunked = np.concatenate([
        probes_lib.sampled_mask(3, 0.25, 0, 100),
        probes_lib.sampled_mask(3, 0.25, 100, 137),
        probes_lib.sampled_mask(3, 0.25, 237, 63),
    ])
    np.testing.assert_array_equal(whole, chunked)
    # rate is honored in expectation (binomial, wide slack)
    assert 0.10 < whole.mean() < 0.45


def test_wilson_interval_brackets_and_degenerates():
    p, lo, hi = probes_lib.wilson_interval(90, 100)
    assert lo < p == 0.9 < hi
    assert 0.0 <= lo and hi <= 1.0
    # no trials: maximally uncertain, never a division crash
    assert probes_lib.wilson_interval(0, 0) == (0.0, 0.0, 1.0)
    # p = 1 stays inside [0, 1] and the interval still has width
    p1, lo1, hi1 = probes_lib.wilson_interval(50, 50)
    assert p1 == 1.0 and hi1 == 1.0 and lo1 < 1.0


def test_count_hits_subcorpus_trials():
    """trials = number of VALID ground-truth ids: a perfect answer over a
    2-row sub-corpus scores 2/2, not 2/k."""
    served = np.array([[5, 9, -1], [1, 2, 3]])
    truth = np.array([[9, 5, -1], [7, 8, -1]])
    hits, trials = probes_lib.count_hits(served, truth)
    np.testing.assert_array_equal(hits, [2, 0])
    np.testing.assert_array_equal(trials, [2, 2])


def test_probe_config_sugar_and_validation():
    assert probes_lib.ProbeConfig.from_cfg(0.05).rate == 0.05
    assert probes_lib.ProbeConfig.from_cfg({"rate": 0.1, "k": 5}).k == 5
    with pytest.raises(ValueError):
        probes_lib.ProbeConfig(rate=1.5)
    with pytest.raises(ValueError):
        probes_lib.ProbeConfig(slo_floor=0.0)
    with pytest.raises(TypeError):
        probes_lib.ProbeConfig.from_cfg("0.1")


def test_view_key_distinguishes_filters():
    k0 = probes_lib.view_key(None)
    k1 = probes_lib.view_key({"category": {"isin": ["a"]}})
    k2 = probes_lib.view_key({"category": {"isin": ["b"]}})
    k3 = probes_lib.view_key(np.array([True, False, True]))
    assert k0 is None
    assert len({k1, k2, k3}) == 3
    # dict key order must not matter
    assert probes_lib.view_key({"a": 1, "b": 2}) == \
        probes_lib.view_key({"b": 2, "a": 1})


# ---------------------------------------------------------------------------
# server-integrated probing
# ---------------------------------------------------------------------------

def _serve(server, Q, k=K, batch=16):
    outs = []
    for i in range(0, len(Q), batch):
        outs.append(server.query(Q[i:i + batch], k=k))
    return np.concatenate([np.asarray(r.idx) for r in outs], axis=0)


def test_probe_estimate_tracks_exact_recall_and_is_bit_exact(data):
    """The headline acceptance: 1%-class sampled probing estimates
    recall within ±0.05 of the exact value, without changing a single
    served id."""
    X, Q = data
    Qm = np.concatenate([Q] * 10, axis=0)  # 640 queries
    plain = SearchServer(X, engine="ivf_flat", cfg={"budget": 96})
    probed = SearchServer(X, engine="ivf_flat", cfg={"budget": 96},
                          probe={"rate": 0.5, "k": K, "seed": 1})
    idx_plain = _serve(plain, Qm)
    idx_probe = _serve(probed, Qm)
    np.testing.assert_array_equal(idx_plain, idx_probe)  # observe-only

    exact = index_lib.build("brute", X, {}).search(Qm, k=K)
    hits, trials = probes_lib.count_hits(
        np.asarray(idx_plain), np.asarray(exact.idx))
    exact_recall = hits.sum() / trials.sum()

    q = probed.stats()["quality"]
    assert q["probed"] > 100  # rate 0.5 over 640 queries
    assert abs(q["recall_estimate"] - exact_recall) <= 0.05
    assert q["ci_low"] <= q["recall_estimate"] <= q["ci_high"]


def test_probe_sampling_identical_across_server_restarts(data):
    """Two servers with the same probe seed over the same traffic probe
    the same query ordinals (restart reproducibility at the server
    level)."""
    X, Q = data
    a = SearchServer(X, engine="brute",
                     probe={"rate": 0.3, "seed": 9, "flush_at": 4})
    b = SearchServer(X, engine="brute",
                     probe={"rate": 0.3, "seed": 9, "flush_at": 4})
    _serve(a, Q)
    _serve(b, Q)
    sa, sb = a.stats()["quality"], b.stats()["quality"]
    assert sa["seen"] == sb["seen"] == len(Q)
    assert sa["probed"] == sb["probed"] > 0
    # and the estimator saw identical outcomes, not just identical counts
    assert sa["recall_estimate"] == sb["recall_estimate"]


def test_probe_filtered_ground_truth(data):
    """Filtered queries are judged against the predicate-passing rows:
    recall stays ~1 for brute even though the filtered answer set would
    score near zero against unfiltered ground truth."""
    X, Q = data
    attrs = {"category": np.array(["even", "odd"])[np.arange(N) % 2]}
    server = SearchServer(X, engine="brute", attrs=attrs,
                          probe={"rate": 1.0, "k": K, "flush_at": 4})
    flt = {"category": {"isin": ["even"]}}
    for i in range(0, len(Q), 16):
        server.query(Q[i:i + 16], k=K, filter=flt)
    q = server.stats()["quality"]
    assert q["probed"] == len(Q)
    assert q["recall_estimate"] > 0.95


def test_probe_live_ground_truth(data):
    """After churn (upserts + deletes), probes judge against the alive
    logical corpus with served slot ids mapped through slot_to_logical —
    a frozen-corpus oracle would misscore every post-churn answer."""
    X, Q = data
    server = SearchServer(X, engine="brute", live=True, delta_cap=64,
                          probe={"rate": 1.0, "k": K, "flush_at": 4})
    rng = np.random.default_rng(5)
    new_ids = server.upsert(rng.normal(size=(16, D)).astype(np.float32))
    server.delete(new_ids[:8])
    server.delete(np.arange(8))  # tombstone frozen rows too
    for i in range(0, len(Q), 16):
        server.query(Q[i:i + 16], k=K)
    q = server.stats()["quality"]
    assert q["probed"] == len(Q)
    assert q["recall_estimate"] > 0.95


def test_probe_slo_breach_walks_health_to_degraded(data):
    """A confidently-bad window (Wilson upper bound under the floor) is a
    quality breach: health DEGRADED, quality_degraded_total counted,
    stats()['quality'] carries the breach."""
    X, Q = data
    telem.enable()
    # starved budget => genuinely low recall; floor set impossibly high
    server = SearchServer(X, engine="ivf_flat",
                          cfg={"budget": 8, "num_clusters": 32},
                          probe={"rate": 1.0, "k": K, "flush_at": 4,
                                 "slo_floor": 0.999, "slo_min_samples": 16})
    Qm = np.concatenate([Q] * 2, axis=0)
    for i in range(0, len(Qm), 16):
        server.query(Qm[i:i + 16], k=K)
    s = server.stats()
    assert s["quality"]["breached"] is True
    assert s["quality"]["breaches"] >= 1
    assert s["health"] == "DEGRADED"
    assert server.fault_counters["quality_breaches"] >= 1
    assert telem.counter_total("quality_degraded_total") >= 1
    # the exposition carries the probe gauges the CI smoke scrapes
    text = telem.metrics_text()
    assert "recall_estimate{" in text
    assert "probe_total" in text


def test_probe_swap_resets_window(data):
    """Hot-swapping engines must not blend one engine's probe window into
    the next engine's estimate."""
    X, Q = data
    server = SearchServer(X, engine="brute", probe={"rate": 1.0, "flush_at": 4})
    _serve(server, Q)
    assert server.stats()["quality"]["probed"] == len(Q)
    server.swap("ivf_flat", cfg={"budget": 96})
    q = server.stats()["quality"]
    assert q["probed"] == 0 and q["seen"] == 0


# ---------------------------------------------------------------------------
# roofline profiles
# ---------------------------------------------------------------------------

def test_capture_jit_topk_scan_profile(data):
    import jax

    X, Q = data
    fn = jax.jit(lambda Q, Y: scan_lib.topk_scan(Q, Y, k=8, metric="euclidean",
                                                 impl="jnp"))
    telem.enable()
    prof = profile_lib.capture_jit("topk:test", fn, Q, X,
                                   labels={"n": N, "k": 8})
    assert prof.flops > 0 and prof.hbm_bytes > 0
    assert prof.t_predicted_s > 0 and prof.t_measured_s > 0
    assert prof.pct_of_peak > 0
    assert prof.dominant in ("compute", "memory", "collective")
    text = telem.metrics_text()
    assert "roofline_pct_of_peak{" in text
    # re-capture returns the cached profile (no recompilation)
    again = profile_lib.capture_jit("topk:test", fn, Q, X,
                                    labels={"n": N, "k": 8})
    assert again is prof


@pytest.mark.parametrize("engine,cfg", [
    ("brute", {}),
    ("ivf_flat", {"budget": 96}),
    ("infinity", {"q": math.inf, "train_steps": 10, "proj_sample": 64,
                  "budget": 128, "rerank": 32}),
])
def test_capture_search_profiles_registry_engines(data, engine, cfg):
    """Every registry engine's whole batched search traces into ONE
    compiled program with a nonzero roofline, telemetry on throughout
    (the capture suspends it only around tracing)."""
    X, Q = data
    telem.enable()
    eng = index_lib.build(engine, X, cfg)
    prof = profile_lib.capture_search(eng, Q[:16], k=K, engine=engine)
    assert prof.name == f"search:{engine}"
    assert prof.labels["engine"] == engine
    assert prof.flops > 0 and prof.hbm_bytes > 0
    assert prof.t_measured_s > 0
    assert telem.enabled()  # restored after tracing
    assert profile_lib.profiles(f"search:{engine}") == [prof]


def test_server_capture_roofline(data):
    X, _ = data
    server = SearchServer(X, engine="brute")
    out = server.capture_roofline(batch=16, k=K)
    (name, blk), = out.items()
    assert name == "search:brute"
    assert blk["flops"] > 0 and blk["t_predicted_s"] > 0
    assert blk["pct_of_peak"] > 0


# ---------------------------------------------------------------------------
# regression sentinel + legacy migration
# ---------------------------------------------------------------------------

def _stamped(rows):
    from benchmarks.common import env_stamp

    return {"meta": env_stamp(), "rows": rows}


SERVING_ROWS = [
    {"engine": "brute", "shards": 1, "k": 10, "n": 2048,
     "p50_ms": 2.5, "p99_ms": 4.0, "qps": 25000.0,
     "mean_comparisons": 2048.0, "recall@k": 1.0},
    {"engine": "ivf_flat", "shards": 1, "k": 10, "n": 2048,
     "p50_ms": 1.2, "p99_ms": 2.0, "qps": 50000.0,
     "mean_comparisons": 300.0, "recall@k": 0.97},
]


def _bundle(tmp_path, name, rows):
    path = str(tmp_path / name)
    regress.save_bundle(path, {"serving": ({}, json.loads(json.dumps(rows)))})
    return path


def test_load_stamped_rejects_legacy_formats(tmp_path):
    bare = tmp_path / "BENCH_bare.json"
    bare.write_text(json.dumps([{"p50_ms": 1.0}]))
    with pytest.raises(regress.UnstampedArtifact, match="migrate_legacy"):
        regress.load_stamped(str(bare))
    nostamp = tmp_path / "BENCH_nostamp.json"
    nostamp.write_text(json.dumps({"meta": {}, "rows": []}))
    with pytest.raises(regress.UnstampedArtifact, match="git_commit"):
        regress.load_stamped(str(nostamp))
    ok = tmp_path / "BENCH_ok.json"
    ok.write_text(json.dumps(_stamped([{"p50_ms": 1.0}])))
    meta, rows = regress.load_stamped(str(ok))
    assert "git_commit" in meta and rows == [{"p50_ms": 1.0}]


def test_regress_clean_self_comparison_exits_zero(tmp_path, capsys):
    b = _bundle(tmp_path, "base.json", SERVING_ROWS)
    report = str(tmp_path / "R.md")
    rc = regress.main(["--baseline", b, "--fresh", b, "--report", report])
    assert rc == 0
    assert os.path.exists(report)
    assert "regressions: **0**" in open(report).read()


def test_regress_injected_p50_regression_exits_nonzero(tmp_path):
    """The acceptance self-test: a synthetic 20% p50 regression on one
    engine trips the sentinel, and only on that engine's rows."""
    b = _bundle(tmp_path, "base.json", SERVING_ROWS)
    report = str(tmp_path / "R.md")
    rc = regress.main(["--baseline", b, "--fresh", b,
                       "--inject", "p50_ms=1.2", "--inject-match",
                       "engine=brute", "--report", report])
    assert rc == 1
    txt = open(report).read()
    assert "REGRESSION" in txt
    # the ivf_flat rows were untouched and must not be flagged
    for line in txt.splitlines():
        if "REGRESSION" in line and "|" in line:
            assert "ivf_flat" not in line


def test_regress_recall_floor_is_absolute(tmp_path):
    fresh_rows = json.loads(json.dumps(SERVING_ROWS))
    fresh_rows[1]["recall@k"] = 0.90  # 0.97 - 0.07 < floor tolerance 0.05
    b = _bundle(tmp_path, "base.json", SERVING_ROWS)
    f = _bundle(tmp_path, "fresh.json", fresh_rows)
    rc = regress.main(["--baseline", b, "--fresh", f,
                       "--report", str(tmp_path / "R.md")])
    assert rc == 1


def test_regress_faster_machine_is_not_a_regression(tmp_path):
    """Rows absolutely better than baseline never flag, even when the
    suite-median speedup is heterogeneous (the normalizer is clamped at
    >= 1 for the hard gate)."""
    fresh_rows = json.loads(json.dumps(SERVING_ROWS))
    fresh_rows[0]["p50_ms"] = 2.0    # 1.25x faster
    fresh_rows[0]["qps"] = 31000.0
    fresh_rows[1]["p50_ms"] = 0.4    # 3x faster
    fresh_rows[1]["qps"] = 150000.0
    b = _bundle(tmp_path, "base.json", SERVING_ROWS)
    f = _bundle(tmp_path, "fresh.json", fresh_rows)
    rc = regress.main(["--baseline", b, "--fresh", f,
                       "--report", str(tmp_path / "R.md")])
    assert rc == 0


def test_migrate_legacy_stamps_and_folds(tmp_path):
    d = str(tmp_path)
    # a bare-list per-bench artifact -> stamped in place
    bare = tmp_path / "BENCH_topk.json"
    bare.write_text(json.dumps([{"n": 4096, "t_scan_jnp_s": 0.1}]))
    # a stamped artifact that must NOT be clobbered
    stamped = tmp_path / "BENCH_serving.json"
    stamped.write_text(json.dumps(_stamped([{"engine": "brute"}])))
    keep_meta = json.load(open(stamped))["meta"]
    # the orphan aggregate: one key targets the stamped file (dropped),
    # one targets a missing file (folded), one is unknown (skipped)
    orphan = tmp_path / "bench_results.json"
    orphan.write_text(json.dumps({
        "serving": [{"engine": "old"}],
        "infinity": [{"q": "inf", "p50_ms": 9.0}],
        "mystery": [{"x": 1}],
    }))

    actions = migrate_legacy.migrate(d, verbose=False)
    assert not (tmp_path / "bench_results.json").exists()
    topk = json.load(open(tmp_path / "BENCH_topk.json"))
    assert "meta" in topk and topk["rows"] == [{"n": 4096, "t_scan_jnp_s": 0.1}]
    assert "migrated_from" in topk["meta"]
    assert json.load(open(stamped))["meta"] == keep_meta  # untouched
    inf = json.load(open(tmp_path / "BENCH_infinity.json"))
    assert inf["rows"] == [{"q": "inf", "p50_ms": 9.0}]
    assert any("mystery" in a for a in actions)
    # after migration every artifact loads under the sentinel's validator
    for f in ("BENCH_topk.json", "BENCH_serving.json", "BENCH_infinity.json"):
        regress.load_stamped(str(tmp_path / f))


def test_committed_artifacts_are_stamped():
    """The repo's own trajectory must satisfy the sentinel's --check."""
    rc = regress.main(["--check"])
    assert rc == 0
