"""Fused distance + streaming top-k: kernel/scan parity vs the jnp oracle,
edge cases (non-tile shapes, k > n, duplicate ties), knn_graph equivalence
against the old materialize+top_k formulation, and the no-(m, n)-buffer
memory guarantee of the blocked jnp path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, scan
from repro.core.knn_graph import knn_graph
from repro.kernels.topk import SUPPORTED, topk, topk_ref

ALL_METRICS = list(SUPPORTED)


def _data(m, n, d, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    return X, Y


def _check(out, ref, atol=1e-4):
    (d_o, i_o), (d_r, i_r) = out, ref
    np.testing.assert_allclose(np.asarray(d_o), np.asarray(d_r), atol=atol, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(i_o), np.asarray(i_r))


@pytest.mark.parametrize("metric", ALL_METRICS)
def test_kernel_matches_oracle_all_metrics(metric):
    X, Y = _data(40, 300, 24, seed=1)
    _check(topk(X, Y, k=10, metric=metric), topk_ref(X, Y, k=10, metric=metric))


@pytest.mark.parametrize("shape", [(1, 1, 1, 1), (33, 257, 20, 5),
                                   (130, 129, 7, 17), (8, 4096, 128, 64)])
def test_kernel_non_tile_multiple_shapes(shape):
    m, n, d, k = shape
    X, Y = _data(m, n, d, seed=2)
    _check(topk(X, Y, k=k, metric="sqeuclidean"),
           topk_ref(X, Y, k=k, metric="sqeuclidean"))


def test_kernel_k_exceeds_n_pads_with_inf_and_minus1():
    X, Y = _data(6, 10, 4, seed=3)
    d, i = topk(X, Y, k=25, metric="euclidean")
    _check((d, i), topk_ref(X, Y, k=25, metric="euclidean"))
    assert np.isinf(np.asarray(d)[:, 10:]).all()
    assert (np.asarray(i)[:, 10:] == -1).all()
    assert (np.asarray(i)[:, :10] >= 0).all()


def test_kernel_duplicate_distance_ties_pick_lowest_index():
    # Y contains each row 3x -> every query has 3-way exact ties at rank 0
    rng = np.random.default_rng(4)
    base = rng.normal(size=(20, 8)).astype(np.float32)
    Y = jnp.asarray(np.concatenate([base, base, base], axis=0))
    X = jnp.asarray(base[:7])
    for impl_out in (
        topk(X, Y, k=9, metric="sqeuclidean"),
        scan.topk_scan(X, Y, k=9, metric="sqeuclidean", impl="jnp", block=16),
    ):
        _check(impl_out, topk_ref(X, Y, k=9, metric="sqeuclidean"))


def test_exclude_self_with_k_exceeding_valid_candidates():
    """All three paths agree that +inf slots (here: the excluded self when
    k > n-1) yield idx -1, not the masked column's real index."""
    X, _ = _data(5, 1, 4, seed=11)
    ref = topk_ref(X, X, k=5, metric="sqeuclidean", exclude_self=True)
    _check(topk(X, X, k=5, metric="sqeuclidean", exclude_self=True), ref)
    _check(
        scan.topk_scan(X, X, k=5, metric="sqeuclidean", impl="jnp",
                       exclude_self=True, block=2),
        ref,
    )
    d_r, i_r = ref
    assert (np.asarray(i_r)[:, -1] == -1).all()
    assert np.isinf(np.asarray(d_r)[:, -1]).all()


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_scan_engine_matches_oracle(impl):
    X, Y = _data(25, 500, 16, seed=5)
    _check(
        scan.topk_scan(X, Y, k=12, metric="euclidean", impl=impl, block=64),
        topk_ref(X, Y, k=12, metric="euclidean"),
    )


def test_scan_engine_jnp_fallback_for_unsupported_metrics():
    # jaccard/correlation have no pallas kernel: impl='pallas' must still work
    rng = np.random.default_rng(6)
    X = jnp.asarray((rng.random((12, 30)) > 0.5).astype(np.float32))
    d, i = scan.topk_scan(X, X, k=4, metric="jaccard", impl="pallas", block=8)
    D = metrics.pairwise(X, X, metric="jaccard")
    neg, ref_i = jax.lax.top_k(-D, 4)
    np.testing.assert_allclose(np.asarray(d), -np.asarray(neg), atol=1e-5)


def test_scan_engine_valid_mask():
    X, Y = _data(9, 64, 8, seed=7)
    valid = jnp.asarray(np.arange(64) % 3 != 0)  # mask a third of candidates
    d, i = scan.topk_scan(X, Y, k=5, metric="euclidean", valid=valid, block=16)
    Dm = jnp.where(~valid[None, :], jnp.inf, metrics.pairwise(X, Y, metric="euclidean"))
    neg, ref_i = jax.lax.top_k(-Dm, 5)
    np.testing.assert_allclose(np.asarray(d), -np.asarray(neg), atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    assert not np.isin(np.asarray(i), np.arange(0, 64, 3)).any()


# ---------------------------------------------------------------------------
# valid-mask edge cases — the irregular candidate sets (IVF padding, filter
# predicates, live delta slots) that used to force the jnp fallback and now
# run the fused kernel with the (1, n) mask operand (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _masked_oracle(X, Y, valid, k, metric="euclidean"):
    Dm = jnp.where(
        ~jnp.asarray(valid)[None, :], jnp.inf,
        metrics.pairwise(X, Y, metric=metric),
    )
    if k > Y.shape[0]:
        Dm = jnp.pad(Dm, ((0, 0), (0, k - Y.shape[0])), constant_values=jnp.inf)
    neg, idx = jax.lax.top_k(-Dm, k)
    return -jnp.asarray(neg), jnp.where(
        jnp.isinf(-neg) | (idx >= Y.shape[0]), -1, idx.astype(jnp.int32)
    )


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_masked_scan_n_not_multiple_of_block(impl):
    """n=61 with block 16 (jnp) / a 128-wide kernel tile: the ragged tail
    block composes padding-mask ∧ valid-mask without leaking either."""
    X, Y = _data(7, 61, 12, seed=21)
    valid = jnp.asarray(np.random.default_rng(0).random(61) > 0.5)
    d, i = scan.topk_scan(X, Y, k=9, metric="euclidean", impl=impl,
                          valid=valid, block=16)
    rd, ri = _masked_oracle(X, Y, valid, 9)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_masked_scan_entirely_invalid_block(impl):
    """A whole block/tile of candidates masked out: the kernel's
    can-improve bound skips it, the jnp path +infs it — either way no id
    from the dead range survives."""
    X, Y = _data(5, 96, 8, seed=22)
    valid = np.ones(96, bool)
    valid[16:48] = False  # two full jnp blocks, dead center
    valid = jnp.asarray(valid)
    d, i = scan.topk_scan(X, Y, k=6, metric="euclidean", impl=impl,
                          valid=valid, block=16)
    rd, ri = _masked_oracle(X, Y, valid, 6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    assert not np.isin(np.asarray(i), np.arange(16, 48)).any()


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_masked_scan_all_invalid_corpus(impl):
    """Every candidate masked: all (-1, +inf) 'no result' slots, never a
    leaked index."""
    X, Y = _data(4, 40, 8, seed=23)
    valid = jnp.zeros(40, bool)
    d, i = scan.topk_scan(X, Y, k=5, metric="euclidean", impl=impl,
                          valid=valid, block=16)
    assert (np.asarray(i) == -1).all()
    assert np.isinf(np.asarray(d)).all()


def test_masked_jnp_vs_pallas_bit_identical_ids_with_ties():
    """The acceptance bar: a masked kernel scan returns ids bit-identical
    to the masked jnp path — tie order included (duplicated rows force
    exact distance ties; both paths must break to the lowest index)."""
    rng = np.random.default_rng(24)
    base = rng.normal(size=(30, 8)).astype(np.float32)
    Y = jnp.asarray(np.concatenate([base, base, base], axis=0))  # 3-way ties
    X = jnp.asarray(base[:6])
    valid = jnp.asarray(np.arange(90) % 4 != 1)
    out_p = scan.topk_scan(X, Y, k=8, metric="sqeuclidean", impl="pallas",
                           valid=valid)
    out_j = scan.topk_scan(X, Y, k=8, metric="sqeuclidean", impl="jnp",
                           valid=valid, block=32)
    np.testing.assert_array_equal(np.asarray(out_p[1]), np.asarray(out_j[1]))
    np.testing.assert_allclose(np.asarray(out_p[0]), np.asarray(out_j[0]),
                               atol=1e-5, rtol=1e-5)
    # masked copies of a tied row must be skipped in favor of the next
    # valid duplicate, not resurface
    assert not np.isin(np.asarray(out_p[1]), np.arange(1, 90, 4)).any()


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("metric", ["euclidean", "manhattan"])
def test_knn_graph_equivalent_to_materialize_topk(impl, metric):
    """The routed knn_graph must reproduce the old eye-mask + full top_k."""
    X, _ = _data(90, 1, 12, seed=8)
    idx, dist = knn_graph(X, k=7, metric=metric, impl=impl)
    D = metrics.pairwise(X, X, metric=metric)
    D = jnp.where(jnp.eye(90, dtype=bool), jnp.inf, D)
    neg, ref_idx = jax.lax.top_k(-D, 7)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
    np.testing.assert_allclose(np.asarray(dist), -np.asarray(neg), atol=1e-4, rtol=1e-4)
    assert idx.dtype == jnp.int32


def test_jnp_scan_path_never_materializes_mn():
    """Peak-memory guarantee: the compiled blocked path contains no (m, n)
    f32 buffer — the defining property of the streaming engine."""
    m, n, d, k, block = 128, 16384, 32, 16, 1024
    fn = lambda Q, Y: scan.topk_scan(Q, Y, k=k, metric="euclidean",
                                     impl="jnp", block=block)
    args = (jax.ShapeDtypeStruct((m, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32))
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    assert f"f32[{m},{n}]" not in hlo
    # the per-step panel (m, block) is the largest distance buffer allowed
    assert f"f32[{m},{block}]" in hlo


def test_brute_force_and_ivf_still_exact():
    from repro.core import baselines

    X, Q = _data(400, 40, 16, seed=9)
    idx, dist, comps = baselines.brute_force(X, Q, k=3)
    _check((dist, idx), topk_ref(Q, X, k=3, metric="euclidean"))
    assert (np.asarray(comps) == 400).all()
    ivf = baselines.IVFFlat.build(X, num_clusters=8, metric="euclidean")
    idx, dist, comps = ivf.search(Q, k=3, nprobe=8)  # all clusters -> exact
    _check((dist, idx), topk_ref(Q, X, k=3, metric="euclidean"))


# ---------------------------------------------------------------------------
# merge_topk edge cases — the exact paths the live frozen+delta merge and
# the shard merge lean on (lists under the scan contract: ascending,
# ties -> lowest index, (-1, +inf) past the valid candidate count)
# ---------------------------------------------------------------------------

def _merge(dists, idxs, k):
    d, i = scan.merge_topk(
        jnp.asarray(dists, jnp.float32)[None],
        jnp.asarray(idxs, jnp.int32)[None], k=k,
    )
    return np.asarray(d)[0], np.asarray(i)[0]


def test_merge_topk_k_exceeds_total_valid():
    """k larger than the union of valid candidates: the tail must be
    (-1, +inf) 'no result' slots, never a leaked padding index."""
    d, i = _merge(
        [[1.0, np.inf, np.inf], [2.0, 3.0, np.inf]],
        [[4, -1, -1], [10, 11, -1]],
        k=6,
    )
    np.testing.assert_array_equal(i, [4, 10, 11, -1, -1, -1])
    np.testing.assert_allclose(d[:3], [1.0, 2.0, 3.0])
    assert np.isinf(d[3:]).all()


def test_merge_topk_all_padding_lists():
    """Lists that are entirely (-1, +inf) padding (an empty delta, a shard
    with every candidate masked) merge to all 'no result'."""
    d, i = _merge(
        [[np.inf] * 4, [np.inf] * 4],
        [[-1] * 4, [-1] * 4],
        k=4,
    )
    assert (i == -1).all()
    assert np.isinf(d).all()
    # one real candidate among the padding still surfaces first
    d, i = _merge(
        [[np.inf] * 4, [5.0, np.inf, np.inf, np.inf]],
        [[-1] * 4, [7, -1, -1, -1]],
        k=4,
    )
    np.testing.assert_array_equal(i, [7, -1, -1, -1])
    assert d[0] == 5.0


def test_merge_topk_duplicate_ids_across_lists():
    """merge_topk does NOT dedupe: a global id appearing in two source
    lists (possible for overlapping candidate generators) occupies two
    slots.  Disjoint id spaces (live frozen+delta, shard offsets) are the
    caller's contract; this pins the no-dedup semantics down."""
    d, i = _merge(
        [[1.0, 4.0, np.inf], [2.0, 4.0, np.inf]],
        [[3, 9, -1], [3, 9, -1]],
        k=4,
    )
    np.testing.assert_array_equal(i, [3, 3, 9, 9])
    np.testing.assert_allclose(d, [1.0, 2.0, 4.0, 4.0])


def test_merge_topk_tie_to_lowest_index_across_merge_order():
    """Equal distances across sources resolve to the lowest global id, no
    matter which source holds it or how late it arrives — because sources
    are merged in ascending-offset order and the running buffer precedes
    the incoming list."""
    # the lowest id of the tie sits in the LAST source: earlier sources
    # must not keep the tie just because they were merged first
    dists = [[7.0, np.inf], [7.0, np.inf], [7.0, np.inf]]
    idxs = [[20, -1], [41, -1], [60, -1]]
    d, i = _merge(dists, idxs, k=2)
    np.testing.assert_array_equal(i, [20, 41])
    np.testing.assert_allclose(d, [7.0, 7.0])
    # full-width ties: the merged list must be the k lowest ids, in order
    dists = [[1.0, 1.0], [1.0, 1.0]]
    idxs = [[0, 5], [10, 15]]
    d, i = _merge(dists, idxs, k=3)
    np.testing.assert_array_equal(i, [0, 5, 10])

    # shard-merge oracle: random per-source scan-contract lists, any k --
    # merged output == single scan over the concatenated candidate pool
    rng = np.random.default_rng(3)
    for k in (1, 3, 8):
        pools = []
        for s in range(4):
            m = rng.integers(0, 6)
            vals = np.sort(rng.integers(0, 4, size=m)).astype(np.float32)
            ids = 10 * s + np.arange(m)  # ascending ids within a source
            pad = 6 - m
            pools.append((
                np.concatenate([vals, np.full(pad, np.inf, np.float32)]),
                np.concatenate([ids, np.full(pad, -1)]).astype(np.int32),
            ))
        dists = np.stack([p[0] for p in pools])
        idxs = np.stack([p[1] for p in pools])
        d, i = _merge(dists, idxs, k=k)
        flat = [(dv, iv) for dv, iv in zip(dists.ravel(), idxs.ravel()) if iv >= 0]
        flat.sort()  # (dist, id): ties -> lowest global id
        want_i = [iv for _, iv in flat[:k]] + [-1] * max(0, k - len(flat))
        np.testing.assert_array_equal(i, want_i)
