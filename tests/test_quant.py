"""Quantized scan subsystem (DESIGN.md §13): the absmax definition, the
int8 kernel regime, the ``quant`` registry key through every engine, the
live/sharded/snapshot plumbing and the registry-wide memory audit."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import index as index_lib
from repro.core import quant as quant_lib
from repro.core import scan as scan_lib

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, D = 512, 24


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, D)).astype(np.float32)
    Q = rng.normal(size=(16, D)).astype(np.float32)
    return X, Q


def _recall(a, b, k):
    from benchmarks.common import recall_at_k

    return recall_at_k(np.asarray(a), np.asarray(b), k)


# ---------------------------------------------------------------------------
# the quantization definition
# ---------------------------------------------------------------------------

def test_absmax_roundtrip_error_bounded_per_dimension(data):
    X, _ = data
    store = quant_lib.QuantStore.build(X)
    assert store.codes.dtype == np.int8 and store.codes.shape == X.shape
    dec = np.asarray(quant_lib.decode(
        jnp.asarray(store.codes), jnp.asarray(store.scales)
    ))
    err = np.abs(dec - X)
    # per-dimension bound: half a quantization step per entry
    assert (err <= store.scales[None, :] * 0.51).all()
    # the scanned-corpus footprint is exactly a quarter of f32
    assert store.codes.nbytes * 4 == X.nbytes


def test_zero_dimension_encodes_to_exact_zero():
    X = np.zeros((8, 4), np.float32)
    X[:, 1] = np.linspace(-3, 3, 8)
    store = quant_lib.QuantStore.build(X)
    dec = np.asarray(quant_lib.decode(
        jnp.asarray(store.codes), jnp.asarray(store.scales)
    ))
    assert (dec[:, 0] == 0.0).all() and (dec[:, 2:] == 0.0).all()


def test_shortlist_width_rule():
    pow2ceil = scan_lib.pow2ceil
    assert quant_lib.shortlist_width(10, 10_000) == pow2ceil(40) == 64
    assert quant_lib.shortlist_width(1, 10_000) == 32  # the floor
    assert quant_lib.shortlist_width(10, 48) == 48  # clamped to n


def test_compression_shares_the_quant_definition():
    """dist/compression's wire model and core/quant are ONE formula."""
    from repro.dist import compression

    g = jnp.asarray(np.random.default_rng(1).normal(size=(77,)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(compression.fake_int8_roundtrip({"w": g})["w"]),
        np.asarray(quant_lib.fake_quant(g)),
    )


# ---------------------------------------------------------------------------
# int8 kernel regime vs the jnp dequant path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["euclidean", "sqeuclidean"])
def test_quant_scan_kernel_matches_its_oracle(data, metric):
    """The kernel quantizes the query side too (the int8 MXU requirement):
    parity is against the same math in plain jnp, ids exact."""
    X, Q = data
    store = quant_lib.QuantStore.build(X)
    codes, scales, sqn = store.device_view()
    d_k, i_k = scan_lib.topk_scan_quant(
        jnp.asarray(Q), codes, scales, k=9, metric=metric, impl="pallas",
        sqnorms=sqn,
    )
    xs = jnp.asarray(Q) * scales[None, :]
    alpha = quant_lib.absmax_scales(xs, axis=1, keepdims=True)
    xq = quant_lib.encode(xs, alpha).astype(jnp.int32)
    cross = alpha * (xq @ codes.astype(jnp.int32).T).astype(jnp.float32)
    d2 = jnp.maximum(
        jnp.sum(jnp.asarray(Q) ** 2, axis=1, keepdims=True)
        + sqn[None, :] - 2.0 * cross, 0.0,
    )
    Dm = jnp.sqrt(d2) if metric == "euclidean" else d2
    neg, ref_i = jax.lax.top_k(-Dm, 9)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(ref_i))
    np.testing.assert_allclose(np.asarray(d_k), -np.asarray(neg),
                               atol=1e-4, rtol=1e-4)


def test_quant_scan_jnp_path_masked_and_blocked(data):
    """The blocked jnp dequant path: per-block decode == whole-corpus
    decode, valid mask respected, ragged tail block handled."""
    X, Q = data
    store = quant_lib.QuantStore.build(X[:301])  # n not a block multiple
    valid = jnp.asarray(np.arange(301) % 5 != 0)
    d, i = scan_lib.topk_scan_quant(
        jnp.asarray(Q), jnp.asarray(store.codes), jnp.asarray(store.scales),
        k=7, metric="euclidean", impl="jnp", valid=valid, block=64,
    )
    from repro.core import metrics
    dec = quant_lib.decode(jnp.asarray(store.codes), jnp.asarray(store.scales))
    Dm = jnp.where(~valid[None, :], jnp.inf,
                   metrics.pairwise(jnp.asarray(Q), dec, metric="euclidean"))
    neg, ref_i = jax.lax.top_k(-Dm, 7)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    np.testing.assert_allclose(np.asarray(d), -np.asarray(neg),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# the "quant" registry key through the engines
# ---------------------------------------------------------------------------

def test_quant_brute_recall_and_bytes(data):
    """The acceptance bar: quantized brute + exact rerank reaches
    recall@10 >= 0.99 vs f32 ground truth while the scanned corpus (the
    code mirror) is a quarter of the f32 bytes."""
    X, Q = data
    gt = index_lib.build("brute", X, {}).search(Q, k=10)
    eng = index_lib.build("brute", X, {"quant": True})
    res = eng.search(Q, k=10)
    assert _recall(res.idx, gt.idx, 10) >= 0.99
    assert eng.quant.codes.nbytes * 4 == X.nbytes
    # memory now reports f32 corpus + the code mirror (+ scales/norms)
    assert eng.memory_bytes() >= X.nbytes + eng.quant.codes.nbytes
    # dists are EXACT original-metric values for the returned ids
    ref = np.linalg.norm(Q[:, None] - X[np.asarray(res.idx)], axis=-1)
    np.testing.assert_allclose(np.asarray(res.dist), ref, atol=1e-4, rtol=1e-4)
    # both stages are counted: n code scores + shortlist_width rescores
    K = quant_lib.shortlist_width(10, N)
    assert (np.asarray(res.comparisons) == N + K).all()


def test_quant_ivf_flat_matches_unquantized_at_full_probe(data):
    X, Q = data
    cfg = {"num_clusters": 8, "nprobe": 8}
    plain = index_lib.build("ivf_flat", X, cfg).search(Q, k=5)
    quant = index_lib.build("ivf_flat", X, dict(cfg) | {"quant": True}).search(Q, k=5)
    # full probing is exhaustive; the exact rerank restores the ordering
    assert _recall(quant.idx, plain.idx, 5) >= 0.99
    # the quantized path pays the extra shortlist rescores
    assert (np.asarray(quant.comparisons) > np.asarray(plain.comparisons)).all()


def test_quant_brute_filtered_never_leaks(data):
    X, Q = data
    score = np.random.default_rng(3).uniform(size=N).astype(np.float32)
    eng = index_lib.build(
        "brute", X, {"quant": True, "attrs": {"score": score}}
    )
    res = eng.search(Q, k=10, filter={"score": {"range": [None, 0.2]}})
    idx = np.asarray(res.idx)
    mask = score <= 0.2
    assert ((idx < 0) | mask[np.maximum(idx, 0)]).all()
    # filtered + quantized == brute over the pre-filtered sub-corpus
    gt = index_lib.build("brute", X[mask], {}).search(Q, k=10)
    ids = np.where(mask)[0]
    gt_idx = np.where(np.asarray(gt.idx) >= 0,
                      ids[np.maximum(np.asarray(gt.idx), 0)], -1)
    assert _recall(idx, gt_idx, 10) >= 0.99


def test_quant_infinity_rerank_prefilter(data):
    """A wide two-stage rerank with quant attached routes through the code
    prefilter (K > shortlist width) and still returns exact original-metric
    distances for its answers."""
    X, Q = data
    eng = index_lib.build("infinity", X, {
        "q": 8.0, "proj_sample": 120, "knn_k": 8, "num_hops": 4,
        "embed_dim": 8, "hidden": (32,), "train_steps": 60,
        "batch_pairs": 128, "rerank": 256,
    })
    base = eng.search(Q, k=10)
    index_lib.attach_quant_store(eng, quant_lib.QuantStore.build(X))
    res = eng.search(Q, k=10)
    assert quant_lib.shortlist_width(10, N) < 256  # prefilter actually ran
    # the quantized prefilter narrows the same tree frontier: near-identical
    # answers, and distances stay exact original-metric values
    assert _recall(res.idx, base.idx, 10) >= 0.9
    ref = np.linalg.norm(Q[:, None] - X[np.maximum(np.asarray(res.idx), 0)], axis=-1)
    got = np.asarray(res.dist)
    np.testing.assert_allclose(got[np.asarray(res.idx) >= 0],
                               ref[np.asarray(res.idx) >= 0],
                               atol=1e-4, rtol=1e-4)


def test_quant_nsw_holds_store_search_unchanged(data):
    """Engines without a corpus-scan stage hold the store (counted in
    memory) but answer exactly as unquantized."""
    X, Q = data
    cfg = {"degree": 8, "ef": 24, "max_steps": 64}
    plain = index_lib.build("nsw", X, cfg)
    quant = index_lib.build("nsw", X, dict(cfg) | {"quant": True})
    r0, r1 = plain.search(Q, k=5), quant.search(Q, k=5)
    np.testing.assert_array_equal(np.asarray(r0.idx), np.asarray(r1.idx))
    assert quant.memory_bytes() == plain.memory_bytes() + quant.quant.memory_bytes()


# ---------------------------------------------------------------------------
# live: delta codes, upsert scales, compaction rebuild
# ---------------------------------------------------------------------------

def test_quant_live_churn_stays_exact(data):
    X, Q = data
    rng = np.random.default_rng(5)
    live = index_lib.build(
        "live", X, {"engine": "brute", "delta_cap": 64, "quant": True}
    )
    ids = live.upsert(rng.normal(size=(40, D)).astype(np.float32) * 3.0)
    live.delete(ids[:10])
    live.delete(np.arange(7))  # frozen tombstones too
    res = live.search(Q, k=10)
    gt = index_lib.build("brute", live.corpus(), {}).search(Q, k=10)
    s2l = live.slot_to_logical()
    mapped = np.where(np.asarray(res.idx) >= 0,
                      s2l[np.maximum(np.asarray(res.idx), 0)], -1)
    assert _recall(mapped, gt.idx, 10) >= 0.99
    assert live.stats()["quant_bytes"] > 0
    # compaction recomputes scales from the compacted corpus and re-attaches
    # the frozen view; answers stay exact
    live.compact()
    assert live.quant.rows == live._gen.n_frozen + live.delta_cap
    assert getattr(live._gen.frozen, "quant", None) is not None
    res = live.search(Q, k=10)
    mapped = np.where(np.asarray(res.idx) >= 0,
                      live.slot_to_logical()[np.maximum(np.asarray(res.idx), 0)], -1)
    assert _recall(mapped, gt.idx, 10) >= 0.99


# ---------------------------------------------------------------------------
# sharded: codes on the data axis (subprocess — tests see 1 device)
# ---------------------------------------------------------------------------

def test_sharded_quant_matches_single_device():
    script = """
        import numpy as np
        from repro.core import index as index_lib
        rng = np.random.default_rng(0)
        X = rng.normal(size=(512, 16)).astype(np.float32)
        Q = rng.normal(size=(8, 16)).astype(np.float32)
        one = index_lib.build("brute", X, {"quant": True}).search(Q, k=5)
        sh = index_lib.build(
            "sharded", X, {"engine": "brute", "shards": 2, "quant": True})
        two = sh.search(Q, k=5)
        # global scales -> identical first-pass distances per shard; the
        # offset merge preserves the single-device tie order
        np.testing.assert_array_equal(np.asarray(one.idx), np.asarray(two.idx))
        np.testing.assert_allclose(np.asarray(one.dist), np.asarray(two.dist),
                                   rtol=1e-5, atol=1e-5)
        assert sh.memory_bytes() > index_lib.pytree_nbytes(sh.stacked)
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


def test_sharded_quant_rejects_unsupported_engine(data):
    X, _ = data
    with pytest.raises(TypeError, match="shard_supports_quant"):
        index_lib.build("sharded", X, {
            "engine": "nsw", "shards": 1, "quant": True,
            "engine_cfg": {"degree": 8},
        })


# ---------------------------------------------------------------------------
# snapshots: format v3
# ---------------------------------------------------------------------------

def test_snapshot_v3_roundtrips_quant_store(tmp_path, data):
    from repro.core import store as store_lib

    X, Q = data
    eng = index_lib.build("brute", X, {"quant": True})
    path = store_lib.save(eng, str(tmp_path / "q"))
    assert store_lib.peek(path)["format_version"] == 3
    back = store_lib.load(path)
    assert back.quant is not None
    np.testing.assert_array_equal(back.quant.codes, eng.quant.codes)
    np.testing.assert_array_equal(back.quant.scales, eng.quant.scales)
    r0, r1 = eng.search(Q, k=5), back.search(Q, k=5)
    np.testing.assert_array_equal(np.asarray(r0.idx), np.asarray(r1.idx))
    np.testing.assert_array_equal(np.asarray(r0.dist), np.asarray(r1.dist))


def test_snapshot_v3_roundtrips_live_quant(tmp_path, data):
    from repro.core import store as store_lib

    X, Q = data
    live = index_lib.build(
        "live", X, {"engine": "brute", "delta_cap": 32, "quant": True}
    )
    live.upsert(np.random.default_rng(6).normal(size=(10, D)).astype(np.float32))
    live.delete([3, 4])
    r0 = live.search(Q, k=5)
    back = store_lib.load(store_lib.save(live, str(tmp_path / "lq")))
    assert back.quant.rows == back._gen.n_frozen + back.delta_cap
    r1 = back.search(Q, k=5)
    np.testing.assert_array_equal(np.asarray(r0.idx), np.asarray(r1.idx))
    np.testing.assert_array_equal(np.asarray(r0.dist), np.asarray(r1.dist))


def test_snapshot_v2_layout_still_loads(tmp_path, data):
    """A quant-less v3 snapshot is layout-identical to v2: rewriting the
    version back to 2 must load byte-for-byte (back-compat guarantee)."""
    import json

    from repro.core import store as store_lib

    X, Q = data
    eng = index_lib.build("brute", X, {})
    path = store_lib.save(eng, str(tmp_path / "v2"))
    meta = store_lib.peek(path)
    meta["format_version"] = 2
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)
    back = store_lib.load(path)
    assert getattr(back, "quant", None) is None
    r0, r1 = eng.search(Q, k=5), back.search(Q, k=5)
    np.testing.assert_array_equal(np.asarray(r0.idx), np.asarray(r1.idx))


# ---------------------------------------------------------------------------
# registry-wide memory audit
# ---------------------------------------------------------------------------

ENGINE_CFGS = {
    "brute": {},
    "ivf_flat": {"num_clusters": 8, "nprobe": 4},
    "ivf_pq": {"num_clusters": 8, "M": 4, "ksub": 16, "nprobe": 4, "rerank": 16},
    "nsw": {"degree": 8, "ef": 24, "max_steps": 64},
    "infinity": {"q": 8.0, "proj_sample": 120, "knn_k": 8, "num_hops": 4,
                 "embed_dim": 8, "hidden": (32,), "train_steps": 40,
                 "batch_pairs": 128, "rerank": 16},
    "live": {"engine": "brute", "delta_cap": 32},
}


@pytest.mark.parametrize("name", list(ENGINE_CFGS))
def test_memory_bytes_covers_all_resident_arrays(name, data):
    """The audit: memory_bytes() must cover every array the engine keeps
    resident — its own state (== the snapshot tree, which by construction
    holds all of it), the attribute columns AND the quant codes."""
    from repro.core import store as store_lib

    X, _ = data
    score = np.arange(N, dtype=np.float32)
    eng = index_lib.build(name, X, dict(ENGINE_CFGS[name]) | {
        "attrs": {"score": score}, "quant": True,
    })
    arrays, _ = store_lib.engine_snapshot_state(eng)
    floor = (index_lib.pytree_nbytes(arrays)
             + eng.attrs.memory_bytes() + eng.quant.memory_bytes())
    assert eng.memory_bytes() >= floor
