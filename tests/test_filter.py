"""Filtered search subsystem (DESIGN.md §12): predicate AST + attribute
store, exactness of filtered exhaustive engines against a pre-filtered
brute oracle (bit-identical incl. tie order), mask composition with the
live subsystem's tombstones, selectivity-scaled infinity recall, sharded
parity (subprocess), snapshot format v2, and registry ergonomics."""
import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, D = 240, 16


def _run_distributed(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, D)).astype(np.float32)
    Q = rng.normal(size=(10, D)).astype(np.float32)
    attrs = {
        "cat": [f"c{i % 4}" for i in range(N)],
        "score": rng.uniform(0.0, 1.0, size=N).astype(np.float32),
    }
    return X, Q, attrs


FLT = {"cat": {"isin": ["c0", "c1"]}, "score": {"range": [0.2, None]}}


def _np_mask(attrs, n):
    """Host-side oracle evaluation of FLT."""
    return np.array([
        attrs["cat"][i] in ("c0", "c1") and attrs["score"][i] >= 0.2
        for i in range(n)
    ])


def _remap(sub_idx, mask):
    """Sub-corpus result ids -> original-corpus ids (-1 preserved)."""
    ids = np.where(mask)[0]
    sub_idx = np.asarray(sub_idx)
    return np.where(sub_idx >= 0, ids[np.maximum(sub_idx, 0)], -1)


# ---------------------------------------------------------------------------
# AST + store
# ---------------------------------------------------------------------------

def test_filter_ast_and_mask_compile(data):
    from repro.core import attrs as attrs_lib, filter as filter_lib

    X, _, attrs = data
    store = attrs_lib.AttributeStore.build(attrs, N)
    mask = np.asarray(filter_lib.compile_mask(filter_lib.Filter.from_spec(FLT), store))
    np.testing.assert_array_equal(mask, _np_mask(attrs, N))
    # dict sugar: bare scalar = eq, bare list = isin
    m_eq = np.asarray(filter_lib.resolve_mask({"cat": "c0"}, store, N))
    np.testing.assert_array_equal(m_eq, np.arange(N) % 4 == 0)
    m_in = np.asarray(filter_lib.resolve_mask({"cat": ["c0", "c3"]}, store, N))
    np.testing.assert_array_equal(m_in, np.isin(np.arange(N) % 4, [0, 3]))
    # selectivity estimator == exact passing fraction
    assert filter_lib.selectivity(mask) == pytest.approx(mask.mean())
    # unknown labels match nothing; unknown columns raise
    assert not np.asarray(filter_lib.resolve_mask({"cat": "zebra"}, store, N)).any()
    with pytest.raises(KeyError):
        filter_lib.resolve_mask({"bogus": 1}, store, N)
    with pytest.raises(ValueError):
        filter_lib.Filter.from_spec({"cat": {"isin": [1], "eq": 2}})
    with pytest.raises(TypeError):
        filter_lib.resolve_mask({"cat": {"range": [0, 1]}}, store, N)
    # compiled masks cache by the hashable AST
    f = filter_lib.Filter.from_spec(FLT)
    a = filter_lib.resolve_mask(f, store, N)
    assert filter_lib.resolve_mask(f, store, N) is a


def test_attribute_store_missing_and_snapshot(data):
    from repro.core import attrs as attrs_lib, filter as filter_lib

    _, _, attrs = data
    store = attrs_lib.AttributeStore.build(attrs, N)
    # rows written without values get missing sentinels: never pass
    ext = store.take(np.arange(N), capacity=N + 8)
    ext.set_rows(N, None, 8)
    m = np.asarray(filter_lib.resolve_mask(FLT, ext, N + 8))
    assert not m[N:].any()
    # column-name / length validation
    with pytest.raises(ValueError):
        attrs_lib.AttributeStore.build({"a/b": np.zeros(N)}, N)
    with pytest.raises(ValueError):
        attrs_lib.AttributeStore.build({"x": np.zeros(N - 1)}, N)
    with pytest.raises(KeyError):
        ext.set_rows(N, {"bogus": [1] * 4}, 4)
    # snapshot hooks round-trip bit-exact (vocab order included)
    arrays, statics = store.snapshot_state()
    back = attrs_lib.AttributeStore.from_snapshot(arrays, statics)
    np.testing.assert_array_equal(
        np.asarray(filter_lib.resolve_mask(FLT, back, N)), _np_mask(attrs, N)
    )


# ---------------------------------------------------------------------------
# exhaustive engines: filtered == brute on the pre-filtered sub-corpus
# ---------------------------------------------------------------------------

def test_brute_filtered_bit_identical_to_subcorpus(data):
    """The returned id sequence — including tie order — is bit-identical
    to brute force over the pre-filtered sub-corpus.  Distances agree to
    reduction-order rounding only: XLA tiles the (B, n) and (B, n_pass)
    scans differently, so the last ulp of a dot product can shift."""
    from repro.core import index as index_lib

    X, Q, attrs = data
    mask = _np_mask(attrs, N)
    eng = index_lib.build("brute", X, {"attrs": attrs})
    res = eng.search(Q, k=7, filter=FLT)
    sub = index_lib.build("brute", X[mask], {}).search(Q, k=7)
    np.testing.assert_array_equal(np.asarray(res.idx), _remap(sub.idx, mask))
    np.testing.assert_allclose(
        np.asarray(res.dist), np.asarray(sub.dist), rtol=1e-6
    )
    # comparisons count the rows actually scored = the passing rows
    assert (np.asarray(res.comparisons) == mask.sum()).all()
    # unfiltered behavior untouched
    r0 = eng.search(Q, k=7)
    assert (np.asarray(r0.comparisons) == N).all()


def test_brute_filtered_tie_order(data):
    """Crafted duplicate rows: the filtered scan must keep the
    tie-to-lowest-index contract exactly as a pre-filtered scan would."""
    from repro.core import index as index_lib

    rng = np.random.default_rng(3)
    base = rng.normal(size=(40, 4)).astype(np.float32)
    X = np.concatenate([base, base, base])  # every row appears 3x -> forced ties
    n = X.shape[0]
    attrs = {"grp": (np.arange(n) % 2).astype(np.float32)}
    mask = np.arange(n) % 2 == 0
    Q = base[:6] + 0.0  # queries exactly ON dataset points
    eng = index_lib.build("brute", X, {"attrs": attrs})
    res = eng.search(Q, k=5, filter={"grp": {"eq": 0}})
    sub = index_lib.build("brute", X[mask], {}).search(Q, k=5)
    np.testing.assert_array_equal(np.asarray(res.idx), _remap(sub.idx, mask))
    np.testing.assert_allclose(
        np.asarray(res.dist), np.asarray(sub.dist), rtol=1e-6
    )


def test_ivf_flat_exhaustive_filtered_matches_brute(data):
    """nprobe = num_clusters probes every list: the filtered answer must
    match the filtered brute oracle (random data: no cross-cluster ties)."""
    from repro.core import index as index_lib

    X, Q, attrs = data
    mask = _np_mask(attrs, N)
    brute = index_lib.build("brute", X, {"attrs": attrs}).search(Q, k=7, filter=FLT)
    ivf = index_lib.build(
        "ivf_flat", X, {"num_clusters": 8, "nprobe": 8, "attrs": attrs}
    )
    res = ivf.search(Q, k=7, filter=FLT)
    np.testing.assert_array_equal(np.asarray(res.idx), np.asarray(brute.idx))
    np.testing.assert_allclose(
        np.asarray(res.dist), np.asarray(brute.dist), rtol=1e-6
    )
    # exhaustive probing scores exactly the passing rows
    assert (np.asarray(res.comparisons) == mask.sum()).all()


@pytest.mark.parametrize("name,cfg", [
    ("ivf_pq", {"num_clusters": 8, "M": 4, "ksub": 16, "nprobe": 4, "rerank": 16}),
    ("nsw", {"degree": 8, "ef": 24, "max_steps": 64}),
    ("ivf_flat", {"num_clusters": 8, "nprobe": 2}),
])
def test_approximate_engines_only_return_passing_rows(name, cfg, data):
    """Approximate settings keep the hard guarantee: every returned id
    passes the predicate, dists ascend, -1 marks missing results."""
    from repro.core import index as index_lib

    X, Q, attrs = data
    mask = _np_mask(attrs, N)
    eng = index_lib.build(name, X, dict(cfg) | {"attrs": attrs})
    res = eng.search(Q, k=7, filter=FLT)
    idx = np.asarray(res.idx)
    ok = idx[idx >= 0]
    assert mask[ok].all(), f"{name} returned non-passing rows"
    fin = np.where(np.isfinite(np.asarray(res.dist)), np.asarray(res.dist), np.inf)
    assert (np.diff(fin, axis=1) >= -1e-6).all()
    assert (np.asarray(res.idx)[np.isinf(fin)] == -1).all()


def test_filter_as_search_default_and_raw_mask(data):
    """cfg {"filter": ...} becomes a sticky search default; raw bool masks
    bypass the store entirely (the composition path)."""
    from repro.core import index as index_lib

    X, Q, attrs = data
    mask = _np_mask(attrs, N)
    sticky = index_lib.build("brute", X, {"attrs": attrs, "filter": FLT})
    res = sticky.search(Q, k=5)  # no explicit filter: default applies
    assert (np.asarray(res.comparisons) == mask.sum()).all()
    plain = index_lib.build("brute", X, {})
    res2 = plain.search(Q, k=5, filter=mask)  # raw mask, no attrs needed
    np.testing.assert_array_equal(np.asarray(res2.idx), np.asarray(res.idx))
    with pytest.raises(TypeError):  # predicate without a store is an error
        plain.search(Q, k=5, filter=FLT)
    with pytest.raises(ValueError):  # wrong-length mask too
        plain.search(Q, k=5, filter=mask[: N // 2])


# ---------------------------------------------------------------------------
# infinity: filtered two-stage with selectivity-scaled rerank
# ---------------------------------------------------------------------------

def test_infinity_filtered_recall_at_narrow_selectivity():
    """Acceptance: recall@10 >= 0.9 at selectivity 0.1 on the synthetic
    benchmark — the selectivity-scaled rerank width is what makes this
    hold (an unscaled width-64 rerank would see too few passing rows)."""
    from repro.core import index as index_lib
    from repro.data import synthetic

    n, nq = 2048, 32
    pool = synthetic.make("manifold", n + nq, seed=0)
    X, Q = pool[:n], pool[n:]
    rng = np.random.default_rng(1)
    score = rng.uniform(0.0, 1.0, size=n).astype(np.float32)
    flt = {"score": {"range": [None, 0.1]}}
    mask = score <= 0.1
    assert 0.05 < mask.mean() < 0.15  # ~selectivity 0.1
    eng = index_lib.build("infinity", np.asarray(X), {
        "q": math.inf, "proj_sample": 512, "knn_k": 12, "num_hops": 5,
        "embed_dim": 16, "hidden": (64,), "train_steps": 300,
        "batch_pairs": 256, "rerank": 64, "attrs": {"score": score},
    })
    res = eng.search(Q, k=10, filter=flt)
    idx = np.asarray(res.idx)
    ok = idx[idx >= 0]
    assert mask[ok].all(), "infinity returned non-passing rows"
    gt = index_lib.build("brute", np.asarray(X)[mask], {}).search(Q, k=10)
    gt_idx = _remap(gt.idx, mask)
    hits = [
        len(set(a.tolist()) & set(t.tolist())) / 10
        for a, t in zip(idx, gt_idx)
    ]
    assert np.mean(hits) >= 0.9, f"filtered recall@10 {np.mean(hits):.3f} < 0.9"


def test_infinity_filtered_respects_budget(data):
    """Every tree visit counts against the budget even when the vantage
    fails the predicate (the filter must not create free traversal)."""
    from repro.core import index as index_lib

    X, Q, attrs = data
    eng = index_lib.build("infinity", X, {
        "q": 8.0, "proj_sample": 120, "knn_k": 8, "num_hops": 4,
        "embed_dim": 8, "hidden": (32,), "train_steps": 60,
        "batch_pairs": 128, "rerank": 0, "attrs": attrs,
    })
    comps = np.asarray(eng.search(Q, k=1, budget=15, filter=FLT).comparisons)
    assert (comps <= 15).all()


# ---------------------------------------------------------------------------
# live: filter ∧ tombstone composition
# ---------------------------------------------------------------------------

def test_live_filtered_excludes_tombstones_and_nonmatching_delta(data):
    from repro.core import index as index_lib

    X, Q, attrs = data
    rng = np.random.default_rng(5)
    live = index_lib.build("live", X, {
        "engine": "brute", "delta_cap": 32, "attrs": attrs,
    })
    new = rng.normal(size=(8, D)).astype(np.float32)
    ids = live.upsert(new, attrs={
        "cat": ["c0"] * 4 + ["c9"] * 4,
        "score": np.full(8, 0.5, np.float32),
    })
    live.delete(ids[:2])  # two matching delta rows tombstoned
    res = live.search(Q, k=60, filter={"cat": "c0", "score": {"range": [0.2, None]}})
    idx = np.asarray(res.idx)
    got = set(idx[idx >= 0].tolist())
    assert not (set(ids[:2].tolist()) & got), "tombstoned delta rows leaked"
    assert not (set(ids[4:].tolist()) & got), "non-matching delta rows leaked"
    assert set(ids[2:4].tolist()) <= got, "matching alive delta rows missing"
    # frozen rows still obey the predicate
    frozen_mask = np.array([
        attrs["cat"][i] == "c0" and attrs["score"][i] >= 0.2 for i in range(N)
    ])
    frozen_got = np.array([i for i in got if i < N])
    assert frozen_mask[frozen_got].all()
    # rows upserted WITHOUT attrs get missing sentinels: never match
    ids2 = live.upsert(rng.normal(size=(2, D)).astype(np.float32))
    res2 = live.search(Q, k=60, filter={"cat": "c0"})
    idx2 = np.asarray(res2.idx)
    assert not (set(ids2.tolist()) & set(idx2[idx2 >= 0].tolist()))


def test_live_filtered_exact_vs_logical_oracle_and_compaction(data):
    """Exhaustive inner engine: the filtered live answer equals brute over
    the pre-filtered *logical* corpus — before AND after a compaction
    (which must realign the attribute store with the remap)."""
    from repro.core import index as index_lib

    X, Q, attrs = data
    rng = np.random.default_rng(6)
    live = index_lib.build("live", X, {
        "engine": "brute", "delta_cap": 16, "auto_compact": False,
        "attrs": attrs,
    })
    cats = np.asarray(attrs["cat"])
    scores = np.asarray(attrs["score"]).copy()
    new = rng.normal(size=(6, D)).astype(np.float32)
    new_cat = ["c1", "c0", "c1", "c2", "c1", "c0"]
    new_score = rng.uniform(0.0, 1.0, size=6).astype(np.float32)
    ids = live.upsert(new, attrs={"cat": new_cat, "score": new_score})
    victims = np.asarray([3, 17, int(ids[0])])
    live.delete(victims)

    cats_all = np.concatenate([cats, np.asarray(new_cat)])
    scores_all = np.concatenate([scores, new_score])
    alive = np.ones(N + 6, bool)
    alive[victims] = False

    def oracle(flt_mask_all):
        logical = np.concatenate([X, new])[alive & flt_mask_all]
        return index_lib.build("brute", logical, {}).search(Q, k=5)

    flt = {"cat": {"isin": ["c0", "c1"]}}
    flt_mask = np.isin(cats_all, ["c0", "c1"])
    for round_ in range(2):  # pre- and post-compaction
        res = live.search(Q, k=5, filter=flt)
        gt = oracle(flt_mask)
        # compare by the live logical view (slot ids differ from logical)
        s2l = live.slot_to_logical()
        idx = np.asarray(res.idx)
        mapped = np.where(idx >= 0, s2l[np.maximum(idx, 0)], -1)
        # logical view includes non-passing rows; build the passing remap
        pass_logical = np.where(flt_mask[alive])[0]
        gt_in_logical = np.where(
            np.asarray(gt.idx) >= 0,
            pass_logical[np.maximum(np.asarray(gt.idx), 0)], -1,
        )
        np.testing.assert_array_equal(mapped, gt_in_logical)
        np.testing.assert_allclose(
            np.asarray(res.dist), np.asarray(gt.dist), rtol=1e-6
        )
        if round_ == 0:
            live.compact()  # must realign the attribute store
            assert live.stats()["generation"] == 1


# ---------------------------------------------------------------------------
# snapshot format v2
# ---------------------------------------------------------------------------

def test_store_rejects_future_format_version(tmp_path, data):
    import json

    from repro.core import index as index_lib, store as store_lib

    X, _, _ = data
    path = store_lib.save(index_lib.build("brute", X, {}), str(tmp_path / "s"))
    meta = store_lib.peek(path)
    assert meta["format_version"] == store_lib.FORMAT_VERSION == 3
    meta["format_version"] = store_lib.FORMAT_VERSION + 1
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="newer"):
        store_lib.load(path)
    meta["format_version"] = "v9"  # malformed is rejected too, not compared
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="malformed"):
        store_lib.load(path)


def test_store_reads_v1_layout_back_compat(tmp_path, data):
    """A pre-attrs snapshot (engine arrays at the npz root, version 1)
    still loads byte-for-byte."""
    import json
    import uuid

    from repro.core import index as index_lib, store as store_lib

    X, Q, _ = data
    eng = index_lib.build("brute", X, {})
    arrays, statics = store_lib.engine_snapshot_state(eng)
    path = tmp_path / "v1"
    os.makedirs(path)
    arrays_file = f"arrays-{uuid.uuid4().hex[:12]}.npz"
    np.savez(path / arrays_file, **store_lib.flatten_arrays(arrays))
    with open(path / "meta.json", "w") as f:
        json.dump({"format_version": 1, "engine": "brute",
                   "arrays": arrays_file, "statics": statics}, f)
    back = store_lib.load(str(path))
    r0 = eng.search(Q, k=5)
    r1 = back.search(Q, k=5)
    np.testing.assert_array_equal(np.asarray(r0.idx), np.asarray(r1.idx))
    np.testing.assert_array_equal(np.asarray(r0.dist), np.asarray(r1.dist))


def test_snapshot_roundtrips_attribute_store(tmp_path, data):
    from repro.core import index as index_lib, store as store_lib

    X, Q, attrs = data
    eng = index_lib.build("brute", X, {"attrs": attrs})
    before = eng.search(Q, k=6, filter=FLT)
    back = store_lib.load(store_lib.save(eng, str(tmp_path / "s")))
    after = back.search(Q, k=6, filter=FLT)
    np.testing.assert_array_equal(np.asarray(before.idx), np.asarray(after.idx))
    np.testing.assert_array_equal(np.asarray(before.dist), np.asarray(after.dist))


# ---------------------------------------------------------------------------
# registry ergonomics
# ---------------------------------------------------------------------------

def test_list_engines_and_cli_flag():
    from repro.core import index as index_lib

    engines = index_lib.list_engines()
    assert set(engines) >= {"brute", "ivf_flat", "ivf_pq", "nsw", "infinity",
                            "sharded", "live"}
    assert all(isinstance(v, str) and v for v in engines.values())
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--list-engines"],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    for name in engines:
        assert name in r.stdout


# ---------------------------------------------------------------------------
# sharded parity + combined server restore (subprocess: needs >1 device)
# ---------------------------------------------------------------------------

def test_sharded_filtered_equals_single_device_subprocess():
    """Acceptance: a 2-device filtered search returns exactly the
    single-device answer for exhaustive engines, and the mask row-shards
    with the corpus."""
    out = _run_distributed("""
        import numpy as np, jax
        from repro.core import index as index_lib
        assert len(jax.devices()) >= 2
        rng = np.random.default_rng(0)
        X = rng.normal(size=(256, 16)).astype(np.float32)
        Q = rng.normal(size=(12, 16)).astype(np.float32)
        attrs = {"cat": [f"c{i % 4}" for i in range(256)],
                 "score": rng.uniform(0, 1, 256).astype(np.float32)}
        flt = {"cat": {"isin": ["c0", "c1"]}, "score": {"range": [0.2, None]}}
        mask = (np.arange(256) % 4 < 2) & (attrs["score"] >= 0.2)
        single = index_lib.build("brute", X, {"attrs": attrs}).search(
            Q, k=7, filter=flt)
        for shards in (2, 4):
            sh = index_lib.build("sharded", X, {
                "engine": "brute", "shards": shards, "attrs": attrs})
            res = sh.search(Q, k=7, filter=flt)
            np.testing.assert_array_equal(np.asarray(res.idx), np.asarray(single.idx))
            np.testing.assert_allclose(np.asarray(res.dist), np.asarray(single.dist), rtol=1e-6)
            assert (np.asarray(res.comparisons) == mask.sum()).all()
        # ivf probing every list stays exhaustive under a filter
        sh = index_lib.build("sharded", X, {
            "engine": "ivf_flat", "shards": 2, "attrs": attrs,
            "engine_cfg": {"num_clusters": 8, "nprobe": 8}})
        res = sh.search(Q, k=7, filter=flt)
        np.testing.assert_array_equal(np.asarray(res.idx), np.asarray(single.idx))
        print("OK")
    """)
    assert "OK" in out


def test_server_restore_live_sharded_attrs_subprocess():
    """Satellite: SearchServer.restore() on the combined path — live +
    sharded + attributes — keeps stats() and a (filtered and unfiltered)
    query bit-identical across snapshot/restore."""
    out = _run_distributed("""
        import numpy as np, tempfile, os
        from repro.launch.serve import SearchServer
        rng = np.random.default_rng(0)
        X = rng.normal(size=(256, 16)).astype(np.float32)
        Q = rng.normal(size=(8, 16)).astype(np.float32)
        attrs = {"cat": [f"c{i % 4}" for i in range(256)],
                 "score": rng.uniform(0, 1, 256).astype(np.float32)}
        flt = {"cat": {"isin": ["c0", "c1"]}}
        srv = SearchServer(X, engine="brute", shards=2, cfg={}, live=True,
                           delta_cap=32, attrs=attrs)
        ids = srv.upsert(rng.normal(size=(6, 16)).astype(np.float32),
                         attrs={"cat": ["c0"] * 6,
                                "score": np.full(6, 0.5, np.float32)})
        srv.delete(ids[:2])
        r_plain = srv.query(Q, k=9)
        r_filt = srv.query(Q, k=9, filter=flt)
        with tempfile.TemporaryDirectory() as tmp:
            path = srv.snapshot(os.path.join(tmp, "snap"))
            back = SearchServer.restore(path)
            assert back.live and back.engine == "brute" and back.shards == 2
            b_plain = back.query(Q, k=9)
            b_filt = back.query(Q, k=9, filter=flt)
            for a, b in ((r_plain, b_plain), (r_filt, b_filt)):
                np.testing.assert_array_equal(a.idx, b.idx)
                np.testing.assert_array_equal(a.dist, b.dist)
                np.testing.assert_array_equal(a.comparisons, b.comparisons)
            # stats: everything structural must survive the round-trip
            s0, s1 = srv.stats(), back.stats()
            for key in ("engine", "shards", "live", "memory_bytes",
                        "generation", "frozen_size", "delta_fill",
                        "delta_cap", "tombstones", "n_alive"):
                assert s0[key] == s1[key], (key, s0[key], s1[key])
            # mutation keeps working after restore (store re-extended)
            ids2 = back.upsert(rng.normal(size=(2, 16)).astype(np.float32),
                               attrs={"cat": ["c1", "c9"],
                                      "score": [0.5, 0.5]})
            r2 = back.query(Q, k=60, filter=flt)
            got = set(r2.idx[r2.idx >= 0].tolist())
            assert int(ids2[0]) in got and int(ids2[1]) not in got
        print("OK")
    """)
    assert "OK" in out
