"""Canonical projection P*_q: Lemma 1, Theorem 2 (A1), Prop. 1, Algs. 4-7."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import knn_graph, metrics, qmetric

QS = [1.0, 2.0, 4.0, 8.0, 32.0, math.inf]


def _dissimilarity(n, d=6, seed=0, metric="euclidean"):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    D = np.array(metrics.pairwise(jnp.asarray(X), jnp.asarray(X), metric=metric))
    np.fill_diagonal(D, 0.0)
    return jnp.asarray((D + D.T) / 2), X


@pytest.mark.parametrize("q", QS)
def test_matches_floyd_warshall_reference(q):
    D, _ = _dissimilarity(48)
    Dq = qmetric.canonical_projection(D, q)
    Dref = qmetric.floyd_warshall_reference(D, q)
    np.testing.assert_allclose(np.asarray(Dq), np.asarray(Dref), atol=2e-5)


@pytest.mark.parametrize("q", QS)
def test_satisfies_q_triangle_inequality(q):
    """Lemma 1: the projected matrix is a valid q-metric."""
    D, _ = _dissimilarity(40, seed=1)
    Dq = qmetric.canonical_projection(D, q)
    assert float(qmetric.q_violation(Dq, q)) <= 1e-5


@pytest.mark.parametrize("q", QS)
def test_axiom_of_projection_fixed_point(q):
    """(A1): P_q(P_q(D)) == P_q(D)."""
    D, _ = _dissimilarity(40, seed=2)
    Dq = qmetric.canonical_projection(D, q)
    Dq2 = qmetric.canonical_projection(Dq, q)
    np.testing.assert_allclose(np.asarray(Dq2), np.asarray(Dq), atol=2e-5)


def test_axiom_of_transformation_scaling():
    """(A2) for the dissimilarity-reducing map x -> x (identity) between
    D and alpha*D with alpha < 1: projections preserve dominance."""
    D, _ = _dissimilarity(32, seed=3)
    for q in (2.0, math.inf):
        hi = qmetric.canonical_projection(D, q)
        lo = qmetric.canonical_projection(0.5 * D, q)
        assert bool(jnp.all(lo <= hi + 1e-5))


def test_projection_never_exceeds_direct_distance():
    D, _ = _dissimilarity(32, seed=4)
    for q in QS:
        Dq = qmetric.canonical_projection(D, q)
        assert bool(jnp.all(Dq <= D + 1e-5))


def test_projection_monotone_decreasing_in_q():
    """Larger q admits cheaper paths: D_q <= D_q' for q >= q'."""
    D, _ = _dissimilarity(32, seed=5)
    prev = qmetric.canonical_projection(D, 1.0)
    for q in [2.0, 4.0, 8.0, math.inf]:
        cur = qmetric.canonical_projection(D, q)
        assert bool(jnp.all(cur <= prev + 1e-5))
        prev = cur


@pytest.mark.parametrize("q", [2.0, 8.0, math.inf])
def test_nearest_neighbor_preservation(q):
    """Prop. 1: argmin preserved (equality for finite q; inclusion at inf)."""
    D, X = _dissimilarity(64, seed=6)
    rng = np.random.default_rng(7)
    Q = rng.normal(size=(8, X.shape[1])).astype(np.float32)
    rows = metrics.pairwise(jnp.asarray(Q), jnp.asarray(X), metric="euclidean")
    Eq = qmetric.project_with_queries(D, rows, q)
    nn0 = np.argmin(np.asarray(rows), axis=1)
    if math.isinf(q):
        # inclusion: the original NN attains the projected minimum
        got = np.asarray(Eq)
        mins = got.min(axis=1)
        assert np.allclose(got[np.arange(len(nn0)), nn0], mins, atol=1e-5)
    else:
        assert (np.argmin(np.asarray(Eq), axis=1) == nn0).all()


def test_sparse_projection_upper_bounds_dense():
    """kNN-restricted paths can only be longer (Algorithm 6 semantics)."""
    D, X = _dissimilarity(48, seed=8)
    idx, _ = knn_graph.knn_graph(jnp.asarray(X), k=8)
    mask = knn_graph.knn_mask(idx, 48)
    for q in (2.0, math.inf):
        dense = qmetric.canonical_projection(D, q)
        sparse = qmetric.sparse_canonical_projection(
            D, mask, q, num_hops=8, schedule="doubling"
        )
        finite = jnp.isfinite(sparse)
        assert bool(jnp.all(sparse[finite] >= dense[finite] - 1e-5))
        # edges present in the graph get exact single-hop-or-better values
        sym = np.asarray(mask | mask.T)
        assert bool(jnp.all(jnp.asarray(np.asarray(sparse)[sym]) <= np.asarray(D)[sym] + 1e-5))


def test_sparse_bellman_matches_paper_hop_semantics():
    D, X = _dissimilarity(24, seed=9)
    mask = jnp.zeros((24, 24), bool).at[jnp.arange(23), jnp.arange(1, 24)].set(True)
    # path graph: after l Bellman sweeps only l+1-hop pairs are finite
    out = qmetric.sparse_canonical_projection(
        D, mask, 2.0, num_hops=3, schedule="bellman"
    )
    finite = np.isfinite(np.asarray(out))
    ij = np.abs(np.subtract.outer(np.arange(24), np.arange(24)))
    assert finite[ij <= 4].all()
    assert not finite[ij > 4].any()


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(4, 24),
    q=st.sampled_from([1.0, 2.0, 8.0, math.inf]),
    seed=st.integers(0, 10_000),
)
def test_property_projection_is_q_metric(n, q, seed):
    rng = np.random.default_rng(seed)
    D = rng.uniform(0.1, 5.0, size=(n, n)).astype(np.float32)
    D = (D + D.T) / 2
    np.fill_diagonal(D, 0.0)
    Dq = qmetric.canonical_projection(jnp.asarray(D), q)
    assert float(qmetric.q_violation(Dq, q)) <= 1e-4
    assert bool(jnp.all(Dq <= jnp.asarray(D) + 1e-5))


def test_pallas_impl_matches_jnp():
    D, _ = _dissimilarity(40, seed=10)
    for q in (2.0, math.inf):
        a = qmetric.canonical_projection(D, q, impl="jnp")
        b = qmetric.canonical_projection(D, q, impl="pallas")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
