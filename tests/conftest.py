import os
import sys

# tests must see exactly ONE device (the dry-run alone forces 512 host
# devices, in its own process).  Distributed tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
