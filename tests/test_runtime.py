"""launch/runtime — the overload-safe async serving runtime (DESIGN.md §18).

Covers: bit-exact parity with the synchronous path, bounded admission
(capacity rejections with retry hints), shed-before-compute of expired
deadlines, EDF ordering, watermark backpressure walking health + budget,
circuit breaking driven by the chaos ``slow_search`` site, SearchServer
counter consistency under concurrent worker threads (the §18 thread-safety
fix), the multi-process HTTP socket path, and the open-loop overload
acceptance run (≥2× measured saturation: bounded p99 for admitted work,
explicit outcomes for everything else, recall of admitted answers held).
"""
import json
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import backoff as backoff_lib
from repro.core import chaos as chaos_lib
from repro.launch import runtime as rt_lib
from repro.launch import serve as serve_lib
from repro.launch.runtime import (
    BoundedQueue, OverloadPolicy, Rejected, ServingRuntime, _Request,
    start_http_front,
)

N, D = 400, 16


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    return rng.standard_normal((N, D)).astype(np.float32)


@pytest.fixture(scope="module")
def server(corpus):
    return serve_lib.SearchServer(corpus, engine="brute")


def _mkreq(seq, k=5, dl_abs=None):
    return _Request(np.zeros((D,), np.float32), k, dl_abs, None, None, seq)


# ------------------------------------------------------------ BoundedQueue

def test_queue_edf_order_within_bucket():
    q = BoundedQueue(capacity=8)
    now = time.monotonic()
    # submit out of deadline order; None-deadline goes last, FIFO ties
    for seq, dl in [(0, now + 9.0), (1, now + 1.0), (2, None), (3, now + 5.0)]:
        assert q.offer(("b",), _mkreq(seq, dl_abs=dl))
    key, batch = q.take_batch(max_batch=8, flush_s=0.0)
    assert [r.seq for r in batch] == [1, 3, 0, 2]


def test_queue_capacity_and_depth():
    q = BoundedQueue(capacity=2)
    assert q.offer(("b",), _mkreq(0))
    assert q.offer(("b",), _mkreq(1))
    assert not q.offer(("b",), _mkreq(2))  # full: refused, not queued
    assert q.depth() == 2
    _, batch = q.take_batch(1, 0.0)
    assert len(batch) == 1 and q.depth() == 1
    assert q.offer(("b",), _mkreq(3))  # space again


def test_queue_buckets_flush_separately():
    q = BoundedQueue(capacity=8)
    q.offer((5, None), _mkreq(0, k=5))
    time.sleep(0.002)
    q.offer((9, None), _mkreq(1, k=9))
    key1, b1 = q.take_batch(8, 0.0)
    key2, b2 = q.take_batch(8, 0.0)
    assert key1 == (5, None) and key2 == (9, None)  # oldest head first
    assert [r.k for r in b1] == [5] and [r.k for r in b2] == [9]


def test_queue_size_triggers_flush_before_timeout():
    q = BoundedQueue(capacity=8)
    for s in range(4):
        q.offer(("b",), _mkreq(s))
    t0 = time.monotonic()
    _, batch = q.take_batch(max_batch=4, flush_s=30.0)  # size reached: no wait
    assert len(batch) == 4
    assert time.monotonic() - t0 < 1.0


# ------------------------------------------------------- runtime lifecycle

def test_parity_with_direct_query(server, corpus):
    run = ServingRuntime(server, OverloadPolicy(max_batch=8, flush_ms=2.0))
    run.start()
    try:
        tickets = [run.submit(corpus[i], k=10) for i in range(12)]
        results = [t.result(timeout=30) for t in tickets]
    finally:
        run.stop()
    direct = server.query(corpus[:12], k=10)
    for i, r in enumerate(results):
        assert r.outcome == "ok"
        np.testing.assert_array_equal(r.idx[0], direct.idx[i])
        assert r.queue_ms >= 0.0


def test_admission_rejects_at_capacity_with_hint(server, corpus):
    run = ServingRuntime(server, OverloadPolicy(capacity=4))  # NOT started
    for i in range(4):
        run.submit(corpus[i], k=5)
    with pytest.raises(Rejected) as ei:
        run.submit(corpus[4], k=5)
    assert ei.value.reason == "capacity"
    assert ei.value.retry_after_s > 0.0
    assert run.stats()["rejected_capacity"] == 1
    run.stop()  # drains: queued work resolves shed_shutdown, not dropped
    assert run.stats()["shed_shutdown"] == 4


def test_expired_requests_shed_before_compute(server, corpus):
    run = ServingRuntime(server, OverloadPolicy(flush_ms=1.0))  # not started
    batches_before = server.stats()["batches"]
    t_live = run.submit(corpus[0], k=5, deadline_ms=5_000.0)
    t_dead = [run.submit(corpus[i], k=5, deadline_ms=1.0) for i in (1, 2)]
    time.sleep(0.02)  # the 1ms deadlines lapse while queued
    run.start()
    try:
        live = t_live.result(timeout=30)
        dead = [t.result(timeout=30) for t in t_dead]
    finally:
        run.stop()
    assert live.outcome == "ok" and live.deadline_met
    for r in dead:
        assert r.outcome == "shed_expired"
        assert not r.deadline_met
        assert (r.idx == -1).all() and int(r.comparisons.sum()) == 0
    # the shed rows never reached the engine: one batch (the live one)
    assert server.stats()["batches"] == batches_before + 1
    assert run.stats()["shed_expired"] == 2


def test_backpressure_walks_health_and_budget(server, corpus):
    pol = OverloadPolicy(capacity=16, high_watermark=0.5, low_watermark=0.25,
                         budget=256, budget_floor=8)
    run = ServingRuntime(server, pol)  # not started: depth is ours to set
    for i in range(12):  # fill 0.75 > high watermark
        run.submit(corpus[i], k=5)
    eff = run._backpressure()
    assert server.health == "DEGRADED"
    assert eff < 256  # headroom 0.25 -> budget halved down the ladder
    run.queue.drain()  # depth 0 < low watermark
    assert run._backpressure() == 256
    assert server.health == "SERVING"


# --------------------------------------------------- breaker x chaos wiring

def _chaos_server(corpus, rules, **kw):
    return serve_lib.SearchServer(
        corpus, engine="brute",
        chaos={"seed": 0, "rules": rules}, **kw)


def test_breaker_trips_then_rejects_submits(corpus):
    srv = _chaos_server(
        corpus, [{"site": "slow_search", "kind": "error", "rate": 1.0}])
    pol = OverloadPolicy(flush_ms=1.0, breaker_trip=2,
                         breaker_cooldown_s=60.0)
    run = ServingRuntime(srv, pol).start()
    try:
        for _ in range(2):  # two consecutive dispatch faults trip it
            t = run.submit(corpus[0], k=5, deadline_ms=5_000.0)
            with pytest.raises(chaos_lib.TransientFault):
                t.result(timeout=30)
        assert run.breaker.state == run.breaker.OPEN
        with pytest.raises(Rejected) as ei:
            run.submit(corpus[0], k=5)
        assert ei.value.reason == "breaker"
        assert ei.value.retry_after_s > 0.0
        st = run.stats()
        assert st["dispatch_faults"] == 2
        assert st["breaker_trips"] == 1
        assert st["rejected_breaker"] == 1
        # the runtime-level site fired, deterministically
        assert srv.chaos.counters["slow_search:error"] == 2
    finally:
        run.stop()


def test_open_breaker_sheds_queued_work(corpus):
    srv = _chaos_server(
        corpus, [{"site": "slow_search", "kind": "error", "rate": 1.0}])
    pol = OverloadPolicy(flush_ms=1.0, breaker_trip=1,
                         breaker_cooldown_s=60.0)
    run = ServingRuntime(srv, pol)  # not started: stage two buckets
    t_bad = run.submit(corpus[0], k=5, deadline_ms=5_000.0)
    time.sleep(0.002)  # the k=5 bucket is strictly older -> dispatches first
    t_shed = run.submit(corpus[1], k=9, deadline_ms=5_000.0)
    run.start()
    try:
        with pytest.raises(chaos_lib.TransientFault):
            t_bad.result(timeout=30)  # first bucket faults, trips breaker
        r = t_shed.result(timeout=30)  # second bucket fast-fails, explicit
        assert r.outcome == "shed_breaker"
        assert (r.idx == -1).all()
        assert run.stats()["shed_breaker"] == 1
    finally:
        run.stop()


# --------------------------------------- SearchServer counters under threads

def test_fault_counters_consistent_under_concurrent_queries(corpus):
    # chaos fires every engine call -> per-query fault/retry counts are
    # exact; lost updates from the old unlocked `+= 1` shows up as a deficit
    srv = _chaos_server(
        corpus, [{"site": "search", "kind": "error", "rate": 1.0}],
        policy=serve_lib.FaultPolicy(max_retries=2, backoff_base_s=1e-4,
                                     backoff_cap_s=1e-3))
    T, Q = 6, 10

    def worker():
        for _ in range(Q):
            with pytest.raises(chaos_lib.TransientFault):
                srv.query(corpus[:2], k=5, deadline_ms=None)

    threads = [threading.Thread(target=worker) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # per query: initial attempt + 2 retries, all fault -> 3 faults, 2 retries
    assert srv.fault_counters["faults"] == T * Q * 3
    assert srv.fault_counters["retries"] == T * Q * 2
    assert srv.chaos.counters["search:error"] == T * Q * 3


def test_latency_counters_consistent_under_concurrent_queries(corpus):
    srv = serve_lib.SearchServer(corpus, engine="brute")
    T, Q, B = 6, 15, 4
    srv.query(corpus[:B], k=5)  # warm the (bucket, k) jit key once

    def worker():
        for _ in range(Q):
            srv.query(corpus[:B], k=5)

    threads = [threading.Thread(target=worker) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = srv.stats()
    assert st["batches"] == 1 + T * Q
    assert st["queries"] == (1 + T * Q) * B


# ------------------------------------------------ multi-process socket path

_CLIENT = r"""
import json, random, sys, urllib.request
url, n, d, seed = sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
random.seed(seed)
codes = {}
for i in range(n):
    q = [random.gauss(0, 1) for _ in range(d)]
    body = json.dumps({"q": q, "k": 5, "deadline_ms": 10000}).encode()
    req = urllib.request.Request(url + "/search", data=body,
                                 headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            out = json.loads(resp.read())
            assert out["outcome"] == "ok" and len(out["idx"]) == 5
            codes[resp.status] = codes.get(resp.status, 0) + 1
    except urllib.error.HTTPError as e:
        codes[e.code] = codes.get(e.code, 0) + 1
print(json.dumps(codes))
"""


def test_http_front_multiprocess_clients(server, corpus, tmp_path):
    run = ServingRuntime(server, OverloadPolicy(max_batch=8, flush_ms=2.0))
    run.start()
    httpd = start_http_front(run, port=0)
    port = httpd.server_address[1]
    script = tmp_path / "client.py"
    script.write_text(_CLIENT)
    try:
        procs = [
            subprocess.Popen(
                [sys.executable, str(script),
                 f"http://127.0.0.1:{port}", "8", str(D), str(seed)],
                stdout=subprocess.PIPE, text=True)
            for seed in range(3)
        ]
        outs = [p.communicate(timeout=120)[0] for p in procs]
        assert all(p.returncode == 0 for p in procs)
    finally:
        httpd.shutdown()
        run.stop()
    codes = [json.loads(o) for o in outs]
    # real sockets, separate client processes, all answered with 200s
    assert all(c == {"200": 8} for c in codes), codes
    assert run.stats()["completed"] >= 24


def test_http_front_maps_rejections(server, corpus):
    run = ServingRuntime(server, OverloadPolicy(capacity=2))  # not started
    httpd = start_http_front(run, port=0)
    port = httpd.server_address[1]
    import urllib.error
    import urllib.request

    def post(payload):
        req = urllib.request.Request(
            f"http://127.0.0.1:{port}/search",
            data=json.dumps(payload).encode(),
            headers={"Content-Type": "application/json"})
        return urllib.request.urlopen(req, timeout=30)

    def fill():  # these resolve shed_shutdown (504) when the test stops
        try:
            post({"q": corpus[0].tolist(), "k": 5})
        except urllib.error.HTTPError:
            pass

    try:
        for i in range(2):  # fill the queue (runtime not started)
            threading.Thread(target=fill, daemon=True).start()
        deadline = time.monotonic() + 5.0
        while run.queue.depth() < 2 and time.monotonic() < deadline:
            time.sleep(0.005)
        with pytest.raises(urllib.error.HTTPError) as ei:
            post({"q": corpus[0].tolist(), "k": 5})
        assert ei.value.code == 429  # capacity -> 429 + Retry-After
        assert float(ei.value.headers["Retry-After"]) > 0.0
        with pytest.raises(urllib.error.HTTPError) as ei:
            post({"k": 5})  # malformed: no q
        assert ei.value.code == 400
    finally:
        httpd.shutdown()
        run.stop()


# ------------------------------------------------- open-loop acceptance run

def test_open_loop_overload_acceptance(corpus):
    """ISSUE 10 acceptance: at ≥2× measured saturation with per-request
    deadlines, admitted requests answer within a bounded p99, everything
    else sheds/rejects with an explicit outcome, the queue stays bounded,
    and admitted answers keep recall@10 ≥ 0.9."""
    spike_ms, deadline_ms = 10.0, 60.0
    srv = _chaos_server(  # every dispatch pays a deterministic 10ms stall
        corpus,
        [{"site": "slow_search", "kind": "latency", "rate": 1.0,
          "ms": spike_ms}])
    pol = OverloadPolicy(capacity=64, max_batch=4, flush_ms=2.0,
                         breaker_trip=10, breaker_cooldown_s=0.05)
    run = ServingRuntime(srv, pol).start()
    for b in (1, 2, 4):  # pre-warm every pow2 bucket the run can form
        srv.query(corpus[:b], k=10, record=False)

    # measured saturation: the batcher serves max_batch per stall window
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        srv.query(corpus[:pol.max_batch], k=10, record=False)
    service_s = (time.perf_counter() - t0) / reps + spike_ms / 1e3
    sat_qps = pol.max_batch / service_s
    offered_qps = 2.0 * sat_qps

    rng = np.random.default_rng(11)
    duration_s = 1.5
    done_at = {}
    tickets, t_submit, rejected = [], [], 0
    t_start = time.monotonic()
    next_t = t_start
    i = 0
    while True:
        next_t += float(rng.exponential(1.0 / offered_qps))  # open loop
        if next_t - t_start > duration_s:
            break
        lag = next_t - time.monotonic()
        if lag > 0:
            time.sleep(lag)
        try:
            t = run.submit(corpus[i % N], k=10, deadline_ms=deadline_ms)
        except Rejected as e:
            assert e.reason in ("capacity", "breaker")
            assert e.retry_after_s > 0.0
            rejected += 1
        else:
            seq = t.seq
            t._future.add_done_callback(
                lambda f, s=seq: done_at.setdefault(s, time.monotonic()))
            tickets.append((i % N, time.monotonic(), t))
            t_submit.append(time.monotonic())
        i += 1
    submitted = len(tickets)
    results = [(qi, ts, t.seq, t.result(timeout=60)) for qi, ts, t in tickets]
    run.stop()

    # -- accounting: every request has an explicit fate, nothing silent
    outcomes = {}
    for _, _, _, r in results:
        outcomes[r.outcome] = outcomes.get(r.outcome, 0) + 1
    assert sum(outcomes.values()) == submitted
    assert set(outcomes) <= {"ok", "shed_expired", "shed_breaker",
                             "shed_shutdown"}
    st = run.stats()
    assert st["queue_depth"] == 0  # fully drained, never unbounded
    assert st["admitted"] == submitted

    # -- at 2x saturation the system MUST refuse work, not absorb it
    shed = submitted - outcomes.get("ok", 0)
    assert shed + rejected > 0
    shed_rate = (shed + rejected) / (submitted + rejected)

    # -- bounded p99 for admitted-and-answered requests: queue wait is
    #    capped by the deadline (expired work sheds pre-compute), so e2e
    #    latency is bounded by deadline + one dispatch (stall + compute)
    ok_lat_ms = [(done_at[seq] - ts) * 1e3
                 for _, ts, seq, r in results if r.outcome == "ok"]
    assert len(ok_lat_ms) > 0  # overload never starved admitted work
    p99 = float(np.percentile(ok_lat_ms, 99))
    bound_ms = deadline_ms + 20 * (spike_ms + 1e3 * service_s)
    assert p99 <= bound_ms, (p99, bound_ms)

    # -- goodput: answers that also met their deadline
    met = sum(1 for _, _, _, r in results
              if r.outcome == "ok" and r.deadline_met)
    goodput_qps = met / duration_s
    assert goodput_qps > 0.0

    # -- recall@10 of admitted answers (brute is exact per effective view)
    direct = srv.query(corpus[: min(N, 64)], k=10, record=False)
    hits = total = 0
    for qi, _, _, r in results:
        if r.outcome != "ok" or qi >= 64:
            continue
        hits += len(set(r.idx[0].tolist()) & set(direct.idx[qi].tolist()))
        total += 10
    if total:
        assert hits / total >= 0.9
    # the run actually reported its overload economics
    assert 0.0 < shed_rate < 1.0
