"""Beam traversal over the flattened VP tree (DESIGN.md §15).

Parity targets: ``search_reference`` (the host recursion oracle — beam and
best-first share its q-CI/q-CO prune rules exactly) in rows mode, and brute
force at q=1 in vector mode (euclidean satisfies the 1-triangle inequality,
so full-width search is exact there).  Plus the engine-level routing
(`mode="beam"`, auto batching), filtered/budgeted behavior, bucket-remap id
correctness, and live + sharded round-trips through the beam path.
"""
import math
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import index as index_lib
from repro.core import metrics, qmetric, vptree

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _data(n=80, d=8, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    D = np.array(metrics.pairwise(jnp.asarray(X), jnp.asarray(X)))
    np.fill_diagonal(D, 0.0)
    return X, jnp.asarray((D + D.T) / 2)


def _flat(X, *, leaf_size=8, seed=0, with_Z=True):
    tree = vptree.build_vptree(X, metric="euclidean", seed=seed)
    return tree, vptree.flatten_vptree(
        tree, leaf_size=leaf_size, Z=X if with_Z else None
    )


# ---------------------------------------------------------------------------
# flatten invariants
# ---------------------------------------------------------------------------

def test_flatten_invariants():
    X, _ = _data(120, seed=0)
    tree, flat = _flat(X, leaf_size=8)
    n = X.shape[0]
    N, nb, L = flat.num_nodes, flat.num_buckets, flat.leaf_size
    perm = np.asarray(flat.perm)
    # layout covers every point exactly once: internal vantages then buckets
    assert perm.shape == (n,)
    assert (np.sort(perm) == np.arange(n)).all()
    assert 1 <= N <= n and flat.depth >= 1
    rows = np.asarray(flat.bucket_rows)
    assert rows.shape == (nb, L)
    # bucket members live past the vantage block; -1 only as trailing pad
    for b in range(nb):
        mem = rows[b][rows[b] >= 0]
        assert (mem >= N).all() and len(mem) >= 1
        assert (rows[b][: len(mem)] >= 0).all()
    flat_members = rows[rows >= 0]
    assert len(flat_members) == n - N  # every non-vantage point is bucketed
    assert len(np.unique(flat_members)) == len(flat_members)
    # child encoding: >=0 node id, -1 none, <=-2 bucket -(b+2), each bucket
    # referenced exactly once
    refs = []
    for c in (np.asarray(flat.child_in), np.asarray(flat.child_out)):
        assert ((c == -1) | ((c >= 0) & (c < N)) | (c <= -2)).all()
        refs.extend((-(c[c <= -2] + 2)).tolist())
    assert sorted(refs) == list(range(nb))
    # subtree radii: inside radius never exceeds the node radius (ties go
    # outside), and both are finite wherever the child exists
    mu = np.asarray(flat.mu)
    rin = np.asarray(flat.rad_in)
    has_in = np.asarray(flat.child_in) != -1
    assert (rin[has_in] <= mu[has_in] + 1e-4).all()
    assert np.isfinite(np.asarray(flat.rad_out)[np.asarray(flat.child_out) != -1]).all()
    assert flat.centroids is not None and flat.centroids.shape == (nb, X.shape[1])


def test_flatten_without_Z_has_inf_radii_no_centroids():
    X, _ = _data(60, seed=1)
    _, flat = _flat(X, with_Z=False)
    assert flat.centroids is None
    assert np.isinf(np.asarray(flat.rad_in)).all()
    assert np.isinf(np.asarray(flat.rad_out)).all()


# ---------------------------------------------------------------------------
# full-width exactness + oracle parity
# ---------------------------------------------------------------------------

def test_beam_full_width_exact_at_q1():
    """Euclidean is a 1-metric: full-coverage beam == brute force, k>1."""
    X, _ = _data(200, d=8, seed=2)
    _, flat = _flat(X, leaf_size=8, seed=1)
    rng = np.random.default_rng(3)
    Q = jnp.asarray(rng.normal(size=(16, X.shape[1])).astype(np.float32))
    Zf = jnp.asarray(X)[flat.perm]
    ki, kd, comps = vptree.search_beam(flat, Q, q=1.0, k=5, X=Zf)
    D = np.array(metrics.pairwise(Q, jnp.asarray(X)))
    ref = np.argsort(D, axis=1)[:, :5]
    assert (np.asarray(ki) == ref).all()
    assert np.allclose(np.asarray(kd), np.sort(D, axis=1)[:, :5], atol=1e-4)
    # full coverage: every point evaluated at most once (+ centroid evals)
    assert (np.asarray(comps) <= X.shape[0] + flat.num_buckets).all()


@pytest.mark.parametrize("q", [2.0, math.inf])
def test_beam_matches_reference_rows_mode(q):
    """Rows mode (canonical projection — a TRUE q-metric): full-width beam
    returns the oracle's nearest neighbor."""
    X, D = _data(60, seed=5)
    Dq = qmetric.canonical_projection(D, q)
    tree = vptree.build_vptree(D=np.asarray(Dq), seed=2)
    flat = vptree.flatten_vptree(tree, leaf_size=4)
    rng = np.random.default_rng(6)
    Qv = rng.normal(size=(5, X.shape[1])).astype(np.float32)
    rows = metrics.pairwise(jnp.asarray(Qv), jnp.asarray(X))
    Eq = np.asarray(qmetric.project_with_queries(D, rows, q))
    ki, kd, _ = vptree.search_beam(flat, jnp.asarray(Eq), q=q, k=1)
    for b in range(5):
        ridx, rd, _ = vptree.search_reference(tree, Eq[b], q=q)
        assert int(ki[b, 0]) == ridx
        assert abs(float(kd[b, 0]) - rd) < 1e-4


@pytest.mark.parametrize("k", [1, 10])
def test_beam_matches_best_first_distances(k):
    """Beam and best-first share the q-CI/q-CO rules: at full budget both
    return the same distance profile (ids may tie-break differently)."""
    X, D = _data(100, seed=7)
    q = 2.0
    Dq = qmetric.canonical_projection(D, q)
    tree = vptree.build_vptree(D=np.asarray(Dq), seed=3)
    flat = vptree.flatten_vptree(tree, leaf_size=4)
    rng = np.random.default_rng(8)
    Qv = rng.normal(size=(6, X.shape[1])).astype(np.float32)
    rows = metrics.pairwise(jnp.asarray(Qv), jnp.asarray(X))
    Eq = jnp.asarray(np.asarray(qmetric.project_with_queries(D, rows, q)))
    bi, bd, _ = vptree.search_beam(flat, Eq, q=q, k=k)
    fi, fd, _ = vptree.search_best_first(tree, Eq, q=q, k=k)
    assert np.allclose(np.asarray(bd), np.asarray(fd), atol=1e-4)


# ---------------------------------------------------------------------------
# filtered / budgeted / id-remap behavior
# ---------------------------------------------------------------------------

def test_beam_filtered_leaks_nothing_and_matches_brute():
    X, _ = _data(150, seed=9)
    _, flat = _flat(X, leaf_size=8, seed=4)
    rng = np.random.default_rng(10)
    Q = jnp.asarray(rng.normal(size=(8, X.shape[1])).astype(np.float32))
    valid = rng.random(X.shape[0]) < 0.4
    Zf = jnp.asarray(X)[flat.perm]
    ki, kd, _ = vptree.search_beam(
        flat, Q, q=1.0, k=5, X=Zf, valid=jnp.asarray(valid)
    )
    ki = np.asarray(ki)
    assert valid[ki[ki >= 0]].all(), "masked-out ids must never surface"
    D = np.array(metrics.pairwise(Q, jnp.asarray(X)))
    D[:, ~valid] = np.inf
    assert (ki == np.argsort(D, axis=1)[:, :5]).all()


def test_beam_budget_bounds_comparisons():
    X, _ = _data(256, d=8, seed=11)
    _, flat = _flat(X, leaf_size=16, seed=5)
    rng = np.random.default_rng(12)
    Q = jnp.asarray(rng.normal(size=(8, X.shape[1])).astype(np.float32))
    Zf = jnp.asarray(X)[flat.perm]
    for budget in (64, 128, 200):
        _, _, comps = vptree.search_beam(
            flat, Q, q=1.0, k=3, X=Zf, max_comparisons=budget
        )
        assert (np.asarray(comps) <= budget).all(), (budget, np.asarray(comps))
    # a truncated budget still returns k valid, ascending results
    ki, kd, _ = vptree.search_beam(
        flat, Q, q=1.0, k=3, X=Zf, max_comparisons=64
    )
    assert (np.asarray(ki) >= 0).all()
    assert (np.diff(np.asarray(kd), axis=1) >= -1e-6).all()


def test_beam_bucket_remap_returns_original_ids():
    """Returned ids are ORIGINAL dataset ids whose recomputed distances
    equal the reported ones — the bucket-major relayout never leaks layout
    rows."""
    X, _ = _data(90, d=6, seed=13)
    _, flat = _flat(X, leaf_size=8, seed=6)
    rng = np.random.default_rng(14)
    Q = rng.normal(size=(7, X.shape[1])).astype(np.float32)
    Zf = jnp.asarray(X)[flat.perm]
    ki, kd, _ = vptree.search_beam(flat, jnp.asarray(Q), q=1.0, k=3, X=Zf)
    ki, kd = np.asarray(ki), np.asarray(kd)
    for b in range(Q.shape[0]):
        for j in range(3):
            direct = float(np.linalg.norm(Q[b] - X[ki[b, j]]))
            assert abs(direct - float(kd[b, j])) < 1e-4


def test_beam_plan_invariants():
    W, B = vptree.beam_plan(
        1024, depth=7, leaf_size=16, num_nodes=127, num_buckets=128, k=10
    )
    assert W >= 1 and 1 <= B <= 128
    # no budget -> full coverage
    Wf, Bf = vptree.beam_plan(
        None, depth=7, leaf_size=16, num_nodes=127, num_buckets=128, k=10
    )
    assert Bf == 128
    # tiny budget still plans enough bucket rows to fill k
    _, Bt = vptree.beam_plan(
        8, depth=7, leaf_size=4, num_nodes=127, num_buckets=128, k=10
    )
    assert Bt * 4 >= 10


# ---------------------------------------------------------------------------
# engine routing
# ---------------------------------------------------------------------------

ENG_CFG = {
    "q": math.inf, "proj_sample": 96, "knn_k": 8, "num_hops": 3,
    "embed_dim": 8, "hidden": (32,), "train_steps": 40, "batch_pairs": 128,
    "rerank": 16, "seed": 0,
}


@pytest.fixture(scope="module")
def engine():
    rng = np.random.default_rng(20)
    X = rng.normal(size=(192, 8)).astype(np.float32)
    Q = rng.normal(size=(96, 8)).astype(np.float32)
    return index_lib.build("infinity", X, dict(ENG_CFG)), X, Q


def test_engine_beam_mode_contract(engine):
    eng, X, Q = engine
    res = eng.search(Q, k=5, mode="beam")
    idx, dist = np.asarray(res.idx), np.asarray(res.dist)
    assert idx.shape == (96, 5)
    assert ((idx >= -1) & (idx < X.shape[0])).all()
    fin = np.where(np.isfinite(dist), dist, np.inf)
    assert (np.diff(fin, axis=1) >= -1e-6).all()
    assert (np.asarray(res.comparisons) > 0).all()


def test_engine_auto_routes_by_batch_size(engine):
    """auto == beam for large batches, best_first for small ones."""
    eng, X, Q = engine
    from repro.core.search import AUTO_BEAM_MIN_BATCH
    big = Q[:AUTO_BEAM_MIN_BATCH]
    assert (np.asarray(eng.search(big, k=3, mode="auto").idx)
            == np.asarray(eng.search(big, k=3, mode="beam").idx)).all()
    small = Q[:4]
    assert (np.asarray(eng.search(small, k=3, mode="auto").idx)
            == np.asarray(eng.search(small, k=3, mode="best_first").idx)).all()


def test_engine_beam_filtered_and_quant(engine):
    eng, X, Q = engine
    valid = np.zeros(X.shape[0], bool)
    valid[: X.shape[0] // 3] = True
    res = eng.search(Q[:8], k=4, mode="beam", filter=jnp.asarray(valid))
    idx = np.asarray(res.idx)
    assert valid[idx[idx >= 0]].all()
    # quantized engine keeps the contract on the beam path
    engq = index_lib.build("infinity", X, dict(ENG_CFG) | {"quant": True})
    resq = engq.search(Q[:8], k=4, mode="beam")
    assert np.asarray(resq.idx).shape == (8, 4)


def test_engine_beam_width_knobs_reach_plan(engine):
    eng, X, Q = engine
    lo = eng.search(Q[:8], k=3, mode="beam", beam_width=2, bucket_cap=2)
    hi = eng.search(Q[:8], k=3, mode="beam")
    assert float(np.asarray(lo.comparisons).mean()) < \
        float(np.asarray(hi.comparisons).mean())


# ---------------------------------------------------------------------------
# live + sharded round-trips
# ---------------------------------------------------------------------------

def test_live_roundtrip_through_beam():
    rng = np.random.default_rng(30)
    X = rng.normal(size=(160, 8)).astype(np.float32)
    Xnew = rng.normal(size=(20, 8)).astype(np.float32)
    Q = rng.normal(size=(6, 8)).astype(np.float32)
    live = index_lib.build("live", X, {
        "engine": "infinity",
        "engine_cfg": dict(ENG_CFG) | {"mode": "beam"},
        "delta_cap": 64,
    })
    ids = live.upsert(Xnew)
    res = live.search(Q, k=5)
    idx = np.asarray(res.idx)
    assert idx.shape == (6, 5)
    assert ((idx >= -1) & (idx < live._gen.n_frozen + live.delta_cap)).all()
    live.delete(ids[:10])
    res2 = live.search(Q, k=5)
    dead = set(int(i) for i in ids[:10])
    assert not (set(np.asarray(res2.idx).ravel().tolist()) & dead)


def test_sharded_roundtrip_through_beam_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import math
        import numpy as np
        from repro.core import index as index_lib
        rng = np.random.default_rng(40)
        X = rng.normal(size=(256, 8)).astype(np.float32)
        Q = rng.normal(size=(6, 8)).astype(np.float32)
        cfg = {"q": math.inf, "proj_sample": 64, "knn_k": 6, "num_hops": 3,
               "embed_dim": 8, "hidden": (24,), "train_steps": 30,
               "batch_pairs": 64, "rerank": 8, "mode": "beam"}
        sh = index_lib.build("sharded", X, {
            "engine": "infinity", "shards": 2, "engine_cfg": cfg})
        res = sh.search(Q, k=4, budget=200)
        idx = np.asarray(res.idx); dist = np.asarray(res.dist)
        assert idx.shape == (6, 4), idx.shape
        assert ((idx >= -1) & (idx < 256)).all()
        fin = np.where(np.isfinite(dist), dist, np.inf)
        assert (np.diff(fin, axis=1) >= -1e-6).all()
        print("OK")
    """)], capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# tier-1 recall guard (CI: the headline must not silently erode)
# ---------------------------------------------------------------------------

def test_beam_recall_guard_small_bench_config():
    """Small bench config: beam infinity search must keep recall@10 >= 0.9.
    This is the acceptance headline — a recall regression here fails CI
    instead of silently eroding BENCH_infinity."""
    from benchmarks.common import recall_at_k
    from repro.data import synthetic

    n, nq, k = 1024, 128, 10
    pool = synthetic.make("manifold", n + nq, seed=0)
    corpus, queries = np.asarray(pool[:n]), np.asarray(pool[n:])
    gt = index_lib.build("brute", corpus, {}).search(queries, k=k)
    eng = index_lib.build("infinity", corpus, {
        "q": math.inf, "proj_sample": 512, "train_steps": 300,
        "rerank": 256, "budget": 1024, "seed": 0,
    })
    res = eng.search(queries, k=k, mode="beam")
    rec = recall_at_k(np.asarray(res.idx), np.asarray(gt.idx), k)
    assert rec >= 0.9, f"beam recall@10 regressed: {rec:.3f}"
