"""core/backoff edge cases — the arithmetic the overload runtime leans on
(DESIGN.md §18): Deadline monotonicity and expiry, backoff_s caps,
degraded_budget's floor, RunCounter trip/reset, and the CircuitBreaker
state machine driven by an injected clock (no sleeping)."""
import time

import pytest

from repro.core import backoff as backoff_lib


# ---------------------------------------------------------------- Deadline

def test_deadline_none_never_expires():
    dl = backoff_lib.Deadline(None)
    assert dl.remaining_ms() == float("inf")
    assert dl.fraction_left() == 1.0
    assert not dl.expired()


def test_deadline_monotone_decrease():
    dl = backoff_lib.Deadline(10_000.0)
    a = dl.remaining_ms()
    time.sleep(0.002)
    b = dl.remaining_ms()
    assert b < a  # the monotonic clock only moves one way
    fa, fb = dl.fraction_left(), dl.fraction_left()
    assert 0.0 <= fb <= fa <= 1.0


def test_deadline_at_and_after_expiry():
    dl = backoff_lib.Deadline(0.5)  # half a millisecond
    time.sleep(0.005)
    assert dl.expired()
    assert dl.remaining_ms() < 0.0  # remaining goes negative, not clamped
    assert dl.fraction_left() == 0.0  # ...but the fraction clamps at 0


def test_deadline_zero_and_negative_ms():
    for ms in (0.0, -5.0):
        dl = backoff_lib.Deadline(ms)
        assert dl.expired()
        assert dl.fraction_left() == 0.0


def test_deadline_elapsed_nonnegative():
    dl = backoff_lib.Deadline(100.0)
    assert dl.elapsed_ms() >= 0.0


# ---------------------------------------------------------------- backoff_s

def test_backoff_doubles_then_caps():
    vals = [backoff_lib.backoff_s(a, base_s=0.01, cap_s=0.05, factor=2.0)
            for a in range(6)]
    assert vals[0] == pytest.approx(0.01)
    assert vals[1] == pytest.approx(0.02)
    assert vals[2] == pytest.approx(0.04)
    assert vals[3] == vals[4] == vals[5] == pytest.approx(0.05)  # capped
    assert all(v <= 0.05 for v in vals)


def test_backoff_negative_attempt_clamps_to_base():
    assert backoff_lib.backoff_s(-3, base_s=0.01, cap_s=1.0) == pytest.approx(0.01)


def test_backoff_huge_attempt_stays_capped():
    assert backoff_lib.backoff_s(10_000, base_s=0.01, cap_s=0.1) == 0.1


# ---------------------------------------------------------- degraded_budget

def test_degraded_budget_none_passthrough():
    assert backoff_lib.degraded_budget(None, 0.01) is None


def test_degraded_budget_full_above_half():
    assert backoff_lib.degraded_budget(256, 1.0) == 256
    assert backoff_lib.degraded_budget(256, 0.5) == 256


def test_degraded_budget_pow2_ladder():
    assert backoff_lib.degraded_budget(256, 0.49) == 128
    assert backoff_lib.degraded_budget(256, 0.25) == 128
    assert backoff_lib.degraded_budget(256, 0.24) == 64


def test_degraded_budget_floor_at_near_zero():
    # a nearly expired request still runs a minimal real search
    assert backoff_lib.degraded_budget(256, 1e-9, floor=8) == 8
    assert backoff_lib.degraded_budget(256, 0.0, floor=8) == 8
    assert backoff_lib.degraded_budget(256, 1e-9, floor=32) == 32


def test_degraded_budget_below_floor_budget():
    # a base budget under the floor is lifted to it, never shrunk further
    assert backoff_lib.degraded_budget(4, 0.01, floor=8) == 8


# --------------------------------------------------------------- RunCounter

def test_runcounter_trips_at_threshold_and_resets():
    rc = backoff_lib.RunCounter(3)
    assert not rc.observe(True)
    assert not rc.observe(True)
    assert rc.observe(True)  # third consecutive: trip
    assert rc.run == 0  # run resets on trip
    assert not rc.observe(True)  # counting afresh


def test_runcounter_reset_on_false():
    rc = backoff_lib.RunCounter(2)
    assert not rc.observe(True)
    assert not rc.observe(False)  # resets the run
    assert not rc.observe(True)
    assert rc.observe(True)


def test_runcounter_repeated_trips():
    rc = backoff_lib.RunCounter(2)
    trips = sum(rc.observe(True) for _ in range(6))
    assert trips == 3  # every 2 consecutive events


# ----------------------------------------------------------- CircuitBreaker

class Clock:
    """Injectable monotonic clock — tests drive cooldowns without sleep."""

    def __init__(self):
        self.t = 0.0

    def advance(self, s: float):
        self.t += s

    def __call__(self):
        return self.t


def _tripped_breaker(trip=3, cooldown=1.0, **kw):
    clk = Clock()
    br = backoff_lib.CircuitBreaker(trip=trip, cooldown_s=cooldown,
                                    clock=clk, **kw)
    for i in range(trip - 1):
        assert not br.record(False)
    assert br.record(False)  # the tripping failure reports True
    return br, clk


def test_breaker_trips_on_consecutive_failures():
    br, _ = _tripped_breaker(trip=3)
    assert br.state == br.OPEN
    assert br.trips == 1
    assert not br.allow()  # fast-fail while open
    assert br.state_code() == 2


def test_breaker_success_resets_run():
    clk = Clock()
    br = backoff_lib.CircuitBreaker(trip=3, clock=clk)
    br.record(False)
    br.record(False)
    br.record(True)  # breaks the run
    assert not br.record(False)
    assert not br.record(False)
    assert br.state == br.CLOSED


def test_breaker_halfopen_single_probe_then_close():
    br, clk = _tripped_breaker(trip=2, cooldown=1.0)
    assert not br.allow()
    clk.advance(1.01)  # cooldown over
    assert br.allow()  # exactly ONE half-open probe...
    assert br.state == br.HALF_OPEN
    assert not br.allow()  # ...others are refused meanwhile
    assert not br.record(True)  # probe succeeded
    assert br.state == br.CLOSED
    assert br.allow()


def test_breaker_probe_failure_doubles_cooldown():
    br, clk = _tripped_breaker(trip=2, cooldown=1.0, cooldown_cap_s=3.0)
    clk.advance(1.01)
    assert br.allow()
    assert br.record(False)  # probe failed: re-open, cooldown doubled
    assert br.state == br.OPEN
    assert br.trips == 2
    clk.advance(1.5)
    assert not br.allow()  # 1.5 < 2.0 doubled cooldown
    clk.advance(0.6)
    assert br.allow()  # 2.1 > 2.0
    assert br.record(False)  # fails again: cooldown would be 4, capped at 3
    clk.advance(2.9)
    assert not br.allow()
    clk.advance(0.2)
    assert br.allow()
    br.record(True)
    assert br.state == br.CLOSED


def test_breaker_retry_after_counts_down():
    br, clk = _tripped_breaker(trip=2, cooldown=1.0)
    assert br.retry_after_s() == pytest.approx(1.0)
    clk.advance(0.4)
    assert br.retry_after_s() == pytest.approx(0.6)
    clk.advance(1.0)
    assert br.retry_after_s() == 0.0  # cooldown elapsed
    br.record(True)
    assert br.retry_after_s() == 0.0  # closed: no hint


def test_breaker_late_failures_while_open_are_noop():
    br, _ = _tripped_breaker(trip=2)
    assert not br.record(False)  # in-flight stragglers failing: no new trip
    assert br.trips == 1


def test_breaker_close_resets_cooldown_exponent():
    br, clk = _tripped_breaker(trip=2, cooldown=1.0)
    clk.advance(1.01)
    br.allow()
    br.record(False)  # round 1: cooldown 2.0
    clk.advance(2.01)
    br.allow()
    br.record(True)  # closed: exponent resets
    br.record(False)
    br.record(False)  # trips again
    assert br.retry_after_s() == pytest.approx(1.0)  # base cooldown again


# ---------------------------------------------------------- median_deadline

def test_median_deadline_needs_samples():
    assert backoff_lib.median_deadline([1.0] * 4, factor=3.0) is None
    assert backoff_lib.median_deadline([2.0] * 5, factor=3.0) == pytest.approx(6.0)
