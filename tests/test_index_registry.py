"""Unified index protocol: registry reachability, SearchResult semantics,
and ShardedIndex multi-device parity (subprocess — tests see 1 device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, D = 240, 16


def _run_distributed(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, D)).astype(np.float32)
    Q = rng.normal(size=(12, D)).astype(np.float32)
    return X, Q


# engine key -> registry cfg small enough for CI (infinity trains a tiny Phi)
ENGINE_CFGS = {
    "brute": {},
    "ivf_flat": {"num_clusters": 8, "nprobe": 4},
    "ivf_pq": {"num_clusters": 8, "M": 4, "ksub": 16, "nprobe": 4, "rerank": 16},
    "nsw": {"degree": 8, "ef": 24, "max_steps": 64},
    "infinity": {"q": 8.0, "proj_sample": 120, "knn_k": 8, "num_hops": 4,
                 "embed_dim": 8, "hidden": (32,), "train_steps": 60,
                 "batch_pairs": 128, "rerank": 16},
}


def test_registry_exposes_all_builtin_engines():
    from repro.core import index as index_lib

    assert set(index_lib.available()) >= {
        "brute", "ivf_flat", "ivf_pq", "nsw", "infinity", "sharded"
    }
    with pytest.raises(KeyError):
        index_lib.get_index("no_such_engine")


@pytest.mark.parametrize("name", list(ENGINE_CFGS))
def test_uniform_contract(name, data):
    """Every engine: build(X, cfg) -> search(Q, k, budget) -> SearchResult
    with identical field semantics, plus memory accounting."""
    from repro.core import index as index_lib

    X, Q = data
    engine = index_lib.build(name, X, ENGINE_CFGS[name])
    res = engine.search(Q, k=5)
    assert isinstance(res, index_lib.SearchResult)
    idx, dist, comps = res  # the triple unpacks (old call sites)
    idx, dist, comps = np.asarray(idx), np.asarray(dist), np.asarray(comps)
    assert idx.shape == (Q.shape[0], 5) and idx.dtype == np.int32
    assert dist.shape == (Q.shape[0], 5)
    assert comps.shape == (Q.shape[0],) and comps.dtype == np.int32
    assert ((idx >= -1) & (idx < N)).all()
    finite = np.where(np.isfinite(dist), dist, np.inf)
    assert (np.diff(finite, axis=1) >= -1e-6).all(), "dist must ascend"
    assert (comps >= 1).all()
    assert engine.memory_bytes() >= X.nbytes


def test_registry_brute_matches_oracle(data):
    from repro.core import index as index_lib

    X, Q = data
    res = index_lib.build("brute", X, {}).search(Q, k=3)
    ref = np.argsort(
        np.linalg.norm(Q[:, None] - X[None], axis=-1), axis=1
    )[:, :3]
    assert (np.asarray(res.idx) == ref).all()
    assert (np.asarray(res.comparisons) == N).all()


def test_cfg_leftover_keys_become_search_defaults(data):
    """nprobe in the cfg mapping must drive subsequent searches."""
    from repro.core import baselines, index as index_lib

    X, Q = data
    wide = index_lib.build("ivf_flat", X, {"num_clusters": 8, "nprobe": 8})
    narrow = index_lib.build("ivf_flat", X, {"num_clusters": 8, "nprobe": 1})
    cw = np.asarray(wide.search(Q, k=1).comparisons).mean()
    cn = np.asarray(narrow.search(Q, k=1).comparisons).mean()
    assert cw > cn
    with pytest.raises(TypeError):
        index_lib.build("ivf_flat", X, {"num_clusters": 8, "bogus_key": 1})
    # unknown engine cfg keys also rejected on the infinity path
    with pytest.raises(TypeError):
        index_lib.build("infinity", X, {"bogus_key": 1})
    assert isinstance(wide, baselines.IVFFlat)  # registry returns real classes


def test_budget_maps_onto_engine_knobs(data):
    """The uniform budget bounds comparisons on every budgeted engine."""
    from repro.core import index as index_lib

    X, Q = data
    ivf = index_lib.build("ivf_flat", X, {"num_clusters": 8})
    # budget -> nprobe: tighter budget, fewer scored candidates
    c_small = np.asarray(ivf.search(Q, k=1, budget=N // 8).comparisons).mean()
    c_large = np.asarray(ivf.search(Q, k=1, budget=N).comparisons).mean()
    assert c_small < c_large
    inf = index_lib.build("infinity", X, ENGINE_CFGS["infinity"] | {"rerank": 0})
    comps = np.asarray(inf.search(Q, k=1, budget=15).comparisons)
    assert (comps <= 15).all()


def test_old_entry_points_still_work(data):
    """Pre-registry signatures are thin wrappers over the same contract."""
    from repro.core import baselines

    X, Q = data
    idx, dist, comps = baselines.brute_force(X, Q, k=2)
    ivf = baselines.IVFFlat.build(X, num_clusters=8)
    i2, d2, c2 = ivf.search(Q, k=2, nprobe=8)
    nsw = baselines.NSWGraph.build(X, degree=8)
    i3, d3, c3 = nsw.search(Q, k=2, ef=24, max_steps=64)
    for i in (idx, i2, i3):
        assert np.asarray(i).shape == (Q.shape[0], 2)


# ---------------------------------------------------------------------------
# sharded engine (subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------

def test_sharded_identical_to_single_device_subprocess():
    """Acceptance: a 2-device sharded run returns exactly the (idx, dist)
    of the single-device engine — for the exhaustive engines where the
    computation is equivalence-preserving (brute, and IVF-Flat probing
    every list)."""
    out = _run_distributed("""
        import numpy as np, jax
        from repro.core import index as index_lib
        assert len(jax.devices()) >= 2, jax.devices()
        rng = np.random.default_rng(0)
        X = rng.normal(size=(256, 16)).astype(np.float32)
        Q = rng.normal(size=(16, 16)).astype(np.float32)
        single = index_lib.build("brute", X, {}).search(Q, k=7)
        for shards in (2, 4):
            sh = index_lib.build("sharded", X, {"engine": "brute", "shards": shards})
            res = sh.search(Q, k=7)
            np.testing.assert_array_equal(np.asarray(res.idx), np.asarray(single.idx))
            np.testing.assert_allclose(np.asarray(res.dist), np.asarray(single.dist), rtol=1e-6)
            assert (np.asarray(res.comparisons) == 256).all()  # work is summed
        # ivf_flat probing all lists is exhaustive -> also exact
        sh = index_lib.build("sharded", X, {
            "engine": "ivf_flat", "shards": 2,
            "engine_cfg": {"num_clusters": 8, "nprobe": 8}})
        res = sh.search(Q, k=7)
        np.testing.assert_array_equal(np.asarray(res.idx), np.asarray(single.idx))
        np.testing.assert_allclose(np.asarray(res.dist), np.asarray(single.dist), rtol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_contract_all_engines_subprocess():
    """Every engine runs under ShardedIndex and keeps the global contract:
    ids cover all shards' offset ranges, dists ascend, comps sum."""
    out = _run_distributed("""
        import numpy as np, math
        from repro.core import index as index_lib
        rng = np.random.default_rng(1)
        X = rng.normal(size=(128, 8)).astype(np.float32)
        Q = rng.normal(size=(6, 8)).astype(np.float32)
        cfgs = {
            "brute": {},
            "ivf_flat": {"num_clusters": 4, "nprobe": 4},
            "ivf_pq": {"num_clusters": 4, "M": 4, "ksub": 8, "nprobe": 4, "rerank": 8},
            "nsw": {"degree": 6, "ef": 16, "max_steps": 48},
            "infinity": {"q": math.inf, "proj_sample": 48, "knn_k": 6,
                         "num_hops": 3, "embed_dim": 8, "hidden": (24,),
                         "train_steps": 30, "batch_pairs": 64, "rerank": 8},
        }
        for name, cfg in cfgs.items():
            sh = index_lib.build("sharded", X, {
                "engine": name, "shards": 2, "engine_cfg": cfg})
            res = sh.search(Q, k=4)
            idx = np.asarray(res.idx); dist = np.asarray(res.dist)
            assert idx.shape == (6, 4), (name, idx.shape)
            assert ((idx >= -1) & (idx < 128)).all(), name
            fin = np.where(np.isfinite(dist), dist, np.inf)
            assert (np.diff(fin, axis=1) >= -1e-6).all(), name
            assert sh.memory_bytes() > 0
        # the per-query budget is split across shards: summed comparisons
        # respect the same bound as a single-device engine
        sh = index_lib.build("sharded", X, {
            "engine": "infinity", "shards": 2,
            "engine_cfg": cfgs["infinity"] | {"rerank": 0}})
        comps = np.asarray(sh.search(Q, k=1, budget=20).comparisons)
        assert (comps <= 20).all(), comps
        # uneven shard split is rejected loudly, not silently truncated
        try:
            index_lib.build("sharded", X[:127], {"engine": "brute", "shards": 2})
            raise AssertionError("expected ValueError")
        except ValueError:
            pass
        print("OK")
    """)
    assert "OK" in out


def test_sharded_budget_remainder_is_tight_subprocess():
    """budget % shards must not be silently discarded: the remainder goes to
    the first shards, so an engine that exhausts its budget (infinity
    best-first at weak pruning, q=1) reports summed comparisons EQUAL to the
    requested bound — not floor(budget/S)*S."""
    out = _run_distributed("""
        import numpy as np
        from repro.core import index as index_lib
        rng = np.random.default_rng(0)
        X = rng.normal(size=(240, 16)).astype(np.float32)
        Q = rng.normal(size=(6, 16)).astype(np.float32)
        cfg = {"q": 1.0, "proj_sample": 120, "knn_k": 8, "num_hops": 4,
               "embed_dim": 8, "hidden": (32,), "train_steps": 60,
               "batch_pairs": 128, "rerank": 0}
        sh = index_lib.build("sharded", X, {
            "engine": "infinity", "shards": 4, "engine_cfg": cfg})
        for budget in (21, 33, 50):  # all leave a nonzero remainder mod 4
            comps = np.asarray(sh.search(Q, k=1, budget=budget).comparisons)
            assert (comps == budget).all(), (budget, comps)
        # the traced budget is an operand, not a compile key: every budget
        # value above shared ONE compiled program
        assert len(sh._jitted) == 1, sh._jitted.keys()
        # degenerate floor: budget below the shard count still gives every
        # shard one comparison (summed = S, the documented lower bound)
        comps = np.asarray(sh.search(Q, k=1, budget=2).comparisons)
        assert (comps == 4).all(), comps
        print("OK")
    """)
    assert "OK" in out
