"""Distribution layer: sharding policies (no devices needed) + multi-device
correctness via subprocess (forced host device count stays OUT of this
process — tests must see 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import roofline

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_distributed(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# policies (pure functions of config + mesh shape)
# ---------------------------------------------------------------------------

def _fake_mesh(shape, axes):
    class FakeMesh:
        def __init__(self):
            self.shape = dict(zip(axes, shape))
    return FakeMesh()


def test_lm_policy_head_divisibility():
    from repro.dist.sharding import lm_policy

    mesh = _fake_mesh((16, 16), ("data", "model"))
    # 64 heads -> TP over heads; 9 heads -> sequence-parallel attention
    qwen = configs.get("qwen3-moe-235b-a22b")
    ctx = lm_policy(qwen, mesh, batch=256)
    assert ctx.w_rules["q_heads"] == "model"
    assert ctx.a_rules["attn_seq"] is None
    smol = configs.get("smollm-135m")
    ctx = lm_policy(smol, mesh, batch=256)
    assert ctx.w_rules["q_heads"] is None
    assert ctx.a_rules["attn_seq"] == "model"


def test_lm_policy_fsdp_threshold_and_decode():
    from repro.dist.sharding import lm_policy

    mesh = _fake_mesh((16, 16), ("data", "model"))
    small = lm_policy(configs.get("smollm-135m"), mesh, batch=256)
    assert small.w_rules["embed"] is None  # 135M: no FSDP
    big = lm_policy(configs.get("deepseek-coder-33b"), mesh, batch=256)
    assert big.w_rules["embed"] == "data"  # 33B: FSDP
    dec = lm_policy(configs.get("deepseek-coder-33b"), mesh, kind="decode", batch=128)
    assert dec.a_rules["kv_seq"] == "model"
    dec1 = lm_policy(configs.get("deepseek-coder-33b"), mesh, kind="decode", batch=1)
    assert dec1.a_rules["kv_seq"] == ("data", "model")
    assert dec1.a_rules["batch"] is None  # B=1 unshardable


def test_moe_ep_modes():
    from repro.models.moe import ep_mode

    mesh = _fake_mesh((16, 16), ("data", "model"))
    assert ep_mode(configs.get("deepseek-v3-671b"), mesh) == "2d"  # 256 % 256
    assert ep_mode(configs.get("qwen3-moe-235b-a22b"), mesh) == "fslice"  # 128 experts, 1536 dff


def test_spec_trees_have_no_duplicate_axes():
    """Every weight PartitionSpec must use each mesh axis at most once."""
    from repro.dist.sharding import lm_policy
    from repro.models import params as plib
    from repro.models.transformer import lm_decls

    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    for arch in ["smollm-135m", "deepseek-coder-33b", "gemma-2b",
                 "qwen3-moe-235b-a22b", "deepseek-v3-671b"]:
        cfg = configs.get(arch)
        ctx = lm_policy(cfg, mesh, batch=256)
        specs = ctx.shard_w(lm_decls(cfg))
        for spec in jax.tree_util.tree_leaves(specs, is_leaf=lambda s: isinstance(s, P)):
            flat = []
            for part in spec:
                if part is None:
                    continue
                flat.extend(part if isinstance(part, tuple) else [part])
            assert len(flat) == len(set(flat)), (arch, spec)


import jax  # noqa: E402  (used above in tree_leaves)


# ---------------------------------------------------------------------------
# multi-device correctness (subprocess with 8 host devices)
# ---------------------------------------------------------------------------

def test_moe_ep_matches_dense_subprocess():
    out = _run_distributed("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses as dc
        from repro.launch.mesh import make_test_mesh
        from repro import configs
        from repro.models import moe as moe_lib
        mesh = make_test_mesh((2,4), ("data","model"))
        cfg = dc.replace(configs.get_reduced("qwen3-moe-235b-a22b"),
                         num_experts=8, num_experts_per_tok=2, capacity_factor=8.0)
        E,d,f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
        k = jax.random.PRNGKey
        p = {"wg": jax.random.normal(k(0),(E,d,f))*0.05,
             "wu": jax.random.normal(k(1),(E,d,f))*0.05,
             "wd": jax.random.normal(k(2),(E,f,d))*0.05}
        x = jax.random.normal(k(3),(4,16,d),jnp.float32)
        probs = jax.nn.softmax(jax.random.normal(k(4),(4,16,E)),axis=-1)
        dense = moe_lib.moe_ffn_dense(x, probs, p, cfg)
        with mesh:
            ep = jax.jit(lambda *a: moe_lib.moe_ffn_ep(*a, cfg, mesh=mesh, batch_axes=("data",)))(x, probs, p)
        err = float(jnp.max(jnp.abs(dense-ep)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_sharded_embedding_lookup_subprocess():
    out = _run_distributed("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.dist.embedlookup import embedding_lookup
        from repro.dist.sharding import DistCtx
        mesh = make_test_mesh((2,4), ("data","model"))
        ctx = DistCtx(mesh=mesh, w_rules={}, a_rules={"batch": "data"})
        V, D = 64, 8
        table = jnp.asarray(np.random.default_rng(0).normal(size=(V,D)).astype(np.float32))
        ids = jnp.asarray(np.random.default_rng(1).integers(0, V, size=(16, 5)).astype(np.int32))
        with mesh:
            out = jax.jit(lambda t, i: embedding_lookup(t, i, ctx))(table, ids)
        ref = np.asarray(table)[np.asarray(ids)]
        assert np.allclose(np.asarray(out), ref, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_lm_train_step_shards_on_small_mesh_subprocess():
    """End-to-end sharded train step on a (2,4) mesh with a reduced config
    whose dims divide: proves the policy machinery, not just the dry-run."""
    out = _run_distributed("""
        import dataclasses as dc
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro import configs
        from repro.dist.sharding import lm_policy
        from repro.models import params as plib, transformer
        from repro.train import optimizer as opt_lib, train_step as steps
        mesh = make_test_mesh((2,4), ("data","model"))
        cfg = dc.replace(configs.get_reduced("qwen3-moe-235b-a22b"),
                         num_heads=4, num_kv_heads=4, d_model=64, moe_d_ff=64,
                         capacity_factor=8.0)  # no drops: EP == dense semantics
        dctx = lm_policy(cfg, mesh, batch=4, fsdp=True)
        decls = transformer.lm_decls(cfg)
        params = plib.init_params(jax.random.PRNGKey(0), decls)
        pspecs = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
            dctx.shard_w(decls), is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, pspecs)
        opt = opt_lib.adamw(1e-3)
        state = opt.init(params)
        # microbatches=1 so the reported loss is the full-batch loss (the
        # microbatch path reports the LAST microbatch's metrics)
        step = steps.make_train_step(cfg, "lm", opt, dctx, microbatches=1)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
        with mesh:
            p2, s2, m = jax.jit(step)(params, state, {"tokens": toks})
        loss = float(m["loss"])
        # microbatched variant still runs and is finite
        step2 = steps.make_train_step(cfg, "lm", opt, dctx, microbatches=2)
        with mesh:
            _, _, m2 = jax.jit(step2)(params, state, {"tokens": toks})
        assert np.isfinite(float(m2["loss"]))
        assert np.isfinite(loss), loss
        # unsharded single-device reference
        p_host = jax.device_get(params)
        loss_ref, _ = transformer.lm_loss(p_host, {"tokens": toks}, cfg)
        assert abs(loss - float(loss_ref)) < 0.05, (loss, float(loss_ref))
        print("OK", loss, float(loss_ref))
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# roofline parser
# ---------------------------------------------------------------------------

def test_collective_parser_on_synthetic_hlo():
    hlo = """
HloModule m

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ag = f32[64]{0} all-gather(%x), replica_groups={}
  ROOT %t = (s32[], f32[8]) tuple(%i, %y)
}

%cond (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %ar = f32[32,2]{1,0} all-reduce(%a), to_apply=%add
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    stats = roofline.parse_collectives(hlo, default_trip=1)
    # all-reduce 32*2*4 = 256 bytes; all-gather 64*4 * 12 trips = 3072
    assert stats.bytes_by_kind["all-reduce"] == 256
    assert stats.bytes_by_kind["all-gather"] == 64 * 4 * 12
    assert stats.loop_trip_counts == {"body": 12}


def test_hlo_stats_loop_scaling():
    import jax

    def g(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h.sum()

    import jax.numpy as jnp

    args = [jax.ShapeDtypeStruct((16, 32), jnp.float32),
            jax.ShapeDtypeStruct((32, 32), jnp.float32)]
    compiled = jax.jit(g).lower(*args).compile()
    st = roofline.hlo_stats(compiled.as_text(), default_trip=7)
    expected = 2 * 16 * 32 * 32 * 7
    assert abs(st.flops - expected) / expected < 0.05
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    assert st.flops > 5 * float(cost["flops"])  # xla doesn't scale loops


def test_roofline_terms_dominance():
    cost = {"flops": 1e15, "bytes accessed": 1e9}
    coll = roofline.CollectiveStats({}, 0, 0, {})
    t = roofline.roofline_terms(cost, coll, chips=256, model_flops=2.56e17)
    assert t["dominant"] == "compute"
    assert 0.9 < t["useful_flops_ratio"] < 1.1
    assert t["roofline_fraction"] == pytest.approx(1.0, rel=0.05)
