"""Substrate: optimizers, checkpoint/restart, fault supervisor, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.data.tokens import TokenStream, recsys_batch
from repro.dist import compression
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib
from repro.train.fault import ElasticPlan, Heartbeat, Supervisor


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_optimizer_reduces_quadratic(name):
    opt = opt_lib.OPTIMIZERS[name](1e-1 if name != "adafactor" else 5e-1)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 6)).astype(np.float32))
    params = {"w": jnp.zeros((4, 6)), "b": jnp.zeros((6,))}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss_fn(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt = opt_lib.adafactor()
    params = {"w": jnp.zeros((32, 64)), "b": jnp.zeros((64,))}
    st = opt.init(params)
    assert st.stats["w"]["vr"].shape == (32,)
    assert st.stats["w"]["vc"].shape == (64,)
    assert st.stats["b"]["v"].shape == (64,)


def test_grad_clipping_and_schedule():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    sched = opt_lib.cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(jnp.int32(5))) == pytest.approx(0.5, abs=1e-5)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.0, abs=1e-5)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"note": "x"})
    restored, step = ckpt.restore(str(tmp_path), t)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_latest_pointer_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.latest_step(str(tmp_path)) == 5
    ckpt.garbage_collect(str(tmp_path), keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


def test_checkpoint_integrity_detection(tmp_path):
    t = _tree()
    path = ckpt.save(str(tmp_path), 1, t)
    # corrupt one leaf
    leaf = os.path.join(path, "leaf_00000.npy")
    data = open(leaf, "rb").read()
    open(leaf, "wb").write(data[:-4] + b"\x00\x00\x00\x01")
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), t)


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (10, 20):
        saver.save(s, t)
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_restore_shape_mismatch_rejected(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    bad = {"w": jnp.zeros((2, 2)), "nested": {"b": jnp.ones((5,), jnp.bfloat16)}}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), bad)


# ---------------------------------------------------------------------------
# fault supervisor
# ---------------------------------------------------------------------------

def test_supervisor_straggler_detection():
    sup = Supervisor()
    for _ in range(10):
        assert sup.observe_step_time(1.0) == "ok"
    assert sup.observe_step_time(10.0) == "straggler"
    assert sup.observe_step_time(10.0) == "straggler"
    assert sup.observe_step_time(10.0) == "restart"


def test_supervisor_nan_guard():
    sup = Supervisor()
    assert sup.observe_loss(1.0) == "ok"
    assert sup.observe_loss(float("nan")) == "skip"
    assert sup.observe_loss(float("nan")) == "skip"
    assert sup.observe_loss(float("nan")) == "restore"
    assert sup.observe_loss(2.0) == "ok"


def test_elastic_plan():
    plan = ElasticPlan()
    assert plan.current_shape() == (2, 16, 16)
    assert plan.shrink() == (16, 16)
    with pytest.raises(RuntimeError):
        plan.shrink()


def test_heartbeat():
    hb = Heartbeat(timeout_s=0.0)
    hb.ping("loader")
    import time

    time.sleep(0.01)
    assert hb.dead() == ["loader"]


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(333,)).astype(np.float32))}
    out = compression.fake_int8_roundtrip(g)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127
    assert err.max() <= scale * 1.01


def test_error_feedback_unbiased_over_steps():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    resid = compression.ErrorFeedback.init(g)
    total_sent = np.zeros(256)
    for _ in range(50):
        sent, resid = compression.ErrorFeedback.apply(g, resid)
        total_sent += np.asarray(sent["w"])
    # accumulated transmitted gradient converges to 50*g (residual bounded)
    np.testing.assert_allclose(total_sent / 50, np.asarray(g["w"]), atol=0.02)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_token_stream_determinism_and_host_sharding():
    s1 = TokenStream(1000, 32, 8, seed=3, host_id=0, num_hosts=2)
    s2 = TokenStream(1000, 32, 8, seed=3, host_id=1, num_hosts=2)
    b1a, b1b = s1.batch(5), s1.batch(5)
    np.testing.assert_array_equal(b1a["tokens"], b1b["tokens"])  # deterministic
    assert not np.array_equal(b1a["tokens"], s2.batch(5)["tokens"])  # per-host
    assert b1a["tokens"].shape == (4, 32)
    assert b1a["tokens"].max() < 1000


def test_recsys_batch_learnable_labels():
    b = recsys_batch(0, 64, [100, 50, 20], seed=0)
    assert b["ids"].shape == (64, 3)
    assert set(np.unique(b["labels"])) <= {0.0, 1.0}


@pytest.mark.parametrize("name", list(synthetic.DATASETS))
def test_synthetic_datasets(name):
    X = synthetic.make(name, 50, seed=1)
    assert X.shape[0] == 50 and np.isfinite(X).all()
    Y = synthetic.make(name, 50, seed=1)
    np.testing.assert_array_equal(X, Y)
