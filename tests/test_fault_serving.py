"""Fault-tolerant serving (DESIGN.md §14): chaos injection, deadline-aware
degraded search, and self-healing snapshots.

Covers the shared backoff/deadline arithmetic (``core/backoff``), the
deterministic fault plan (``core/chaos``), snapshot integrity
(``core/store`` sha256 manifest + the partial-snapshot up-front check),
mid-compaction crash atomicity (``core/live``), and the ``SearchServer``
controller — including the acceptance scenario: kill 1 of 2 shards
mid-run, every request still answered within deadline and flagged
degraded, recall@10 over surviving rows >= 0.9, revive -> SERVING with
bit-identical results (subprocess: tests see 1 device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import backoff as backoff_lib
from repro.core import chaos as chaos_lib
from repro.core import index as index_lib
from repro.core import store as store_lib
from repro.launch.serve import FaultPolicy, SearchServer, ServedResult

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, D = 400, 16


def _run_distributed(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, D)).astype(np.float32)
    Q = X[:8] + 0.01
    return X, Q


# ---------------------------------------------------------------------------
# core/backoff: the shared retry/deadline arithmetic
# ---------------------------------------------------------------------------

def test_backoff_is_capped_exponential():
    s = [backoff_lib.backoff_s(a, base_s=0.01, cap_s=0.05) for a in range(6)]
    assert s[:3] == [0.01, 0.02, 0.04]
    assert all(v == 0.05 for v in s[3:])  # capped, never unbounded
    assert backoff_lib.backoff_s(-3, base_s=0.01) == 0.01  # clamped attempt


def test_deadline_none_never_expires():
    dl = backoff_lib.Deadline(None)
    assert dl.remaining_ms() == float("inf")
    assert dl.fraction_left() == 1.0
    assert not dl.expired()


def test_deadline_counts_down():
    dl = backoff_lib.Deadline(10_000.0)
    assert 0.0 < dl.fraction_left() <= 1.0
    assert not dl.expired()
    spent = backoff_lib.Deadline(0.0)
    assert spent.expired() and spent.fraction_left() == 0.0


def test_degraded_budget_pow2_ladder():
    # full budget while >= half the deadline remains
    assert backoff_lib.degraded_budget(256, 1.0) == 256
    assert backoff_lib.degraded_budget(256, 0.5) == 256
    # each further halving of the fraction halves the budget
    assert backoff_lib.degraded_budget(256, 0.49) == 128
    assert backoff_lib.degraded_budget(256, 0.25) == 128
    assert backoff_lib.degraded_budget(256, 0.24) == 64
    # floored, and None (no budget knob) passes through
    assert backoff_lib.degraded_budget(256, 0.0) == 8
    assert backoff_lib.degraded_budget(256, 0.0, floor=32) == 32
    assert backoff_lib.degraded_budget(None, 0.1) is None


def test_run_counter_trips_and_resets():
    rc = backoff_lib.RunCounter(3)
    assert [rc.observe(e) for e in (True, True, True)] == [False, False, True]
    assert rc.run == 0  # reset on trip
    assert not rc.observe(True) and rc.run == 1
    assert not rc.observe(False) and rc.run == 0  # reset on success


def test_median_deadline_needs_samples():
    assert backoff_lib.median_deadline([1.0] * 4, factor=3.0) is None
    assert backoff_lib.median_deadline([1.0] * 5, factor=3.0) == 3.0


# ---------------------------------------------------------------------------
# core/chaos: deterministic injection
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic():
    def trace(plan):
        out = []
        for _ in range(64):
            try:
                plan.on_search()
                out.append("ok")
            except chaos_lib.TransientFault:
                out.append("fault")
        return out

    rules = [{"site": "search", "kind": "error", "rate": 0.3}]
    t1 = trace(chaos_lib.FaultPlan(seed=5, rules=rules))
    t2 = trace(chaos_lib.FaultPlan(seed=5, rules=rules))
    assert t1 == t2 and "fault" in t1 and "ok" in t1
    t3 = trace(chaos_lib.FaultPlan(seed=6, rules=rules))
    assert t1 != t3  # the seed is the schedule


def test_window_rule_fires_exactly_in_window():
    plan = chaos_lib.FaultPlan(rules=[
        {"site": "search", "kind": "error", "start": 2, "stop": 4}])
    got = []
    for _ in range(6):
        try:
            plan.on_search()
            got.append("ok")
        except chaos_lib.TransientFault:
            got.append("fault")
    assert got == ["ok", "ok", "fault", "fault", "ok", "ok"]


def test_rule_validation():
    with pytest.raises(ValueError, match="unknown site"):
        chaos_lib.Rule(site="disk")
    with pytest.raises(ValueError, match="never fires"):
        chaos_lib.Rule(site="search")
    with pytest.raises(TypeError):
        chaos_lib.FaultPlan.from_cfg("rate=1")


def test_kill_and_revive_shard():
    plan = chaos_lib.FaultPlan()
    assert plan.dead_shards(4) == set()
    plan.kill_shard(2)
    assert plan.dead_shards(4) == {2}
    plan.revive_shard(2)
    assert plan.dead_shards(4) == set()


def test_latency_rule_sleeps_injectably():
    slept = []
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "search", "kind": "latency", "start": 0, "ms": 20}],
        sleep=slept.append,
    )
    plan.on_search()
    assert slept == [0.02]
    assert plan.counters["search:latency"] == 1


def test_generic_engine_gets_chaos_wrapped(data):
    X, Q = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "search", "kind": "error", "start": 1, "stop": 2}])
    eng = index_lib.build("brute", X, {"chaos": plan})
    eng.search(Q, k=3)  # callno 0: clean
    with pytest.raises(chaos_lib.TransientFault):
        eng.search(Q, k=3)  # callno 1: injected
    r = eng.search(Q, k=3)  # callno 2: clean again
    assert np.asarray(r.idx).shape == (len(Q), 3)


def test_build_fault_poisons_build(data):
    X, _ = data
    plan = chaos_lib.FaultPlan(rules=[{"site": "build", "start": 0, "stop": 1}])
    with pytest.raises(chaos_lib.BuildFault):
        index_lib.build("brute", X, {"chaos": plan})


# ---------------------------------------------------------------------------
# core/store: sha256 manifest + partial-snapshot up-front detection
# ---------------------------------------------------------------------------

def _snap(tmp_path, X, name="snap"):
    eng = index_lib.build("brute", X, {})
    path = os.path.join(str(tmp_path), name)
    store_lib.save(eng, path)
    return path


def test_verify_clean_snapshot(tmp_path, data):
    X, _ = data
    path = _snap(tmp_path, X)
    meta = store_lib.verify(path)
    assert meta["arrays"] in meta["sha256"]
    assert isinstance(store_lib.load(path), type(index_lib.build("brute", X[:4], {})))


@pytest.mark.parametrize("mode", ["bitflip", "truncate", "drop"])
def test_corruption_is_detected_up_front(tmp_path, data, mode):
    X, _ = data
    path = _snap(tmp_path, X, name=f"snap-{mode}")
    member = chaos_lib.corrupt_snapshot(path, mode=mode)
    arrays_file = os.path.basename(member)
    with pytest.raises(store_lib.SnapshotCorruption, match=arrays_file):
        store_lib.verify(path)
    with pytest.raises(store_lib.SnapshotCorruption, match=arrays_file):
        store_lib.load(path)


def test_partial_snapshot_missing_member_names_it(tmp_path, data):
    # the bugfix: meta.json committed but the arrays member never landed —
    # load must raise one clear error naming the member, not die in np.load
    X, _ = data
    path = _snap(tmp_path, X)
    arrays_file = store_lib.peek(path)["arrays"]
    os.unlink(os.path.join(path, arrays_file))
    with pytest.raises(store_lib.SnapshotCorruption, match=arrays_file) as ei:
        store_lib.load(path)
    assert "missing" in str(ei.value)


def test_partial_snapshot_zero_length_member(tmp_path, data):
    X, _ = data
    path = _snap(tmp_path, X)
    arrays_file = store_lib.peek(path)["arrays"]
    with open(os.path.join(path, arrays_file), "w"):
        pass  # truncate to zero bytes
    with pytest.raises(store_lib.SnapshotCorruption, match="zero-length"):
        store_lib.load(path)


def test_pre_manifest_snapshot_still_loads(tmp_path, data):
    # back-compat: snapshots written before the sha256 manifest (v1/v2/v3
    # metas without the key) skip the digest check but still load
    import json

    X, _ = data
    path = _snap(tmp_path, X)
    meta_path = os.path.join(path, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["sha256"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    store_lib.verify(path)
    eng = store_lib.load(path)
    r = eng.search(X[:4], k=3)
    assert np.asarray(r.idx)[0][0] == 0


def test_chaos_snapshot_rule_corrupts_committed_save(tmp_path, data):
    X, _ = data
    plan = chaos_lib.FaultPlan(rules=[{"site": "snapshot", "rate": 1.0,
                                       "mode": "bitflip"}])
    eng = index_lib.build("brute", X, {"chaos": plan})
    path = os.path.join(str(tmp_path), "snap")
    store_lib.save(eng, path)
    assert plan.counters["snapshot:bitflip"] == 1
    with pytest.raises(store_lib.SnapshotCorruption):
        store_lib.verify(path)


# ---------------------------------------------------------------------------
# core/live: mid-compaction crash atomicity (satellite test)
# ---------------------------------------------------------------------------

def test_mid_compaction_fault_leaves_old_generation_serving(data):
    X, Q = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "compact", "start": 0, "stop": 1}])  # first only
    live = index_lib.build("live", X, {"engine": "brute", "delta_cap": 64,
                                       "auto_compact": False, "chaos": plan})
    ins = np.random.default_rng(3).normal(size=(16, D)).astype(np.float32)
    ids = live.upsert(ins)
    live.delete(ids[:4])
    before = live.search(Q, k=10)
    gen_before = live.stats()["generation"]

    # the injected crash lands AFTER the full rebuild, BEFORE the publish
    with pytest.raises(chaos_lib.CompactFault):
        live.compact()

    # no remap escaped, no generation published, stores untouched
    assert live.stats()["generation"] == gen_before
    assert live.stats()["compactions"] == 0
    assert live.stats()["delta_fill"] == 16  # delta was not drained
    after = live.search(Q, k=10)
    np.testing.assert_array_equal(np.asarray(before.idx), np.asarray(after.idx))
    np.testing.assert_array_equal(np.asarray(before.dist), np.asarray(after.dist))

    # a subsequent clean compaction succeeds and answers the same rows
    remap = live.compact()
    assert live.stats()["generation"] == gen_before + 1
    assert live.stats()["delta_fill"] == 0
    assert (remap[np.asarray(before.idx[0])] >= 0).all()
    compacted = live.search(Q, k=10)
    np.testing.assert_array_equal(
        remap[np.asarray(before.idx)], np.asarray(compacted.idx))


def test_delta_overflow_server_self_heals(data):
    X, Q = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "delta", "start": 1, "stop": 2}])  # second upsert
    srv = SearchServer(X, engine="brute", cfg={}, live=True, delta_cap=64,
                       chaos=plan)
    ins = np.random.default_rng(4).normal(size=(8, D)).astype(np.float32)
    srv.upsert(ins)  # callno 0: clean
    ids = srv.upsert(ins)  # callno 1: injected overflow -> compact + retry
    assert ids.shape == (8,)
    assert srv.fault_counters["faults"] == 1
    assert srv.fault_counters["recoveries"] == 1
    assert srv.stats()["compactions"] == 1
    r = srv.query(Q, k=5)
    assert not r.degraded


# ---------------------------------------------------------------------------
# SearchServer: deadline-aware degraded controller + self-healing
# ---------------------------------------------------------------------------

def test_query_returns_served_result_unchanged_semantics(data):
    X, Q = data
    srv = SearchServer(X, engine="brute", cfg={})
    r = srv.query(Q, k=5)
    assert isinstance(r, ServedResult)
    assert not r.degraded and r.deadline_met and r.retries == 0
    assert r.shards_answered == r.shards_total == 1
    assert np.asarray(r.idx)[0][0] == 0  # Q[0] is X[0] + eps
    assert srv.stats()["health"] == "SERVING"


def test_transient_fault_retried_transparently(data):
    X, Q = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "search", "kind": "error", "start": 1, "stop": 2}])
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan,
                       policy=FaultPolicy(backoff_base_s=0.001))
    r0 = srv.query(Q, k=5)
    r1 = srv.query(Q, k=5)  # injected once, retried, answered
    assert r1.retries == 1 and not r1.degraded
    np.testing.assert_array_equal(r0.idx, r1.idx)
    assert srv.fault_counters["faults"] == 1
    assert srv.fault_counters["retries"] == 1
    assert srv.fault_counters["degraded_queries"] == 0


def test_fault_storm_surfaces_after_max_retries(data):
    X, Q = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "search", "kind": "error", "start": 1, "stop": 50}])
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan,
                       policy=FaultPolicy(max_retries=2, backoff_base_s=0.001))
    srv.query(Q, k=5)
    with pytest.raises(chaos_lib.TransientFault):
        srv.query(Q, k=5)
    assert srv.fault_counters["retries"] == 2


def test_deadline_shrinks_budget_not_correctness(data):
    X, Q = data
    srv = SearchServer(X, engine="ivf_flat",
                       cfg={"num_clusters": 8, "nprobe": 4, "budget": 256})
    roomy = srv.query(Q, k=5, budget=256, deadline_ms=60_000)
    assert roomy.deadline_met
    # an already-lapsed deadline: the controller still answers (budget
    # floored, never zero) and stamps the miss
    spent = srv.query(Q, k=5, budget=256, deadline_ms=1e-6)
    assert not spent.deadline_met
    assert np.asarray(spent.idx).shape == (len(Q), 5)
    assert srv.fault_counters["deadline_misses"] == 1


def test_swap_build_fault_restores_last_good_snapshot(tmp_path, data):
    X, Q = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "build", "start": 1, "stop": 2}])  # second build
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan,
                       snapshot_dir=str(tmp_path))
    before = srv.query(Q, k=5)
    with pytest.raises(chaos_lib.BuildFault):
        srv.swap("ivf_flat", cfg={"num_clusters": 8, "nprobe": 4})
    # health walked the full machine and the last good snapshot is serving
    assert srv.health_log == ["SERVING", "DEGRADED", "RECOVERING", "SERVING"]
    assert srv.fault_counters["snapshot_restores"] == 1
    after = srv.query(Q, k=5)
    np.testing.assert_array_equal(before.idx, after.idx)
    assert srv.engine == "brute"  # the failed swap never took effect


def test_swap_build_fault_without_snapshot_keeps_memory_index(data):
    X, Q = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "build", "start": 1, "stop": 2}])
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan)
    before = srv.query(Q, k=5)
    with pytest.raises(chaos_lib.BuildFault):
        srv.swap("ivf_flat", cfg={"num_clusters": 8, "nprobe": 4})
    assert srv.health == "SERVING"
    assert srv.fault_counters["snapshot_restores"] == 0
    np.testing.assert_array_equal(before.idx, srv.query(Q, k=5).idx)


def test_server_snapshot_verifies_what_it_wrote(tmp_path, data):
    X, _ = data
    plan = chaos_lib.FaultPlan(rules=[{"site": "snapshot", "rate": 1.0,
                                       "mode": "truncate"}])
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan)
    with pytest.raises(store_lib.SnapshotCorruption):
        srv.snapshot(os.path.join(str(tmp_path), "snap"))
    assert srv.fault_counters["snapshot_corrupt"] == 1


def test_good_snapshot_rotation_survives_corrupted_write(tmp_path, data):
    # first rotation write is corrupted -> discarded; the retry (draws
    # advance per call) or the previous good snapshot stays the restore point
    X, _ = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "snapshot", "start": 1, "stop": 2}])  # 2nd save only
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan,
                       snapshot_dir=str(tmp_path))
    first = srv._last_good
    assert first is not None
    second = srv._save_good_snapshot()  # corrupted once, clean on retry
    assert second is not None and second != first
    assert srv.fault_counters["snapshot_corrupt"] == 1
    store_lib.verify(second)
    assert not os.path.exists(first)  # rotation pruned the old generation


def test_restored_server_has_fresh_fault_state(tmp_path, data):
    X, Q = data
    srv = SearchServer(X, engine="brute", cfg={})
    path = srv.snapshot(os.path.join(str(tmp_path), "snap"))
    back = SearchServer.restore(path)
    assert back.health == "SERVING" and back.chaos is None
    np.testing.assert_array_equal(srv.query(Q, k=5).idx, back.query(Q, k=5).idx)


def test_stats_surface_health_and_chaos(data):
    X, Q = data
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "search", "kind": "latency", "rate": 1.0, "ms": 0.1}])
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan)
    srv.query(Q, k=3)
    s = srv.stats()
    assert s["health"] == "SERVING"
    assert s["chaos"]["injected"]["search:latency"] >= 1
    assert "faults" not in s or s["faults"]["faults"] == 0


# ---------------------------------------------------------------------------
# acceptance: kill 1 of 2 shards mid-run (subprocess: needs >1 device)
# ---------------------------------------------------------------------------

def test_shard_kill_degraded_serving_and_revival():
    _run_distributed(
        """
        import numpy as np
        from repro.core import chaos as chaos_lib
        from repro.launch.serve import SearchServer

        N, D, K = 600, 16, 10
        X = np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)
        Q = X[:16] + 0.01

        plan = chaos_lib.FaultPlan(seed=0)
        srv = SearchServer(X, engine="ivf_flat", shards=2,
                           cfg={"num_clusters": 8, "nprobe": 8, "budget": 512},
                           chaos=plan)
        full = srv.query(Q, k=K, budget=512, deadline_ms=60_000)
        assert not full.degraded and full.shards_answered == 2

        # kill shard 1 mid-run: every request must still answer in deadline,
        # flagged degraded, from the surviving shard only — no exceptions
        plan.kill_shard(1)
        shard_rows = N // 2
        answers = []
        for _ in range(4):
            r = srv.query(Q, k=K, budget=512, deadline_ms=60_000)
            assert r.degraded and r.shards_answered == 1
            assert r.deadline_met
            idx = np.asarray(r.idx)
            assert (idx[idx >= 0] < shard_rows).all()
            answers.append(idx)
        assert srv.health == "DEGRADED"
        assert sorted(srv._dead_shards) == [1]
        # once the shard is known dead, requests stop burning retries on it
        assert answers[-1] is not None and r.retries == 0
        np.testing.assert_array_equal(answers[0], answers[-1])

        # recall over the surviving shard's rows vs an exact oracle
        d = ((Q[:, None, :] - X[None, :shard_rows, :]) ** 2).sum(-1)
        gt = np.argsort(d, axis=1)[:, :K]
        hits = np.mean([len(set(map(int, a)) & set(map(int, t))) / K
                        for a, t in zip(answers[0], gt)])
        assert hits >= 0.9, hits

        # revival: the next full clean answer flips the server back to
        # SERVING and results are bit-identical to the no-fault run
        plan.revive_shard(1)
        back = srv.query(Q, k=K, budget=512, deadline_ms=60_000)
        assert not back.degraded and back.shards_answered == 2
        assert srv.health == "SERVING" and not srv._dead_shards
        np.testing.assert_array_equal(np.asarray(full.idx), np.asarray(back.idx))
        np.testing.assert_array_equal(np.asarray(full.dist), np.asarray(back.dist))
        assert srv.fault_counters["degraded_queries"] == 4
        assert srv.fault_counters["recoveries"] == 1
        print("ok")
        """
    )


def test_rate_based_shard_flap_is_absorbed_by_retries():
    _run_distributed(
        """
        import numpy as np
        from repro.core import chaos as chaos_lib
        from repro.launch.serve import SearchServer, FaultPolicy

        X = np.random.default_rng(0).normal(size=(400, 16)).astype(np.float32)
        Q = X[:8] + 0.01
        # a flapping shard: window rule kills shard 0 for two shard-site
        # calls, then it comes back — the retry loop rides it out
        plan = chaos_lib.FaultPlan(rules=[
            {"site": "shard", "shard": 0, "start": 1, "stop": 3}])
        srv = SearchServer(X, engine="brute", shards=2, cfg={}, chaos=plan,
                           policy=FaultPolicy(max_retries=4,
                                              backoff_base_s=0.001))
        clean = srv.query(Q, k=5)  # shard-site call 0: alive
        flap = srv.query(Q, k=5)   # calls 1, 2 dead; call 3 answers
        assert flap.retries == 2 and not flap.degraded
        np.testing.assert_array_equal(np.asarray(clean.idx),
                                      np.asarray(flap.idx))
        print("ok")
        """
    )
