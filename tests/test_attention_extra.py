"""Deeper attention coverage: chunked-vs-direct equivalence across families,
long-context masks, cache-length semantics."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as attn
from repro import configs
from repro.models import params as plib, transformer


@pytest.fixture(autouse=True)
def _restore_chunking():
    thr, sz = attn.CHUNK_THRESHOLD, attn.CHUNK_SIZE
    yield
    attn.CHUNK_THRESHOLD, attn.CHUNK_SIZE = thr, sz


@pytest.mark.parametrize("arch", ["smollm-135m", "gemma-2b", "deepseek-v3-671b"])
@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_equals_direct(arch, chunk):
    cfg = configs.get_reduced(arch)
    decls = transformer.lm_decls(cfg)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    attn.CHUNK_THRESHOLD = 10**9
    direct, _, _ = transformer.lm_forward(p, toks, cfg)
    attn.CHUNK_THRESHOLD, attn.CHUNK_SIZE = 32, chunk
    chunked, _, _ = transformer.lm_forward(p, toks, cfg)
    np.testing.assert_allclose(
        np.asarray(direct), np.asarray(chunked), atol=5e-4, rtol=5e-4
    )


def test_decode_respects_cache_length():
    """Positions beyond the current length must not contribute."""
    cfg = configs.get_reduced("smollm-135m")
    decls = transformer.lm_decls(cfg)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 4), 0, cfg.vocab_size)
    # two caches of different max_len, same content
    out = []
    for max_len in (8, 16):
        cache = transformer.init_cache(cfg, B, max_len)
        for t in range(4):
            lg, cache = transformer.lm_decode_step(
                p, cache, toks[:, t : t + 1], jnp.int32(t), cfg
            )
        out.append(np.asarray(lg))
    np.testing.assert_allclose(out[0], out[1], atol=1e-4)


def test_gqa_grouping_matches_repeated_kv():
    """GQA grouped einsum == explicit KV repetition."""
    cfg = configs.get_reduced("smollm-135m")  # 4 heads, 2 kv
    decls = transformer.lm_decls(cfg)
    p0 = plib.init_params(jax.random.PRNGKey(0), decls)
    layer = jax.tree_util.tree_map(lambda x: x[0], p0["dense_blocks"]["attn"])
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model), jnp.float32)
    pos = jnp.arange(S)
    out, _ = attn.gqa_attention(layer, x, pos, cfg)
    # MHA reference: duplicate each kv head G times
    G = cfg.num_heads // cfg.num_kv_heads
    cfg_mha = dc.replace(cfg, num_kv_heads=cfg.num_heads)
    layer_mha = dict(layer)
    layer_mha["wk"] = jnp.repeat(layer["wk"], G, axis=1)
    layer_mha["wv"] = jnp.repeat(layer["wv"], G, axis=1)
    out_ref, _ = attn.gqa_attention(layer_mha, x, pos, cfg_mha)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), atol=1e-4)


def test_moe_chunking_invariance():
    """MoE EP output must not depend on the token-chunk size."""
    import subprocess
    import sys
    import os
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    script = textwrap.dedent("""
        import dataclasses as dc
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro import configs
        import repro.models.moe as moe_lib
        mesh = make_test_mesh((2,4), ("data","model"))
        cfg = dc.replace(configs.get_reduced("qwen3-moe-235b-a22b"),
                         num_experts=8, num_experts_per_tok=2, capacity_factor=8.0)
        E,d,f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
        k = jax.random.PRNGKey
        p = {"wg": jax.random.normal(k(0),(E,d,f))*0.05,
             "wu": jax.random.normal(k(1),(E,d,f))*0.05,
             "wd": jax.random.normal(k(2),(E,f,d))*0.05}
        x = jax.random.normal(k(3),(4,64,d),jnp.float32)
        probs = jax.nn.softmax(jax.random.normal(k(4),(4,64,E)),axis=-1)
        outs = []
        for chunk in (32768, 64, 32):
            moe_lib.MOE_CHUNK_TOKENS = chunk
            with mesh:
                o = jax.jit(lambda *a: moe_lib.moe_ffn_ep(
                    *a, cfg, mesh=mesh, batch_axes=("data",)))(x, probs, p)
            outs.append(np.asarray(o))
        assert np.allclose(outs[0], outs[1], atol=1e-5)
        assert np.allclose(outs[0], outs[2], atol=1e-5)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr
