"""Search telemetry subsystem (DESIGN.md §16): registry, spans, trace ring,
Prometheus exposition, and the instrumented query path.

Pins the PR's acceptance invariants:
  * a beam query's per-stage comparison counters (traversal /
    centroid_rank / bucket_scan, threaded out of the jitted program as
    extra scalar outputs) sum exactly to the engine-reported comparisons,
    with the rerank stage on top at the engine level;
  * the trace of one instrumented beam query holds >= 4 distinct stage
    spans;
  * ``metrics_text()`` parses as Prometheus text exposition (cumulative
    ``_bucket{le=...}`` histograms + ``_sum``/``_count``);
  * enabling telemetry changes NO search result ids (bit-exact);
  * under injected faults the counters stay consistent — telemetry
    retries == the server's fault_counters == the chaos plan's injected
    count — and spans close (flagged) on exception paths;
  * the trace ring is bounded and never corrupts under overflow;
  * ``SearchServer``'s latency record is a bounded ring: 100k appends
    hold memory flat while percentile semantics cover the window.
"""
import json
import math
import re

import numpy as np
import pytest

from repro.core import chaos as chaos_lib
from repro.core import index as index_lib
from repro.core import telemetry as telem
from repro.core import vptree as vptree_lib
from repro.launch.serve import FaultPolicy, LatencyRing, SearchServer

N, D = 256, 16


@pytest.fixture(autouse=True)
def _clean_registry():
    """Telemetry state is process-global: every test starts and ends
    disabled + zeroed so no counters leak across the suite."""
    telem.disable()
    telem.reset()
    telem.set_trace_cap(8192)
    yield
    telem.disable()
    telem.reset()
    telem.set_trace_cap(8192)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, D)).astype(np.float32)
    Q = X[:12] + 0.01
    return X, Q


@pytest.fixture(scope="module")
def infinity_engine(data):
    X, _ = data
    return index_lib.build("infinity", X, {
        "q": math.inf, "train_steps": 20, "proj_sample": 64,
        "budget": 192, "rerank": 32,
    })


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------

def test_disabled_entry_points_are_noops():
    telem.count("c_total", 5, engine="x")
    telem.observe("h_seconds", 0.1, engine="x")
    telem.set_gauge("g", 1.0)
    with telem.span("stage_x", engine="x"):
        pass
    snap = telem.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}
    assert telem.trace_events() == []


def test_counter_accumulates_per_label_set():
    telem.enable()
    telem.count("c_total", 2, engine="a", stage="s1")
    telem.count("c_total", 3, engine="a", stage="s1")
    telem.count("c_total", 7, engine="a", stage="s2")
    assert telem.counter_total("c_total", engine="a", stage="s1") == 5
    assert telem.counter_total("c_total", engine="a") == 12
    assert telem.counter_total("c_total") == 12


def test_metric_kind_collision_raises():
    telem.enable()
    telem.count("thing_total", 1)
    with pytest.raises(TypeError):
        telem.REGISTRY.histogram("thing_total")


def test_histogram_buckets_are_fixed_and_cumulative_in_exposition():
    telem.enable()
    for v in (2e-4, 2e-4, 3e-3, 0.7, 100.0):  # last lands in +Inf
        telem.observe("lat_seconds", v, engine="e")
    [(lbl, rec)] = telem.histogram_series("lat_seconds")
    assert lbl == {"engine": "e"}
    assert rec["count"] == 5
    assert sum(rec["buckets"]) == 5
    assert rec["buckets"][-1] == 1  # the +Inf overflow slot


def test_span_records_histogram_and_trace_event():
    telem.enable()
    with telem.span("stage_y", engine="e", q="inf"):
        pass
    [(lbl, rec)] = telem.histogram_series("stage_seconds")
    assert lbl == {"engine": "e", "q": "inf", "stage": "stage_y"}
    assert rec["count"] == 1
    [ev] = telem.trace_events()
    assert ev["ph"] == "X" and ev["name"] == "stage_y"
    assert ev["dur"] >= 0 and "error" not in ev["args"]


def test_span_closes_on_exception_and_flags_error():
    telem.enable()
    with pytest.raises(RuntimeError):
        with telem.span("doomed", engine="e"):
            raise RuntimeError("boom")
    [(lbl, rec)] = telem.histogram_series("stage_seconds")
    assert rec["count"] == 1  # observed despite the raise
    [ev] = telem.trace_events()
    assert ev["name"] == "doomed" and ev["args"]["error"] is True


def test_trace_ring_bounded_and_uncorrupted_under_overflow():
    telem.enable()
    telem.set_trace_cap(16)
    for i in range(100):
        telem.emit_span(f"s{i}", 1e-4, engine="e")
    evs = telem.trace_events()
    assert len(evs) == 16
    # oldest-overwritten: the survivors are the most recent 16, in order
    assert [e["name"] for e in evs] == [f"s{i}" for i in range(84, 100)]
    assert all(e["ph"] == "X" and "ts" in e and "dur" in e for e in evs)
    assert telem.snapshot()["trace"]["dropped"] == 84


def test_dump_trace_is_perfetto_loadable_json(tmp_path):
    telem.enable()
    with telem.span("a", engine="e"):
        pass
    out = telem.dump_trace(str(tmp_path / "trace.json"))
    doc = json.load(open(out))
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    ev = doc["traceEvents"][0]
    assert {"name", "ph", "ts", "dur", "pid", "tid", "args"} <= set(ev)


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

_SAMPLE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'          # metric name
    r'(\{[a-zA-Z0-9_]+="(?:[^"\\]|\\.)*"'   # first label
    r'(,[a-zA-Z0-9_]+="(?:[^"\\]|\\.)*")*\})?'  # more labels
    r' (\S+)$'                               # value
)


def _parse_exposition(text: str):
    """Minimal text-format 0.0.4 parser: returns {name: [(labels_str, value)]}
    and raises on any malformed line — the 'parses as valid exposition'
    check without a prometheus_client dependency."""
    series: dict = {}
    typed: dict = {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ", 3)
            typed[name] = kind
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        assert m, f"malformed exposition line: {line!r}"
        name, labels, _, value = m.groups()
        float(value)  # must be numeric
        series.setdefault(name, []).append((labels or "", float(value)))
    return series, typed


def test_metrics_text_parses_and_histograms_are_cumulative():
    telem.enable()
    telem.count("comparisons_total", 9, engine="e", stage="traversal")
    for v in (2e-4, 5e-3, 0.2):
        telem.observe("search_latency", v, engine="e")
    series, typed = _parse_exposition(telem.metrics_text())
    assert typed["comparisons_total"] == "counter"
    assert typed["search_latency"] == "histogram"
    assert series["comparisons_total"] == [('{engine="e",stage="traversal"}', 9.0)]
    buckets = [v for lbl, v in series["search_latency_bucket"]]
    assert buckets == sorted(buckets), "histogram buckets must be cumulative"
    assert 'le="+Inf"' in series["search_latency_bucket"][-1][0]
    assert buckets[-1] == 3.0
    [( _, count)] = series["search_latency_count"]
    assert count == 3.0


def test_exposition_escapes_label_values():
    telem.enable()
    telem.count("odd_total", 1, label='he said "hi"\nback\\slash')
    series, _ = _parse_exposition(telem.metrics_text())
    assert series["odd_total"][0][1] == 1.0


# ---------------------------------------------------------------------------
# beam stage counters: jit-threaded accounting (acceptance invariant)
# ---------------------------------------------------------------------------

def test_beam_stage_counters_sum_to_comparisons(infinity_engine, data):
    _, Q = data
    flat, Zf, _ = infinity_engine._flat_view()
    idx, dist, comps, stages = vptree_lib.search_beam(
        flat, np.asarray(infinity_engine.Z[:12]), q=math.inf, k=5, X=Zf,
        metric="euclidean", max_comparisons=192, with_stages=True,
    )
    assert set(stages) == {"traversal", "centroid_rank", "bucket_scan"}
    total = (np.asarray(stages["traversal"]) +
             np.asarray(stages["centroid_rank"]) +
             np.asarray(stages["bucket_scan"]))
    np.testing.assert_array_equal(total, np.asarray(comps))
    assert int(np.asarray(stages["traversal"]).min()) > 0


def test_beam_default_return_signature_unchanged(infinity_engine):
    flat, Zf, _ = infinity_engine._flat_view()
    out = vptree_lib.search_beam(
        flat, np.asarray(infinity_engine.Z[:4]), q=math.inf, k=3, X=Zf,
        metric="euclidean",
    )
    assert len(out) == 3  # (idx, dist, comps) — pre-PR callers unaffected


def test_engine_counters_sum_to_reported_comparisons(infinity_engine, data):
    _, Q = data
    telem.enable()
    res = infinity_engine.search(Q, k=5, mode="beam")
    reported = int(np.asarray(res.comparisons).sum())
    counted = telem.counter_total("comparisons_total", engine="infinity")
    assert counted == reported
    # the trace of one beam query holds >= 4 distinct stage spans
    names = {e["name"] for e in telem.trace_events()}
    assert {"traversal", "centroid_rank", "bucket_scan", "rerank"} <= names


def test_enabling_telemetry_is_bit_exact(infinity_engine, data):
    _, Q = data
    for mode in ("beam", "best_first"):
        off = infinity_engine.search(Q, k=5, mode=mode)
        telem.enable()
        on = infinity_engine.search(Q, k=5, mode=mode)
        telem.disable()
        np.testing.assert_array_equal(np.asarray(off.idx), np.asarray(on.idx))
        np.testing.assert_array_equal(
            np.asarray(off.comparisons), np.asarray(on.comparisons))


# ---------------------------------------------------------------------------
# instrumented serving path under failure (chaos consistency)
# ---------------------------------------------------------------------------

def test_server_counters_match_fault_counters_and_chaos(data):
    X, Q = data
    telem.enable()
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "search", "kind": "error", "start": 1, "stop": 3}])
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan,
                       policy=FaultPolicy(max_retries=3,
                                          backoff_base_s=0.001))
    srv.query(Q, k=5)
    srv.query(Q, k=5)  # absorbs injection 1
    srv.query(Q, k=5)  # absorbs injection 2
    injected = sum(plan.stats()["injected"].values())
    assert injected == 2
    assert srv.fault_counters["retries"] == injected
    assert telem.counter_total("retries_total", engine="brute") == injected
    assert telem.counter_total("faults_total", engine="brute") == injected
    assert telem.counter_total("queries_total", engine="brute") == 3 * len(Q)
    # every retried dispatch opened AND closed a span: 3 clean + 2 flagged
    dispatch = [e for e in telem.trace_events() if e["name"] == "dispatch"]
    assert len(dispatch) == 5
    assert sum(bool(e["args"].get("error")) for e in dispatch) == 2


def test_fault_storm_closes_spans_on_the_raising_path(data):
    X, Q = data
    telem.enable()
    plan = chaos_lib.FaultPlan(
        rules=[{"site": "search", "kind": "error", "start": 1, "stop": 50}])
    srv = SearchServer(X, engine="brute", cfg={}, chaos=plan,
                       policy=FaultPolicy(max_retries=1,
                                          backoff_base_s=0.001))
    srv.query(Q, k=5)
    with pytest.raises(chaos_lib.TransientFault):
        srv.query(Q, k=5)
    dispatch = [e for e in telem.trace_events() if e["name"] == "dispatch"]
    # 1 clean + 2 flagged (first attempt + the exhausted retry): no span
    # leaks open even though the second query raised out of the server
    assert len(dispatch) == 3
    assert sum(bool(e["args"].get("error")) for e in dispatch) == 2
    # the trace ring stays well-formed after the exception path
    assert all(e["ph"] == "X" and e["dur"] >= 0
               for e in telem.trace_events())


def test_deadline_miss_counted_consistently(data):
    X, Q = data
    telem.enable()
    srv = SearchServer(X, engine="brute", cfg={})
    srv.query(Q, k=5, budget=64, deadline_ms=1e-6)
    assert srv.fault_counters["deadline_misses"] == 1
    assert telem.counter_total("deadline_misses_total", engine="brute") == 1


def test_health_transitions_become_counters(data):
    X, _ = data
    telem.enable()
    srv = SearchServer(X, engine="brute", cfg={})
    srv._set_health("DEGRADED")
    srv._set_health("RECOVERING")
    srv._set_health("SERVING")
    assert telem.counter_total("health_transitions_total") == 3
    assert telem.counter_total(
        "health_transitions_total", **{"from": "DEGRADED"}) == 1


def test_server_jit_cache_counters_track_buckets(data):
    X, Q = data
    telem.enable()
    srv = SearchServer(X, engine="brute", cfg={})
    srv.query(Q, k=5)        # bucket 16: miss
    srv.query(Q, k=5)        # same bucket: hit
    srv.query(Q[:3], k=5)    # bucket 8: miss
    assert telem.counter_total("jit_cache_misses_total", scope="server") == 2
    assert telem.counter_total("jit_cache_hits_total", scope="server") == 1


def test_stats_carries_telemetry_tree_and_metrics_text(data):
    X, Q = data
    telem.enable()
    srv = SearchServer(X, engine="brute", cfg={})
    srv.query(Q, k=5)
    s = srv.stats()
    assert "telemetry" in s
    assert s["telemetry"]["counters"]["queries_total"]
    series, _ = _parse_exposition(srv.metrics_text())
    assert "search_latency_bucket" in series
    assert "queries_total" in series
    # disabled servers don't grow a telemetry tree
    telem.disable()
    assert "telemetry" not in srv.stats()


# ---------------------------------------------------------------------------
# bounded latency record (the _lat_s bugfix)
# ---------------------------------------------------------------------------

def test_latency_ring_memory_flat_at_100k_appends():
    ring = LatencyRing(cap=4096)
    base = ring._lat.nbytes + ring._nq.nbytes
    for i in range(100_000):
        ring.append(1e-3 + (i % 7) * 1e-4, 16)
    assert len(ring) == 4096  # window, not history
    assert ring._lat.nbytes + ring._nq.nbytes == base  # no growth, ever
    lat, nq = ring.window()
    assert lat.shape == (4096,) and nq.shape == (4096,)
    assert np.all(lat > 0) and np.all(nq == 16)


def test_latency_ring_percentiles_cover_recent_window():
    ring = LatencyRing(cap=8)
    for _ in range(100):
        ring.append(1.0, 1)  # old regime: would dominate an unbounded list
    for _ in range(8):
        ring.append(0.001, 1)  # new regime fills the whole window
    lat, _ = ring.window()
    assert float(np.percentile(lat * 1e3, 50)) == pytest.approx(1.0)


def test_server_stats_batches_count_lifetime_window_bounded(data):
    X, Q = data
    srv = SearchServer(X, engine="brute", cfg={})
    srv._lat = LatencyRing(cap=4)  # tiny window to exercise wrap
    for _ in range(9):
        srv.query(Q, k=5)
    s = srv.stats()
    assert s["batches"] == 9            # lifetime total survives the wrap
    assert s["window_batches"] == 4     # percentiles cover the window
    assert s["queries"] == 9 * len(Q)
    assert s["p50_ms"] > 0 and s["qps"] > 0


# ---------------------------------------------------------------------------
# bench integration
# ---------------------------------------------------------------------------

def test_write_stamped_attaches_telemetry_summary(tmp_path):
    from benchmarks.common import write_stamped

    telem.enable()
    telem.count("comparisons_total", 11, engine="e", stage="traversal")
    path = str(tmp_path / "BENCH_x.json")
    write_stamped(path, [{"a": 1}])
    doc = json.load(open(path))
    assert doc["meta"]["telemetry"]["counters"]["comparisons_total"]
    # disabled runs stay schema-identical to pre-PR artifacts
    telem.disable()
    write_stamped(path, [{"a": 1}])
    assert "telemetry" not in json.load(open(path))["meta"]


def test_stage_breakdown_reads_the_registry(infinity_engine, data):
    from benchmarks.common import stage_breakdown

    _, Q = data
    telem.enable()
    infinity_engine.search(Q, k=5, mode="beam")
    br = stage_breakdown("infinity")
    assert {"traversal", "centroid_rank", "bucket_scan", "rerank"} <= set(br)
    for stage in ("traversal", "centroid_rank", "bucket_scan", "rerank"):
        assert br[stage]["comparisons"] > 0
    # embed rides along as a pure-latency stage (no comparison counter)
    assert br.get("embed", {"comparisons": 0.0})["comparisons"] == 0.0
    telem.disable()
    assert stage_breakdown("infinity") == {}
