"""Live index subsystem: interleaved upsert/delete/query traces vs
from-scratch rebuilds, generation-swap compaction bit-identity, snapshot
round-trips for every registry engine, and the streaming bench artifact."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, D = 200, 16
DELTA_CAP = 48

# tiny-but-real engine cfgs (the registry split: build keys + search defaults)
ENGINE_CFGS = {
    "brute": {},
    # nprobe == num_clusters: every list probed -> exhaustive (exact) search
    "ivf_flat": {"num_clusters": 8, "nprobe": 8},
    "ivf_pq": {"num_clusters": 8, "M": 4, "ksub": 16, "nprobe": 4, "rerank": 16},
    "nsw": {"degree": 8, "ef": 24, "max_steps": 64},
    "infinity": {"q": 8.0, "proj_sample": 120, "knn_k": 8, "num_hops": 4,
                 "embed_dim": 8, "hidden": (32,), "train_steps": 60,
                 "batch_pairs": 128, "rerank": 16},
}
EXHAUSTIVE = ("brute", "ivf_flat")  # per-query scoring covers every alive row


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, D)).astype(np.float32)
    Xnew = rng.normal(size=(60, D)).astype(np.float32)
    Q = rng.normal(size=(10, D)).astype(np.float32)
    return X, Xnew, Q


def _mapped(live, idx):
    """Live slot ids -> positions in live.corpus() (-1 stays -1)."""
    s2l = live.slot_to_logical()
    idx = np.asarray(idx)
    return np.where(idx >= 0, s2l[np.maximum(idx, 0)], -1)


def _trace(live, Xnew):
    """The shared churn trace: two upsert bursts + frozen AND delta deletes."""
    ids1 = live.upsert(Xnew[:25])
    live.delete([3, 17, 42])            # frozen rows
    live.delete(ids1[[0, 7]])           # delta rows
    ids2 = live.upsert(Xnew[25:40])
    live.delete(ids2[[1]])
    return ids1, ids2


@pytest.mark.parametrize("engine", list(ENGINE_CFGS))
def test_interleaved_trace_and_compaction(engine, data):
    """The acceptance trace: pre-compaction the live view keeps the search
    contract (and, for exhaustive engines, the exact top-k set of a rebuild
    on the equivalent corpus); post-compaction results are bit-identical to
    a from-scratch build of the same engine on the compacted corpus."""
    from repro.core import index as index_lib

    X, Xnew, Q = data
    cfg = dict(ENGINE_CFGS[engine])
    live = index_lib.build("live", X, {
        "engine": engine, "engine_cfg": cfg, "delta_cap": DELTA_CAP,
        "auto_compact": False,
    })
    _trace(live, Xnew)

    k = 5
    res = live.search(Q, k=k)
    idx = np.asarray(res.idx)
    dist = np.asarray(res.dist)
    assert idx.shape == (Q.shape[0], k) and idx.dtype == np.int32
    fin = np.where(np.isfinite(dist), dist, np.inf)
    assert (np.diff(fin, axis=1) >= -1e-6).all(), "dist must ascend"
    assert (np.asarray(res.comparisons) >= 1).all()
    # no tombstoned slot may surface
    s2l = live.slot_to_logical()
    assert (s2l[idx[idx >= 0]] >= 0).all(), "tombstoned id leaked"

    corpus = live.corpus()  # the equivalent final corpus, pre-compaction
    gt = index_lib.build("brute", corpus, {}).search(Q, k=k)
    if engine in EXHAUSTIVE:
        # identical top-k SETS (ids mapped to the logical view) + distances
        np.testing.assert_array_equal(_mapped(live, idx), np.asarray(gt.idx))
        np.testing.assert_allclose(dist, np.asarray(gt.dist), rtol=1e-5, atol=1e-5)

    remap = live.compact()
    assert live.stats()["generation"] == 1
    assert remap.shape[0] == N + 40  # every old slot is accounted for
    assert (remap[[3, 17, 42]] == -1).all()  # deleted rows vanish

    # post-compaction: bit-identical to a from-scratch rebuild on the
    # equivalent final corpus (same cfg, seeds included)
    scratch = index_lib.build(engine, corpus, dict(ENGINE_CFGS[engine]))
    a = live.search(Q, k=k)
    b = scratch.search(Q, k=k)
    np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
    np.testing.assert_array_equal(np.asarray(a.dist), np.asarray(b.dist))
    np.testing.assert_array_equal(np.asarray(a.comparisons), np.asarray(b.comparisons))


@pytest.mark.parametrize("engine", list(ENGINE_CFGS))
def test_snapshot_roundtrip(engine, data):
    """snapshot -> load -> search is bit-exact for every registry engine."""
    from repro.core import index as index_lib
    from repro.core import store

    X, _, Q = data
    eng = index_lib.build(engine, X, dict(ENGINE_CFGS[engine]))
    r1 = eng.search(Q, k=5)
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        path = store.save(eng, os.path.join(td, "snap"))
        assert store.peek(path)["engine"] == engine
        eng2 = store.load(path)
    r2 = eng2.search(Q, k=5)
    np.testing.assert_array_equal(np.asarray(r1.idx), np.asarray(r2.idx))
    np.testing.assert_array_equal(np.asarray(r1.dist), np.asarray(r2.dist))
    np.testing.assert_array_equal(
        np.asarray(r1.comparisons), np.asarray(r2.comparisons))


def test_live_snapshot_roundtrip_mid_churn(data):
    """The FULL live state — delta rows, tombstone bitmap, generation —
    survives a snapshot taken mid-churn, bit-exactly."""
    from repro.core import index as index_lib
    from repro.core import store

    X, Xnew, Q = data
    live = index_lib.build("live", X, {
        "engine": "nsw", "engine_cfg": dict(ENGINE_CFGS["nsw"]),
        "delta_cap": DELTA_CAP, "auto_compact": False,
    })
    live.compact()  # generation 1: the counter must persist too
    _trace(live, Xnew)
    r1 = live.search(Q, k=5)
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        live2 = store.load(store.save(live, os.path.join(td, "snap")))
    assert live2.stats() == live.stats()
    r2 = live2.search(Q, k=5)
    np.testing.assert_array_equal(np.asarray(r1.idx), np.asarray(r2.idx))
    np.testing.assert_array_equal(np.asarray(r1.dist), np.asarray(r2.dist))
    # mutation continues from the restored state
    live2.upsert(Xnew[40:45])
    assert live2.stats()["delta_fill"] == live.stats()["delta_fill"] + 5


def test_snapshot_overwrite_commits_atomically(data, tmp_path):
    """Re-saving over an existing snapshot writes a fresh arrays file and
    commits via the meta replace; exactly one arrays generation survives
    and it is the one meta names."""
    import json

    from repro.core import index as index_lib
    from repro.core import store

    X, _, Q = data
    path = str(tmp_path / "s")
    store.save(index_lib.build("brute", X, {}), path)
    store.save(index_lib.build("brute", X[:100], {}), path)  # overwrite
    arrays = [f for f in os.listdir(path) if f.startswith("arrays-")]
    assert len(arrays) == 1  # stale generation swept after the commit
    assert json.load(open(os.path.join(path, "meta.json")))["arrays"] == arrays[0]
    assert store.load(path).X.shape[0] == 100


def test_delete_everything_does_not_crash_autocompaction(data):
    """Tombstoning every row is a valid state: autocompaction must defer
    (nothing alive to freeze) instead of raising out of delete()."""
    from repro.core import index as index_lib

    X, Xnew, Q = data
    live = index_lib.build("live", X, {"engine": "brute", "delta_cap": 8})
    live.delete(np.arange(N))  # deleted_frac 1.0 — past every threshold
    st = live.stats()
    assert st["n_alive"] == 0 and st["generation"] == 0
    res = live.search(Q, k=3)
    assert (np.asarray(res.idx) == -1).all()  # all 'no result', no crash
    # the next insert revives the index (and may trigger the compaction)
    ids = live.upsert(Xnew[:2])
    res = live.search(Xnew[:1], k=1)
    assert int(np.asarray(res.idx)[0, 0]) == ids[0]


def test_snapshot_version_gate(data, tmp_path):
    """A snapshot from a future format version is refused, not misread."""
    import json

    from repro.core import index as index_lib
    from repro.core import store

    X, _, _ = data
    path = store.save(index_lib.build("brute", X, {}), str(tmp_path / "s"))
    meta = json.load(open(os.path.join(path, "meta.json")))
    meta["format_version"] = 999
    json.dump(meta, open(os.path.join(path, "meta.json"), "w"))
    with pytest.raises(ValueError, match="format_version"):
        store.load(path)


def test_upsert_delete_semantics(data):
    """Slot assignment, replace-by-id, delete validation, and the
    compaction remap."""
    from repro.core import index as index_lib

    X, Xnew, Q = data
    live = index_lib.build("live", X, {"engine": "brute",
                                       "delta_cap": 16, "auto_compact": False})
    ids = live.upsert(Xnew[:4])
    np.testing.assert_array_equal(ids, N + np.arange(4))
    # upsert with ids tombstones the replaced slots and appends new rows
    ids2 = live.upsert(Xnew[4:6], ids=[0, int(ids[1])])
    s2l = live.slot_to_logical()
    assert s2l[0] == -1 and s2l[ids[1]] == -1
    np.testing.assert_array_equal(ids2, N + np.arange(4, 6))
    # the replacement row is searchable under its new slot id
    res = live.search(Xnew[4:5], k=1)
    assert int(np.asarray(res.idx)[0, 0]) == int(ids2[0])
    # invalid deletes raise instead of silently missing
    with pytest.raises(KeyError):
        live.delete([N + 16])  # beyond the delta fill
    with pytest.raises(KeyError):
        live.delete([-2])
    assert live.delete([5, 5]) == 1  # dup ids mark once
    # double delete is idempotent
    assert live.delete([5]) == 0


def test_upsert_ids_valid_across_midbatch_compaction(data):
    """A batch larger than the remaining delta room compacts mid-insert;
    the returned ids must all be valid in the FINAL generation (remapped
    through the swap), so callers can delete / look up what they inserted."""
    from repro.core import index as index_lib

    X, Xnew, _ = data
    live = index_lib.build("live", X, {"engine": "brute", "delta_cap": 8})
    live.upsert(Xnew[:5])
    ids = live.upsert(Xnew[5:25])  # 20 rows through 3 remaining slots
    assert live.stats()["generation"] >= 2
    # every returned id addresses exactly the row that was inserted
    res = live.search(Xnew[5:25], k=1)
    np.testing.assert_array_equal(np.asarray(res.idx)[:, 0], ids)
    # self-distance ~0 up to the dot-product-expansion cancellation of the
    # euclidean matrix kernel in float32
    assert (np.asarray(res.dist)[:, 0] < 1e-2).all()
    live.delete(ids)  # and they are deletable without KeyError
    assert live.stats()["tombstones"] + live.stats()["generation"] > 0


def test_auto_compaction_triggers(data):
    """The delta filling or the deleted fraction crossing the threshold
    swaps generations without an explicit compact() call."""
    from repro.core import index as index_lib

    X, Xnew, Q = data
    live = index_lib.build("live", X, {"engine": "brute", "delta_cap": 8})
    live.upsert(Xnew[:20])  # 20 rows through an 8-slot delta: compacts twice
    st = live.stats()
    assert st["generation"] == 2 and st["delta_fill"] == 4
    assert st["frozen_size"] == N + 16
    # deleted-fraction trigger: deletes only flip bits (held ids stay
    # valid); the threshold compaction fires at the NEXT upsert, which is
    # the operation that hands back remapped ids
    live2 = index_lib.build("live", X, {
        "engine": "brute", "delta_cap": 8, "compact_deleted_frac": 0.1})
    live2.delete(np.arange(25))  # 25/200 = 12.5% >= 10%
    st2 = live2.stats()
    assert st2["generation"] == 0 and st2["tombstones"] == 25
    ids = live2.upsert(Xnew[:1])
    st2 = live2.stats()
    assert st2["generation"] == 1 and st2["tombstones"] == 0
    assert st2["frozen_size"] == N - 25 + 1
    assert ids[0] == N - 25  # the returned id went through the remap
    # searches in the new generation never see the dead rows
    res = live2.search(X[:4], k=1)
    assert (np.asarray(res.dist)[:, 0] > 0).all()


def test_live_rejects_bad_config(data):
    from repro.core import index as index_lib

    X, _, _ = data
    with pytest.raises(TypeError):
        index_lib.build("live", X, {"engine": "live"})
    with pytest.raises(ValueError):
        index_lib.build("live", X, {"delta_cap": 0})
    with pytest.raises(ValueError):
        index_lib.build("live", X, {"compact_mode": "bogus"})
    live = index_lib.build("live", X, {"engine": "brute", "delta_cap": 4,
                                       "auto_compact": False})
    with pytest.raises(ValueError):  # nothing left to freeze
        live.delete(np.arange(N))
        live.compact()


def test_server_live_operations_and_stats(data):
    """SearchServer: upsert/delete/compact/snapshot pass-through, and
    stats() reporting segment composition next to the latency numbers."""
    from repro.launch.serve import SearchServer

    X, Xnew, Q = data
    srv = SearchServer(X, engine="brute", cfg={}, live=True, delta_cap=16)
    ids = srv.upsert(Xnew[:6])
    srv.delete(ids[:2])
    srv.query(Q, k=3)
    st = srv.stats()
    assert st["live"] and st["queries"] == Q.shape[0]
    # serve()'s warm-up/compile calls stay OUT of the operator stats: one
    # measured batch here -> exactly one more latency sample than before
    srv.serve([Q], k=3)
    assert srv.stats()["batches"] == st["batches"] + 1
    assert st["frozen_size"] == N and st["delta_fill"] == 6
    assert st["tombstones"] == 2 and st["generation"] == 0
    assert {"p50_ms", "p99_ms", "qps"} <= set(st)
    srv.compact()
    assert srv.stats()["generation"] == 1

    import tempfile

    with tempfile.TemporaryDirectory() as td:
        path = srv.snapshot(os.path.join(td, "snap"))
        srv2 = SearchServer.restore(path)
        r1 = srv.query(Q, k=3)
        r2 = srv2.query(Q, k=3)
        np.testing.assert_array_equal(r1.idx, r2.idx)
        assert srv2.stats()["frozen_size"] == srv.stats()["frozen_size"]

    # frozen servers refuse mutation loudly
    frozen = SearchServer(X, engine="brute", cfg={})
    with pytest.raises(TypeError):
        frozen.upsert(Xnew[:1])


def test_live_sharded_engine_subprocess():
    """The live wrapper composes with the sharded engine (frozen segment
    data-parallel over 2 devices, delta + tombstones on the host)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import numpy as np
            from repro.core import index as index_lib
            rng = np.random.default_rng(0)
            X = rng.normal(size=(240, 16)).astype(np.float32)
            Q = rng.normal(size=(6, 16)).astype(np.float32)
            Xn = rng.normal(size=(8, 16)).astype(np.float32)
            live = index_lib.build("live", X, {
                "engine": "sharded",
                "engine_cfg": {"engine": "brute", "shards": 2},
                "delta_cap": 16, "auto_compact": False})
            ids = live.upsert(Xn)
            live.delete([1, 2, int(ids[0])])
            res = live.search(Q, k=5)
            s2l = live.slot_to_logical()
            idx = np.asarray(res.idx)
            mapped = np.where(idx >= 0, s2l[np.maximum(idx, 0)], -1)
            gt = index_lib.build("brute", live.corpus(), {}).search(Q, k=5)
            np.testing.assert_array_equal(mapped, np.asarray(gt.idx))
            # compaction with an alive count NOT divisible by the shard
            # count: the remainder rows carry into the new delta buffer
            before = live.corpus()
            assert before.shape[0] % 2 == 1, before.shape
            live.compact()
            st = live.stats()
            assert st["generation"] == 1 and st["delta_fill"] == 1, st
            after = live.search(Q, k=5)
            gt2 = index_lib.build("brute", before, {}).search(Q, k=5)
            s2l = live.slot_to_logical()
            idx = np.asarray(after.idx)
            mapped = np.where(idx >= 0, s2l[np.maximum(idx, 0)], -1)
            np.testing.assert_array_equal(mapped, np.asarray(gt2.idx))
            # the original metric resolves through sharded's NESTED cfg
            lc = index_lib.build("live", X, {
                "engine": "sharded",
                "engine_cfg": {"engine": "brute", "shards": 2,
                               "engine_cfg": {"metric": "cosine"}},
                "delta_cap": 16})
            assert lc.metric == "cosine", lc.metric
            print("OK")
        """)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


def test_streaming_bench_emits_artifact(tmp_path):
    """The churn bench runs end to end and writes the machine-readable
    artifact benchmarks/run.py publishes as BENCH_streaming.json."""
    import json

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import bench_streaming

    rows = bench_streaming.run(
        n=128, steps=2, ins=16, dels=8, qbatch=8, k=3,
        engines="brute", delta_cap=24, verbose=False,
    )
    assert len(rows) == 2
    assert {"engine", "recall@k", "qps", "delta_fill", "tombstones",
            "generation"} <= set(rows[0])
    assert rows[0]["recall@k"] == 1.0  # brute under churn stays exact
    path = tmp_path / "BENCH_streaming.json"
    bench_streaming.write_artifact(rows, str(path))
    art = json.load(open(path))
    assert len(art["rows"]) == 2
    # every artifact carries the provenance stamp (benchmarks/common)
    assert {"git_commit", "jax_version", "backend",
            "device_count"} <= set(art["meta"])
