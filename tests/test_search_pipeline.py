"""End-to-end InfinitySearch pipeline + ANN baselines (small, CPU-sized)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, embedding as embed_lib
from repro.core.search import IndexConfig, InfinityIndex
from repro.data import synthetic

N, D = 500, 16


@pytest.fixture(scope="module")
def data():
    X = synthetic.make("clustered", N, d=D, num_clusters=6, seed=0)
    Xtr, Q = synthetic.train_query_split(X, seed=0)
    gt, _, _ = baselines.brute_force(jnp.asarray(Xtr), jnp.asarray(Q), k=10)
    return jnp.asarray(Xtr), jnp.asarray(Q), np.asarray(gt)


@pytest.fixture(scope="module")
def index(data):
    Xtr, Q, gt = data
    cfg = IndexConfig(
        q=8.0, metric="euclidean", proj_sample=300, knn_k=10, num_hops=5,
        embed_dim=16, hidden=(128,), train_steps=400, batch_pairs=512,
    )
    return InfinityIndex.build(Xtr, cfg)


def test_build_artifacts(index, data):
    Xtr, Q, gt = data
    assert index.Z.shape == (Xtr.shape[0], 16)
    assert index.tree.num_nodes == Xtr.shape[0]
    assert np.isfinite(np.asarray(index.Z)).all()
    losses = [l for _, l in index.train_history["loss"]]
    assert losses[-1] < losses[0], "stress must decrease during training"


def test_two_stage_search_recall(index, data):
    Xtr, Q, gt = data
    idx, dist, comps = index.search(Q, k=1, mode="best_first", rerank=64)
    rec = float(np.mean(np.asarray(idx)[:, 0] == gt[:, 0]))
    assert rec >= 0.55, rec  # paper: two-stage recovers accuracy (F.5)
    assert (np.asarray(comps) <= index.tree.num_nodes + 64).all()
    # returned distances are genuine original-metric distances
    d0 = np.linalg.norm(np.asarray(Q)[0] - np.asarray(Xtr)[int(idx[0, 0])])
    assert abs(d0 - float(dist[0, 0])) < 1e-4


def test_budget_controls_comparisons(index, data):
    Xtr, Q, gt = data
    _, _, c1 = index.search(Q, k=1, mode="best_first", max_comparisons=20)
    _, _, c2 = index.search(Q, k=1, mode="best_first", max_comparisons=200)
    assert float(np.mean(np.asarray(c1))) < float(np.mean(np.asarray(c2)))
    assert (np.asarray(c1) <= 20).all()


def test_descend_mode_uses_depth_comparisons(index, data):
    Xtr, Q, gt = data
    _, _, comps = index.search(Q, k=1, mode="descend")
    assert (np.asarray(comps) <= index.tree.depth).all()


def test_knn_search(index, data):
    Xtr, Q, gt = data
    idx, dist, _ = index.search(Q, k=5, mode="best_first", rerank=64)
    rec5 = np.mean([
        len(set(map(int, idx_row)) & set(map(int, gt_row[:5]))) / 5.0
        for idx_row, gt_row in zip(np.asarray(idx), gt)
    ])
    assert rec5 >= 0.5, rec5


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def test_brute_force_is_exact(data):
    Xtr, Q, gt = data
    idx, dist, comps = baselines.brute_force(Xtr, Q, k=3)
    ref = np.argsort(
        np.linalg.norm(np.asarray(Q)[:, None] - np.asarray(Xtr)[None], axis=-1), axis=1
    )[:, :3]
    assert (np.asarray(idx) == ref).all()
    assert (np.asarray(comps) == Xtr.shape[0]).all()


def test_ivf_flat_high_recall(data):
    Xtr, Q, gt = data
    ivf = baselines.IVFFlat.build(Xtr, num_clusters=16, metric="euclidean")
    idx, _, comps = ivf.search(Q, k=1, nprobe=6)
    rec = float(np.mean(np.asarray(idx)[:, 0] == gt[:, 0]))
    assert rec >= 0.9, rec
    assert float(np.mean(np.asarray(comps))) < Xtr.shape[0]


def test_ivf_pq_with_rerank(data):
    Xtr, Q, gt = data
    pq = baselines.IVFPQ.build(Xtr, num_clusters=16, M=4, ksub=16)
    idx, _, _ = pq.search(Q, k=1, nprobe=6, rerank=16)
    rec = float(np.mean(np.asarray(idx)[:, 0] == gt[:, 0]))
    assert rec >= 0.75, rec


def test_nsw_graph_search(data):
    Xtr, Q, gt = data
    nsw = baselines.NSWGraph.build(Xtr, degree=10, random_links=4)
    idx, _, comps = nsw.search(Q, k=1, ef=24, max_steps=128)
    rec = float(np.mean(np.asarray(idx)[:, 0] == gt[:, 0]))
    assert rec >= 0.85, rec
    assert float(np.mean(np.asarray(comps))) < Xtr.shape[0]


def test_embedding_losses():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    cfg = embed_lib.EmbedConfig(in_dim=8, out_dim=4, hidden=(16,), steps=5)
    import jax

    params = embed_lib.init_params(jax.random.PRNGKey(0), cfg)
    d = embed_lib.embed_dist(params, X[:10], X[10:20])
    assert d.shape == (10,)
    assert (np.asarray(d) >= 0).all()
    tl = embed_lib.triangle_loss(params, X[:10], X[10:20], X[20:30], 2.0)
    assert float(tl) >= 0.0
    tl_inf = embed_lib.triangle_loss(params, X[:10], X[10:20], X[20:30], math.inf)
    assert float(tl_inf) >= 0.0
