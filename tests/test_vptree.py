"""VP trees: Algorithm 1 build, Theorem 1 descent, Algorithm 2 best-first."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import metrics, qmetric, vptree


def _data(n=80, d=8, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    D = np.array(metrics.pairwise(jnp.asarray(X), jnp.asarray(X)))
    np.fill_diagonal(D, 0.0)
    return X, jnp.asarray((D + D.T) / 2)


def test_build_invariants():
    X, D = _data()
    tree = vptree.build_vptree(X, metric="euclidean", seed=0)
    assert tree.num_nodes == X.shape[0]  # every point is a vantage exactly once
    v = np.sort(np.asarray(tree.vantage))
    assert (v == np.arange(X.shape[0])).all()
    # children are valid node ids
    for c in (np.asarray(tree.left), np.asarray(tree.right)):
        assert ((c == -1) | ((c >= 0) & (c < tree.num_nodes))).all()


def test_theorem1_descent_depth_bound_and_exactness():
    """On an ultrametric space, dataset-point queries find themselves in
    <= depth comparisons (Theorem 1)."""
    X, D = _data(100, seed=1)
    Dinf = qmetric.canonical_projection(D, math.inf)
    tree = vptree.build_vptree(D=np.asarray(Dinf), seed=0)
    # queries ARE dataset rows of the ultrametric -> exact self-match
    rows = Dinf[:16]
    bi, bd, comps = vptree.descend_infty(tree, rows)
    assert (np.asarray(comps) <= tree.depth).all()
    assert np.allclose(np.asarray(bd), 0.0, atol=1e-6)
    assert (np.asarray(bi) == np.arange(16)).all()


def test_descent_close_to_log2n():
    """Fig. 2/10: mean comparisons stay near log2(n)."""
    X, D = _data(128, seed=2)
    Dinf = qmetric.canonical_projection(D, math.inf)
    tree = vptree.build_vptree(D=np.asarray(Dinf), seed=0)
    _, _, comps = vptree.descend_infty(tree, Dinf[:64])
    assert float(np.mean(np.asarray(comps))) <= 3.0 * math.log2(128)


def test_best_first_exact_against_brute_force():
    """Algorithm 2 with full budget returns the true NN for a q-metric."""
    X, D = _data(90, seed=3)
    for q in (2.0, 8.0):
        Dq = qmetric.canonical_projection(D, q)
        tree = vptree.build_vptree(D=np.asarray(Dq), seed=1)
        rng = np.random.default_rng(4)
        Qv = rng.normal(size=(10, X.shape[1])).astype(np.float32)
        rows = metrics.pairwise(jnp.asarray(Qv), jnp.asarray(X))
        Eq = qmetric.project_with_queries(D, rows, q)
        ki, kd, comps = vptree.search_best_first(tree, Eq, q=q, k=1)
        assert (np.asarray(ki)[:, 0] == np.argmin(np.asarray(Eq), axis=1)).all()


def test_best_first_matches_reference_recursion():
    X, D = _data(60, seed=5)
    q = 2.0
    Dq = qmetric.canonical_projection(D, q)
    tree = vptree.build_vptree(D=np.asarray(Dq), seed=2)
    rng = np.random.default_rng(6)
    Qv = rng.normal(size=(5, X.shape[1])).astype(np.float32)
    rows = metrics.pairwise(jnp.asarray(Qv), jnp.asarray(X))
    Eq = np.asarray(qmetric.project_with_queries(D, rows, q))
    ki, kd, comps = vptree.search_best_first(tree, jnp.asarray(Eq), q=q, k=1)
    for b in range(5):
        ridx, rd, rc = vptree.search_reference(tree, Eq[b], q=q)
        assert int(ki[b, 0]) == ridx
        assert int(comps[b]) == rc, "comparison counts must match Algorithm 2"


def test_knn_and_budget():
    X, D = _data(120, seed=7)
    tree = vptree.build_vptree(X, metric="euclidean", seed=3)
    rng = np.random.default_rng(8)
    Qv = jnp.asarray(rng.normal(size=(6, X.shape[1])).astype(np.float32))
    ki, kd, comps = vptree.search_best_first(
        tree, Qv, q=1.0, k=5, X=jnp.asarray(X), metric="euclidean"
    )
    # exact 5-NN vs brute force (euclidean is a 1-metric -> exact)
    Dq = np.array(metrics.pairwise(Qv, jnp.asarray(X)))
    ref = np.argsort(Dq, axis=1)[:, :5]
    assert (np.sort(np.asarray(ki), axis=1) == np.sort(ref, axis=1)).all()
    # budgeted search visits no more than the budget
    _, _, comps_b = vptree.search_best_first(
        tree, Qv, q=1.0, k=1, X=jnp.asarray(X), metric="euclidean",
        max_comparisons=17,
    )
    assert (np.asarray(comps_b) <= 17).all()


def test_fewer_comparisons_with_larger_q():
    """(C1): monotone-ish decrease of comparisons in q (mean over queries)."""
    X, D = _data(150, seed=9)
    rng = np.random.default_rng(10)
    Qv = rng.normal(size=(20, X.shape[1])).astype(np.float32)
    rows = metrics.pairwise(jnp.asarray(Qv), jnp.asarray(X))
    means = []
    for q in [1.0, 4.0, 16.0]:
        Dq = qmetric.canonical_projection(D, q)
        tree = vptree.build_vptree(D=np.asarray(Dq), seed=4)
        Eq = qmetric.project_with_queries(D, rows, q)
        _, _, comps = vptree.search_best_first(tree, Eq, q=q, k=1)
        means.append(float(np.mean(np.asarray(comps))))
    assert means[-1] < means[0], means


# ---------------------------------------------------------------------------
# select="spread" (Yianilos variance heuristic, Remark 2)
# ---------------------------------------------------------------------------

def test_build_spread_invariants_and_exact_search():
    """Spread-selected vantage points must keep Algorithm 1's invariants and
    exact-search behavior (euclidean is a 1-metric -> full-budget best-first
    is exact)."""
    X, D = _data(90, seed=11)
    tree = vptree.build_vptree(X, metric="euclidean", seed=0, select="spread")
    assert tree.num_nodes == X.shape[0]
    v = np.sort(np.asarray(tree.vantage))
    assert (v == np.arange(X.shape[0])).all()  # every point a vantage once
    for c in (np.asarray(tree.left), np.asarray(tree.right)):
        assert ((c == -1) | ((c >= 0) & (c < tree.num_nodes))).all()
    rng = np.random.default_rng(12)
    Qv = jnp.asarray(rng.normal(size=(8, X.shape[1])).astype(np.float32))
    ki, kd, comps = vptree.search_best_first(
        tree, Qv, q=1.0, k=3, X=jnp.asarray(X), metric="euclidean"
    )
    ref = np.argsort(np.array(metrics.pairwise(Qv, jnp.asarray(X))), axis=1)[:, :3]
    assert (np.sort(np.asarray(ki), axis=1) == np.sort(ref, axis=1)).all()


def test_build_spread_differs_from_random_but_same_contract():
    """The heuristic actually changes vantage choices (it isn't a silent
    fall-through to random) while preserving the node-count contract."""
    X, D = _data(120, seed=13)
    t_rand = vptree.build_vptree(X, metric="euclidean", seed=5, select="random")
    t_spread = vptree.build_vptree(X, metric="euclidean", seed=5, select="spread")
    assert t_rand.num_nodes == t_spread.num_nodes == X.shape[0]
    assert (np.asarray(t_rand.vantage) != np.asarray(t_spread.vantage)).any()


# ---------------------------------------------------------------------------
# precomputed-D build + search (canonical-projection mode)
# ---------------------------------------------------------------------------

def test_spread_build_on_precomputed_projection_descend_exact():
    """select='spread' over a precomputed canonical projection D_inf: the
    Theorem-1 descent must still find dataset-row queries exactly within
    depth comparisons."""
    X, D = _data(100, seed=14)
    Dinf = qmetric.canonical_projection(D, math.inf)
    tree = vptree.build_vptree(D=np.asarray(Dinf), seed=3, select="spread")
    rows = Dinf[:12]
    bi, bd, comps = vptree.descend_infty(tree, rows)
    assert (np.asarray(comps) <= tree.depth).all()
    assert np.allclose(np.asarray(bd), 0.0, atol=1e-6)
    assert (np.asarray(bi) == np.arange(12)).all()


def test_precomputed_D_search_matches_reference_on_spread_tree():
    """Best-first over query->dataset projection rows (X=None) must agree
    with the literal recursive reference, including comparison counts."""
    X, D = _data(60, seed=15)
    q = 4.0
    Dq = qmetric.canonical_projection(D, q)
    tree = vptree.build_vptree(D=np.asarray(Dq), seed=4, select="spread")
    rng = np.random.default_rng(16)
    Qv = rng.normal(size=(5, X.shape[1])).astype(np.float32)
    rows = metrics.pairwise(jnp.asarray(Qv), jnp.asarray(X))
    Eq = np.asarray(qmetric.project_with_queries(D, rows, q))
    ki, kd, comps = vptree.search_best_first(tree, jnp.asarray(Eq), q=q, k=1)
    assert (np.asarray(ki)[:, 0] == np.argmin(Eq, axis=1)).all()
    for b in range(5):
        ridx, rd, rc = vptree.search_reference(tree, Eq[b], q=q)
        assert int(ki[b, 0]) == ridx
        assert int(comps[b]) == rc


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(10, 60))
def test_property_descend_comparisons_bounded_by_depth(seed, n):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 4)).astype(np.float32)
    D = np.array(metrics.pairwise(jnp.asarray(X), jnp.asarray(X)))
    np.fill_diagonal(D, 0.0)
    Dinf = qmetric.canonical_projection(jnp.asarray(D), math.inf)
    tree = vptree.build_vptree(D=np.asarray(Dinf), seed=seed)
    _, _, comps = vptree.descend_infty(tree, Dinf[: min(8, n)])
    assert (np.asarray(comps) <= tree.depth).all()


# ---------------------------------------------------------------------------
# DFS stack guard (fixed-capacity stack must not silently corrupt on deep
# unbalanced trees — pushes are bounds-checked and surfaced as `truncated`)
# ---------------------------------------------------------------------------

def test_deep_unbalanced_tree_stack_guard_default_cap():
    """All-duplicate points build a maximally unbalanced chain (every split
    sends the whole remainder outside).  The default stack cap must absorb
    it: correct result, truncated=False."""
    n = 40
    X = np.zeros((n, 4), np.float32)  # all identical -> depth-n right chain
    tree = vptree.build_vptree(X, metric="euclidean", seed=0)
    assert tree.depth >= n - 1  # the pathological shape actually happened
    Q = jnp.zeros((3, 4), jnp.float32)
    ki, kd, comps, trunc = vptree.search_best_first(
        tree, Q, q=2.0, k=1, X=jnp.asarray(X), metric="euclidean",
        with_truncated=True,
    )
    assert np.allclose(np.asarray(kd), 0.0, atol=1e-6)
    assert not np.asarray(trunc).any()


def test_stack_overflow_is_flagged_not_silent():
    """With a deliberately tiny stack, overflow must raise the truncated
    flag instead of clamping `stack.at[sp]` onto a live slot."""
    X, _ = _data(64, seed=21)
    tree = vptree.build_vptree(X, metric="euclidean", seed=7)
    rng = np.random.default_rng(22)
    Q = jnp.asarray(rng.normal(size=(8, X.shape[1])).astype(np.float32))
    ki, kd, comps, trunc = vptree._best_first_impl(
        (tree.vantage, tree.mu, tree.left, tree.right),
        jnp.asarray(X),
        Q,
        jnp.asarray(tree.num_nodes, jnp.int32),
        "euclidean",
        2.0,
        1,
        1,  # stack_cap=1: any branch with two viable children overflows
        None,
    )
    assert np.asarray(trunc).any()
    # results remain well-formed even when truncated
    assert (np.asarray(ki)[:, 0] >= 0).all()


def test_with_truncated_flag_api_default_false():
    X, _ = _data(50, seed=23)
    tree = vptree.build_vptree(X, metric="euclidean", seed=8)
    Q = jnp.asarray(np.random.default_rng(24).normal(size=(4, X.shape[1]))
                    .astype(np.float32))
    out3 = vptree.search_best_first(tree, Q, q=2.0, k=2, X=jnp.asarray(X))
    assert len(out3) == 3
    out4 = vptree.search_best_first(
        tree, Q, q=2.0, k=2, X=jnp.asarray(X), with_truncated=True)
    assert len(out4) == 4 and not np.asarray(out4[3]).any()
