"""Per-architecture smoke tests (reduced configs, one fwd/train step on CPU)
+ family-specific correctness (decode==forward, FM algebra, GCN vs dense)."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import gnn, params as plib, recsys, sampler, transformer

LM_ARCHS = [
    "smollm-135m", "deepseek-coder-33b", "gemma-2b",
    "qwen3-moe-235b-a22b", "deepseek-v3-671b",
]
RECSYS_ARCHS = ["fm", "deepfm", "xdeepfm", "autoint"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = configs.get_reduced(arch)
    decls = transformer.lm_decls(cfg)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    loss, metrics = transformer.lm_loss(p, {"tokens": toks}, cfg)
    assert np.isfinite(float(loss))
    logits, h, aux = transformer.lm_forward(p, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    grads = jax.grad(lambda p: transformer.lm_loss(p, {"tokens": toks}, cfg)[0])(p)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_matches_forward(arch):
    """Greedy decode through the cache must produce the same logits as a
    full forward at each position (teacher forcing)."""
    cfg = configs.get_reduced(arch)
    decls = transformer.lm_decls(cfg)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = transformer.lm_forward(p, toks, cfg)
    cache = transformer.init_cache(cfg, B, S)
    step_logits = []
    for t in range(S):
        lg, cache = transformer.lm_decode_step(
            p, cache, toks[:, t : t + 1], jnp.int32(t), cfg
        )
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits), atol=2e-2, rtol=2e-2
    )


def test_mla_absorb_equals_naive():
    cfg = configs.get_reduced("deepseek-v3-671b")
    decls = transformer.lm_decls(cfg)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    B, S = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    caches = []
    for absorb in (False, True):
        cache = transformer.init_cache(cfg, B, S)
        outs = []
        for t in range(S):
            lg, cache = transformer.lm_decode_step(
                p, cache, toks[:, t : t + 1], jnp.int32(t), cfg, mla_absorb=absorb
            )
            outs.append(np.asarray(lg))
        caches.append(np.stack(outs))
    np.testing.assert_allclose(caches[0], caches[1], atol=1e-3, rtol=1e-3)


def test_lm_prefill_matches_decode_path():
    cfg = configs.get_reduced("smollm-135m")
    decls = transformer.lm_decls(cfg)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size)
    logits_pref, cache = transformer.lm_prefill(p, toks, cfg, max_len=S + 4)
    full_logits, _, _ = transformer.lm_forward(p, toks, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_pref[:, 0]), np.asarray(full_logits[:, -1]),
        atol=2e-2, rtol=2e-2,
    )
    # continue decoding from the prefilled cache
    nxt = jnp.argmax(full_logits[:, -1:], -1).astype(jnp.int32)
    lg, _ = transformer.lm_decode_step(p, cache, nxt, jnp.int32(S), cfg)
    assert np.isfinite(np.asarray(lg)).all()


def test_full_configs_match_published_param_counts():
    expected = {
        "smollm-135m": (0.12e9, 0.15e9),
        "deepseek-coder-33b": (32e9, 34e9),
        "gemma-2b": (2.3e9, 2.7e9),
        "qwen3-moe-235b-a22b": (230e9, 240e9),
        "deepseek-v3-671b": (660e9, 685e9),
    }
    for arch, (lo, hi) in expected.items():
        n = plib.param_count(transformer.lm_decls(configs.get(arch)))
        assert lo <= n <= hi, (arch, n)


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def test_gcn_matches_dense_adjacency():
    """segment_sum message passing == dense normalized adjacency matmul."""
    cfg = configs.get_reduced("gcn-cora")
    n, d, E = 30, 12, 90
    rng = np.random.default_rng(0)
    edges = rng.integers(0, n, size=(2, E)).astype(np.int32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    decls = gnn.gcn_decls(cfg, d)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    w, b = p["layers"][0]["w"], p["layers"][0]["b"]
    out = gnn.gcn_conv(jnp.asarray(x), jnp.asarray(edges), w, b, n_nodes=n)
    # dense reference
    deg = np.zeros(n)
    for dst in edges[1]:
        deg[dst] += 1
    deg = np.maximum(deg, 1.0)
    A = np.zeros((n, n), np.float32)
    for s, t in edges.T:
        A[t, s] += 1.0 / np.sqrt(deg[s] * deg[t])
    ref = A @ (x @ np.asarray(w) + np.asarray(b))
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4)


def test_gcn_smoke_and_padding_mask():
    cfg = configs.get_reduced("gcn-cora")
    n, d = 40, 10
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    edges = rng.integers(0, n, size=(2, 100)).astype(np.int32)
    edges[:, 90:] = -1  # padding must be ignored
    decls = gnn.gcn_decls(cfg, d)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    labels = jnp.asarray(rng.integers(0, cfg.num_classes, size=n))
    loss1, _ = gnn.gcn_loss(p, {"x": x, "edges": jnp.asarray(edges), "labels": labels}, cfg)
    loss2, _ = gnn.gcn_loss(p, {"x": x, "edges": jnp.asarray(edges[:, :90]), "labels": labels}, cfg)
    assert abs(float(loss1) - float(loss2)) < 1e-5


def test_neighbor_sampler_invariants():
    g = sampler.random_graph(300, 6, seed=0)
    rng = np.random.default_rng(0)
    sub = sampler.sample_subgraph(g, np.arange(8), (4, 3), rng=rng)
    edges = sub["edges"]
    valid = edges[0] >= 0
    assert (edges[0][valid] < sub["num_nodes"]).all()
    assert (edges[1][valid] < sub["num_nodes"]).all()
    # every edge exists in the original graph
    node_index = sub["node_index"]
    for s, t in edges.T[valid[: edges.shape[1]]][:50]:
        gsrc, gdst = node_index[s], node_index[t]
        assert gsrc in g.neighbors(int(gdst))


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    cfg = configs.get_reduced(arch)
    decls = recsys.recsys_decls(cfg)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(np.stack(
        [rng.integers(0, v, size=6) for v in cfg.vocabs[: cfg.n_sparse]], axis=1
    ).astype(np.int32))
    labels = jnp.asarray(rng.integers(0, 2, size=6).astype(np.float32))
    loss, m = recsys.recsys_loss(p, {"ids": ids, "labels": labels}, cfg)
    assert np.isfinite(float(loss))
    logits = recsys.recsys_forward(p, ids, cfg)
    assert logits.shape == (6,)


def test_fm_sum_square_trick_matches_pairwise():
    """0.5((sum v)^2 - sum v^2) == sum_{i<j} <v_i, v_j> (Rendle's identity)."""
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(3, 7, 4)).astype(np.float32)
    fast = recsys._fm_pairwise(jnp.asarray(emb))
    slow = np.zeros(3)
    for b in range(3):
        for i in range(7):
            for j in range(i + 1, 7):
                slow[b] += emb[b, i] @ emb[b, j]
    np.testing.assert_allclose(np.asarray(fast), slow, atol=1e-4)


def test_retrieval_topk_matches_brute_force():
    cfg = configs.get_reduced("fm")
    decls = recsys.recsys_decls(cfg)
    p = plib.init_params(jax.random.PRNGKey(0), decls)
    rng = np.random.default_rng(3)
    ids = jnp.asarray(np.stack(
        [rng.integers(0, v, size=2) for v in cfg.vocabs[: cfg.n_sparse]], axis=1
    ).astype(np.int32))
    cand = jnp.asarray(rng.normal(size=(200, cfg.embed_dim)).astype(np.float32))
    u = recsys.user_embedding(p, ids, cfg)
    s, i = recsys.retrieval_score(u, cand, k=7)
    ref = np.argsort(-(np.asarray(u) @ np.asarray(cand).T), axis=1)[:, :7]
    assert (np.asarray(i) == ref).all()


def test_infinity_search_config_registry():
    cfg = configs.get("infinity-search")
    assert cfg.metric == "euclidean"
    assert configs.family("infinity-search") == "search"
