"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.bag.bag import embedding_bag_pallas
from repro.kernels.bag.ref import embedding_bag_ref
from repro.kernels.pdist.pdist import pdist_pallas
from repro.kernels.pdist.ref import pdist_ref
from repro.kernels.qpath.qpath import qpath_matmul_pallas
from repro.kernels.qpath.ref import qpath_matmul_ref

SHAPES = [(32, 48, 16), (128, 128, 128), (130, 70, 257), (8, 300, 9)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("mode", ["minplus", "minmax", "logminplus"])
def test_qpath_shapes(shape, mode):
    m, k, n = shape
    rng = np.random.default_rng(hash((shape, mode)) % 2**31)
    A = jnp.asarray(rng.uniform(0.05, 4.0, size=(m, k)).astype(np.float32))
    B = jnp.asarray(rng.uniform(0.05, 4.0, size=(k, n)).astype(np.float32))
    out = qpath_matmul_pallas(A, B, mode=mode)
    ref = qpath_matmul_ref(A, B, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_qpath_inf_identity_padding():
    """+inf entries (masked edges) must pass through the semiring."""
    A = jnp.asarray([[0.0, jnp.inf], [1.0, 2.0]], jnp.float32)
    B = jnp.asarray([[0.5, jnp.inf], [jnp.inf, 1.0]], jnp.float32)
    for mode in ("minplus", "minmax", "logminplus"):
        out = qpath_matmul_pallas(A, B, mode=mode)
        ref = qpath_matmul_ref(A, B, mode=mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("shape", [(40, 56, 20), (128, 128, 64), (33, 257, 100)])
@pytest.mark.parametrize(
    "metric", ["sqeuclidean", "euclidean", "cosine", "dot", "manhattan", "chebyshev"]
)
def test_pdist_shapes(shape, metric):
    m, n, d = shape
    rng = np.random.default_rng(hash((shape, metric)) % 2**31)
    X = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    out = pdist_pallas(X, Y, metric=metric)
    ref = pdist_ref(X, Y, metric=metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4)


def test_pdist_bf16_inputs():
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(32, 64)), jnp.bfloat16)
    Y = jnp.asarray(rng.normal(size=(48, 64)), jnp.bfloat16)
    out = pdist_pallas(X, Y, metric="sqeuclidean")
    ref = pdist_ref(X.astype(jnp.float32), Y.astype(jnp.float32), metric="sqeuclidean")
    assert np.median(np.abs(np.asarray(out) - np.asarray(ref))) < 0.5


@pytest.mark.parametrize("combine", ["sum", "mean"])
@pytest.mark.parametrize("weighted", [False, True])
def test_bag(combine, weighted):
    rng = np.random.default_rng(5)
    table = jnp.asarray(rng.normal(size=(300, 24)).astype(np.float32))
    ids = rng.integers(0, 300, size=(10, 6)).astype(np.int32)
    ids[3, 2:] = -1
    ids = jnp.asarray(ids)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(10, 6)).astype(np.float32)) if weighted else None
    out = embedding_bag_pallas(table, ids, w, combine=combine)
    ref = embedding_bag_ref(table, ids, w, combine=combine)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40), k=st.integers(1, 40), n=st.integers(1, 40),
    seed=st.integers(0, 999),
)
def test_property_qpath_minmax(m, k, n, seed):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.uniform(0, 3, size=(m, k)).astype(np.float32))
    B = jnp.asarray(rng.uniform(0, 3, size=(k, n)).astype(np.float32))
    out = qpath_matmul_pallas(A, B, mode="minmax")
    ref = qpath_matmul_ref(A, B, mode="minmax")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 12), s=st.integers(1, 9), v=st.integers(4, 200),
    d=st.integers(1, 33), seed=st.integers(0, 999),
)
def test_property_bag_sum(b, s, v, d, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, v, size=(b, s)).astype(np.int32))
    out = embedding_bag_pallas(table, ids)
    ref = embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
