"""Algorithms 4-7: canonical-projection compute kernel timings.

Compares the TPU-oriented path-doubling semiring matmul (jnp, row-blocked)
against the literal pivot-sequential Floyd-Warshall on CPU, plus the Pallas
kernel in interpret mode (correctness-path only on this host — wall-times
for the Pallas kernel are NOT meaningful on CPU; its value is the VMEM
tiling exercised by the TPU target).
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.core import metrics, qmetric
from repro.data import synthetic
from benchmarks.common import timeit


def run(ns=(256, 512, 1024), verbose=True):
    out = []
    for n in ns:
        X = synthetic.make("clustered", n, d=16, seed=0)
        D = np.array(metrics.pairwise(jnp.asarray(X), jnp.asarray(X)))
        np.fill_diagonal(D, 0.0)
        D = jnp.asarray(D)
        for q in (2.0, math.inf):
            t_pd = timeit(lambda: qmetric.canonical_projection(D, q, row_block=64))
            t_fw = timeit(lambda: qmetric.floyd_warshall_reference(D, q))
            rec = {
                "n": n, "q": q,
                "path_doubling_ms": round(t_pd * 1e3, 1),
                "floyd_warshall_ms": round(t_fw * 1e3, 1),
                "sweeps": max(1, math.ceil(math.log2(n - 1))),
            }
            out.append(rec)
            if verbose:
                print(
                    f"  n={n} q={q}: path-doubling={rec['path_doubling_ms']}ms "
                    f"({rec['sweeps']} sweeps) vs floyd-warshall={rec['floyd_warshall_ms']}ms"
                )
    return out


if __name__ == "__main__":
    run()
