"""Fig. 3 / App. F.2: search with the exact canonical projection E_q.

n = 1000 points (the paper's subset size), q sweep, multiple
dissimilarities.  Reports comparisons / Recall@1 / RankOrder@10 — the
theoretical-properties experiment: recall is exactly 1.0 for finite q
(Prop. 1) and degrades only at q = inf (spurious neighbors).
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.core import baselines, metrics, qmetric, vptree
from repro.data import synthetic
from benchmarks.common import ground_truth, rank_order_at_k, recall_at_k

QS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, math.inf)
DATASETS = (
    ("fashion_like", "euclidean"),
    ("fashion_like", "cosine"),
    ("glove_like", "cosine"),
    ("sparse_binary", "jaccard"),
)


def run(n=1000, n_queries=100, qs=QS, datasets=DATASETS[:2], verbose=True):
    out = []
    for ds_name, metric in datasets:
        X = synthetic.make(ds_name, n + n_queries, seed=0)
        Xtr, Q = X[:n], X[n : n + n_queries]
        D = np.array(metrics.pairwise(jnp.asarray(Xtr), jnp.asarray(Xtr), metric=metric))
        np.fill_diagonal(D, 0.0)
        D = jnp.asarray((D + D.T) / 2)
        rows = metrics.pairwise(jnp.asarray(Q), jnp.asarray(Xtr), metric=metric)
        gt, _ = ground_truth(jnp.asarray(Xtr), jnp.asarray(Q), k=10, metric=metric)
        for q in qs:
            Dq = qmetric.canonical_projection(D, q, row_block=16)
            Eq = qmetric.project_with_queries(D, rows, q, row_block=16)
            tree = vptree.build_vptree(D=np.asarray(Dq), seed=0)
            ki, kd, comps = vptree.search_best_first(tree, Eq, q=q, k=10)
            rec = {
                "dataset": ds_name, "metric": metric, "q": q,
                "mean_comparisons": float(np.mean(np.asarray(comps))),
                "recall@1": recall_at_k(np.asarray(ki), gt, 1),
                "rank_order@10": rank_order_at_k(np.asarray(ki), gt, 10),
            }
            out.append(rec)
            if verbose:
                print(
                    f"  {ds_name}/{metric} q={q}: comps={rec['mean_comparisons']:.0f} "
                    f"R@1={rec['recall@1']:.3f} RO@10={rec['rank_order@10']:.2f}"
                )
    return out


if __name__ == "__main__":
    run()
