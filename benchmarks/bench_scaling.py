"""Fig. 5/23 / App. F.6: scaling in n with a Phi trained once on a fixed
subset and applied inductively (the paper's Deep1B protocol, scaled down).

The inductive part reuses one trained Phi across growing corpora (embed +
re-tree only).  At each n a registry sweep (``core/index``) runs the other
engines through the same uniform contract, so per-n comparison counts are
directly comparable across methods without per-baseline glue.
"""
from __future__ import annotations

import math
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_scaling.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import embedding as embed_lib, index as index_lib, vptree
from repro.core.search import IndexConfig, InfinityIndex
from repro.data import synthetic
from benchmarks.common import ground_truth, recall_at_k

# registry engines swept alongside the inductive index at every n
SWEEP = (
    ("ivf_flat", {"num_clusters": 32, "nprobe": 4}),
    ("nsw", {"degree": 12, "ef": 32, "max_steps": 96}),
)


def run(ns=(1000, 3000, 8000), n_queries=128, verbose=True):
    nmax = max(ns)
    X = synthetic.make("manifold", nmax + n_queries, seed=0)
    Q = jnp.asarray(X[nmax:])
    # Phi trained ONCE on the smallest corpus subset
    cfg = IndexConfig(
        q=8.0, proj_sample=1000, train_steps=800, embed_dim=24, seed=0
    )
    base = InfinityIndex.build(jnp.asarray(X[: ns[0]]), cfg)
    phi = base.phi_params
    out = []
    for n in ns:
        Xn = jnp.asarray(X[:n])
        gt, _ = ground_truth(Xn, Q, k=10)
        t0 = time.perf_counter()
        Z = embed_lib.apply(phi, Xn)
        tree = vptree.build_vptree(np.asarray(Z), metric="euclidean", seed=0)
        build_s = time.perf_counter() - t0
        Zq = embed_lib.apply(phi, Q)
        ki, _, comps = vptree.search_best_first(
            tree, Zq, q=cfg.q, k=10, X=Z, metric="euclidean",
            max_comparisons=max(64, int(8 * math.log2(n) ** 2)),
        )
        rec = {
            "n": n,
            "build_s": round(build_s, 2),
            "mean_comparisons": float(np.mean(np.asarray(comps))),
            "frac_of_n": float(np.mean(np.asarray(comps))) / n,
            "recall@1": recall_at_k(np.asarray(ki), np.asarray(gt), 1),
            "recall@10": recall_at_k(np.asarray(ki), np.asarray(gt), 10),
        }
        # uniform-contract engine sweep at the same n
        for key, ecfg in SWEEP:
            engine = index_lib.build(key, Xn, dict(ecfg))
            eki, _, ecomps = engine.search(Q, k=10)
            rec[f"{key}_mean_comparisons"] = float(np.mean(np.asarray(ecomps)))
            rec[f"{key}_recall@10"] = recall_at_k(np.asarray(eki), np.asarray(gt), 10)
        out.append(rec)
        if verbose:
            sweep = " ".join(
                f"{key}:comps={rec[f'{key}_mean_comparisons']:.0f}" for key, _ in SWEEP
            )
            print(
                f"  n={n}: comps={rec['mean_comparisons']:.0f} "
                f"({100*rec['frac_of_n']:.1f}% of n) R@1={rec['recall@1']:.3f} "
                f"R@10={rec['recall@10']:.3f} build={rec['build_s']}s  [{sweep}]"
            )
    # sub-linear check: comparisons growth slower than n growth
    if len(out) >= 2:
        growth_c = out[-1]["mean_comparisons"] / out[0]["mean_comparisons"]
        growth_n = out[-1]["n"] / out[0]["n"]
        if verbose:
            print(f"  comparisons grew {growth_c:.1f}x for {growth_n:.1f}x points (sub-linear: {growth_c < growth_n})")
    return out


if __name__ == "__main__":
    run()
