"""Benchmark driver: one harness per paper table/figure (DESIGN.md §8).

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes

Prints per-benchmark result lines followed by a ``name,us_per_call,derived``
CSV summary.  Roofline terms come from launch/dryrun.py (separate process —
it forces 512 host devices).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    quick = args.quick

    from benchmarks import (
        bench_ann_compare,
        bench_depth_bound,
        bench_fault,
        bench_filtered,
        bench_infinity,
        bench_learned_search,
        bench_load,
        bench_projection_search,
        bench_qpath_kernel,
        bench_quant,
        bench_scaling,
        bench_serving,
        bench_streaming,
        bench_topk_kernel,
        bench_two_stage,
    )

    suite = [
        ("depth_bound", lambda: bench_depth_bound.run(
            ns=(100, 300, 1000) if quick else (100, 300, 1000, 3000))),
        ("projection_search", lambda: bench_projection_search.run(
            n=400 if quick else 1000, n_queries=50 if quick else 100,
            qs=(1.0, 4.0, 16.0, float("inf")) if quick
            else (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, float("inf")))),
        ("learned_search", lambda: bench_learned_search.run(
            n=1500 if quick else 4000, train_steps=300 if quick else 800)),
        ("two_stage", lambda: bench_two_stage.run(
            n=1200 if quick else 3000)),
        ("scaling", lambda: bench_scaling.run(
            ns=(500, 1500) if quick else (1000, 3000, 8000))),
        ("ann_compare", lambda: bench_ann_compare.run(
            n=1200 if quick else 3000, train_steps=300 if quick else 800)),
        ("ann_compare_jaccard", lambda: bench_ann_compare.run_jaccard(
            n=800 if quick else 1200, verbose=True)),
        ("qpath_kernel", lambda: bench_qpath_kernel.run(
            ns=(128, 256) if quick else (256, 512, 1024))),
        ("topk_kernel", lambda: bench_topk_kernel.run(
            ns=(4096, 16384) if quick else (4096, 65536, 524288))),
        # engine x shard-count serving sweep (child process: needs >1 device)
        ("serving", lambda: bench_serving.run(
            n=1024 if quick else 2048, batches=4 if quick else 8,
            engines="brute,ivf_flat,nsw" if quick else "brute,ivf_flat,nsw,infinity",
            train_steps=150 if quick else 300)),
        # interleaved upsert/delete/query churn through the live subsystem
        ("streaming", lambda: bench_streaming.run(
            n=512 if quick else 2048, steps=3 if quick else 6,
            ins=48 if quick else 96, dels=24 if quick else 48,
            delta_cap=96 if quick else 256,
            engines="brute,ivf_flat,nsw" if quick else "brute,ivf_flat,nsw,infinity",
            train_steps=150 if quick else 300)),
        # predicate-mask selectivity sweep through every engine
        ("filtered", lambda: bench_filtered.run(
            n=512 if quick else 2048,
            engines="brute,ivf_flat,nsw" if quick else "brute,ivf_flat,nsw,infinity",
            train_steps=150 if quick else 300)),
        # f32 vs int8 corpus codes: recall / QPS / bytes-scanned per engine
        ("quant", lambda: bench_quant.run(
            n=512 if quick else 2048,
            engines="brute,ivf_flat" if quick else "brute,ivf_flat,infinity",
            train_steps=150 if quick else 300)),
        # q-sweep x {best_first, beam} x {f32, int8}: the one-dispatch beam
        # traversal vs the host best-first loop at matched budget
        ("infinity", lambda: bench_infinity.run(
            n=512 if quick else 2048, qbatch=128 if quick else 512,
            qs=(2.0, float("inf")) if quick else (2.0, 4.0, 8.0, float("inf")),
            budget=384 if quick else 1024, rerank=128 if quick else 256,
            train_steps=150 if quick else 300,
            proj_sample=256 if quick else 512, repeats=1 if quick else 3,
            quant_modes=(False,) if quick else (False, True))),
        # open-loop offered-QPS sweep through the async runtime: goodput /
        # shed rate / bounded latency around the measured saturation knee
        ("load", lambda: bench_load.run(
            n=512 if quick else 2048,
            engines="brute" if quick else "brute,ivf_flat",
            duration_s=0.6 if quick else 1.5,
            train_steps=150 if quick else 200)),
        # injected fault-rate sweep: recall/p99 degradation under chaos
        ("fault", lambda: bench_fault.run(
            n=512 if quick else 2048, batches=4 if quick else 8,
            engines="brute,ivf_flat",
            rates=(0.0, 0.2) if quick else (0.0, 0.1, 0.3),
            train_steps=150 if quick else 300)),
    ]
    if args.only:
        suite = [(n, f) for n, f in suite if args.only in n]

    csv = ["name,us_per_call,derived"]
    results = {}
    for name, fn in suite:
        print(f"== {name} ==", flush=True)
        t0 = time.perf_counter()
        rows = fn()
        dt = time.perf_counter() - t0
        results[name] = rows
        derived = ""
        if rows and isinstance(rows, list) and isinstance(rows[0], dict):
            keys = [k for k in ("recall@1", "mean_comparisons", "worst_comparisons")
                    if k in rows[-1]]
            derived = ";".join(f"{k}={rows[-1][k]}" for k in keys)
        csv.append(f"{name},{dt * 1e6:.0f},{derived}")
        print()

    # NOTE: no aggregate bench_results.json dump — every trajectory lives
    # in its stamped per-bench artifact (benchmarks/regress.py rejects
    # unstamped rows; benchmarks/migrate_legacy.py converted the orphan).
    os.makedirs("experiments", exist_ok=True)
    if "topk_kernel" in results:
        # machine-readable perf trajectory for the hot scan path: per-size
        # latency + HBM-byte estimates, regressed against by future PRs
        bench_topk_kernel.write_artifact(results["topk_kernel"])
    if "serving" in results:
        # serving-side trajectory: QPS / p50 / p99 / comparisons per
        # engine x shard count through the registry-driven SearchServer
        bench_serving.write_artifact(results["serving"])
    if "streaming" in results:
        # live-subsystem trajectory: recall-vs-churn + QPS per engine under
        # interleaved upsert/delete/query traces
        bench_streaming.write_artifact(results["streaming"])
    if "filtered" in results:
        # filtered-search trajectory: recall/QPS/comparisons per engine
        # across the predicate selectivity sweep
        bench_filtered.write_artifact(results["filtered"])
    if "quant" in results:
        # quantized-scan trajectory: f32 vs int8 recall/QPS/bytes-scanned —
        # the bytes-moved axis of the perf record
        bench_quant.write_artifact(results["quant"])
    if "infinity" in results:
        # infinity-engine trajectory: recall/QPS/comparisons across the
        # q-sweep in both traversal modes — the beam-speedup evidence
        bench_infinity.write_artifact(results["infinity"])
    if "fault" in results:
        # fault-tolerance trajectory: recall/p99 vs injected fault rate —
        # graceful degradation, measured
        bench_fault.write_artifact(results["fault"])
    if "load" in results:
        # overload trajectory: goodput / shed rate / p99 vs offered QPS
        # with the saturation knee per engine — overload degrades the
        # offered curve, never the admitted one
        bench_load.write_artifact(results["load"])
    print("\n".join(csv))

    # roofline readout: dry-run mesh tables (when experiments/dryrun/ has
    # captures) + the search-program profiles the suite just stamped
    from benchmarks import report_roofline

    report = report_roofline.render_all()
    if report.strip():
        print("\n" + report)


if __name__ == "__main__":
    main()
