"""Filtered-search benchmark: selectivity sweep × engine (DESIGN.md §12).

For each engine and each target selectivity in the sweep, builds the index
with a uniform-[0,1) ``score`` attribute column, filters with
``score <= s`` (passing fraction ≈ s) and records recall@k against a
brute-force oracle over the pre-filtered sub-corpus, QPS and
comparisons/query.  The sweep is where the two filtered-search claims
become measurable: exhaustive engines hold recall 1.0 at every
selectivity (the mask-AND argument), and the infinity engine's
selectivity-scaled rerank keeps recall up as the filter narrows while
comparisons grow sub-linearly in 1/s.

``benchmarks/run.py`` writes the rows to ``experiments/BENCH_filtered.json``
— the filtered-search trajectory regressed against by future PRs — and CI
smoke-runs the standalone entry point next to bench_streaming.

  PYTHONPATH=src python benchmarks/bench_filtered.py \
      --n 1024 --engines brute,ivf_flat,nsw
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_filtered.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

SELECTIVITIES = (0.9, 0.5, 0.1, 0.01)


def run(
    n=2048, qbatch=64, k=10, engines="brute,ivf_flat,nsw,infinity",
    selectivities=SELECTIVITIES, budget=256, rerank=64, train_steps=200,
    proj_sample=512, verbose=True,
):
    """Selectivity sweep; returns one row per (engine, selectivity)."""
    from benchmarks.common import recall_at_k
    from repro.core import index as index_lib
    from repro.data import synthetic
    from repro.launch.serve import default_cfg

    rng = np.random.default_rng(0)
    pool = synthetic.make("manifold", n + qbatch, seed=0)
    corpus, queries = np.asarray(pool[:n]), np.asarray(pool[n:])
    score = rng.uniform(0.0, 1.0, size=n).astype(np.float32)

    # per-selectivity oracles over the pre-filtered sub-corpus (engine-
    # independent: the filtered ground truth IS brute force on the subset)
    oracles = {}
    for s in selectivities:
        mask = score <= s
        if not mask.any():
            continue
        gt = index_lib.build("brute", corpus[mask], {}).search(queries, k=k)
        ids = np.where(mask)[0]
        oracles[s] = (mask, np.where(
            np.asarray(gt.idx) >= 0, ids[np.maximum(np.asarray(gt.idx), 0)], -1
        ))

    rows = []
    for engine in [e.strip() for e in engines.split(",") if e.strip()]:
        cfg = default_cfg(engine, budget=budget, rerank=rerank,
                          train_steps=train_steps, proj_sample=proj_sample)
        t0 = time.perf_counter()
        eng = index_lib.build(engine, corpus, dict(cfg) | {"attrs": {"score": score}})
        build_s = time.perf_counter() - t0
        for s, (mask, gt_idx) in oracles.items():
            flt = {"score": {"range": [None, float(s)]}}
            eng.search(queries, k=k, filter=flt)  # warm-up: compile out
            t0 = time.perf_counter()
            res = eng.search(queries, k=k, filter=flt)
            np.asarray(res.idx)
            query_s = time.perf_counter() - t0
            idx = np.asarray(res.idx)
            leaked = (idx >= 0) & ~mask[np.maximum(idx, 0)]
            rows.append({
                "engine": engine, "n": n, "k": k,
                "selectivity": float(s),
                "n_pass": int(mask.sum()),
                "build_s": round(build_s, 3),
                "recall@k": recall_at_k(idx, gt_idx, k),
                "leaked": int(leaked.sum()),  # non-passing ids returned (must be 0)
                "query_ms": round(query_s * 1e3, 3),
                "qps": round(qbatch / query_s, 1),
                "mean_comparisons": float(np.asarray(res.comparisons).mean()),
            })
            if verbose:
                r = rows[-1]
                print(
                    f"  {engine:10s} sel={s:5.2f} pass={r['n_pass']:5d} "
                    f"recall@{k}={r['recall@k']:.3f} leaked={r['leaked']} "
                    f"qps={r['qps']:8.0f} comps={r['mean_comparisons']:7.0f}"
                )
    return rows


def write_artifact(rows, path="experiments/BENCH_filtered.json") -> None:
    """Single owner of the machine-readable filtered-search artifact
    (also called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--qbatch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,ivf_flat,nsw,infinity")
    ap.add_argument("--selectivities", default="0.9,0.5,0.1,0.01")
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--rerank", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--proj-sample", type=int, default=512)
    args = ap.parse_args()
    write_artifact(run(
        n=args.n, qbatch=args.qbatch, k=args.k, engines=args.engines,
        selectivities=tuple(float(s) for s in args.selectivities.split(",")),
        budget=args.budget, rerank=args.rerank, train_steps=args.train_steps,
        proj_sample=args.proj_sample,
    ))


if __name__ == "__main__":
    main()
