"""Quantized-scan benchmark: f32 vs int8 per engine (DESIGN.md §13).

For each engine in the sweep, builds the index twice over the same corpus —
plain f32 and with the reserved ``quant`` registry cfg key — and records
recall@k against the f32 brute-force oracle, QPS, comparisons/query,
``memory_bytes()`` and a per-query bytes-scanned estimate.  This benchmark
is where the PR's claim becomes measurable: the win is counted in bytes
moved, not comparisons — the int8 first pass reads 1 byte/dim where the
f32 scan reads 4, and the exact pow2-shortlist rerank (the rerank-width
rule) keeps recall@10 >= 0.99 for the exhaustive engines.

``benchmarks/run.py`` writes the rows to ``experiments/BENCH_quant.json``
(stamped with run provenance) and CI smoke-runs the standalone entry point
next to bench_filtered.

  PYTHONPATH=src python benchmarks/bench_quant.py --n 1024 \
      --engines brute,ivf_flat
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_quant.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _bytes_scanned(engine: str, quant: bool, *, n: int, d: int, k: int,
                   mean_comps: float, rerank: int) -> int:
    """Per-query corpus HBM-read estimate (first pass + rerank).

    ``comparisons`` counts scored rows: for a quantized scan engine the
    last ``shortlist_width`` of them are exact f32 re-scores (4 bytes/dim),
    the rest read int8 codes (1 byte/dim); unquantized rows are all f32.
    The infinity engine's comparisons count embedding-space tree visits
    that never touch the corpus, so its estimate covers the rerank stage
    only — the ``rerank`` candidates read f32, or (quantized, when the
    shortlist is narrower) int8 codes plus the f32 shortlist.
    """
    from repro.core import quant as quant_lib

    K = quant_lib.shortlist_width(k, n)
    if engine == "infinity":
        R = max(int(rerank), k)
        if not quant or R <= K:  # prefilter inactive: all R rows read f32
            return int(R * d * 4)
        return int(R * d * 1 + K * d * 4)
    if not quant:
        return int(mean_comps * d * 4)
    code_rows = max(0.0, mean_comps - K)
    return int(code_rows * d * 1 + K * d * 4)


def run(
    n=2048, qbatch=64, k=10, engines="brute,ivf_flat,infinity",
    budget=256, rerank=256, train_steps=200, proj_sample=512, verbose=True,
):
    """f32-vs-int8 sweep; returns one row per (engine, mode)."""
    from benchmarks.common import recall_at_k
    from repro.core import index as index_lib
    from repro.data import synthetic
    from repro.launch.serve import default_cfg

    pool = synthetic.make("manifold", n + qbatch, seed=0)
    corpus, queries = np.asarray(pool[:n]), np.asarray(pool[n:])
    d = corpus.shape[1]
    gt = index_lib.build("brute", corpus, {}).search(queries, k=k)
    gt_idx = np.asarray(gt.idx)

    rows = []
    for engine in [e.strip() for e in engines.split(",") if e.strip()]:
        cfg = default_cfg(engine, budget=budget, rerank=rerank,
                          train_steps=train_steps, proj_sample=proj_sample)
        for quant in (False, True):
            t0 = time.perf_counter()
            eng = index_lib.build(
                engine, corpus, dict(cfg) | ({"quant": True} if quant else {})
            )
            build_s = time.perf_counter() - t0
            eng.search(queries, k=k)  # warm-up: compile out of the timing
            t0 = time.perf_counter()
            res = eng.search(queries, k=k)
            np.asarray(res.idx)
            query_s = time.perf_counter() - t0
            mean_comps = float(np.asarray(res.comparisons).mean())
            rows.append({
                "engine": engine, "mode": "int8" if quant else "f32",
                "n": n, "d": d, "k": k,
                "build_s": round(build_s, 3),
                "recall@k": recall_at_k(np.asarray(res.idx), gt_idx, k),
                "query_ms": round(query_s * 1e3, 3),
                "qps": round(qbatch / query_s, 1),
                "mean_comparisons": mean_comps,
                "memory_bytes": int(eng.memory_bytes()),
                "corpus_bytes": int(corpus.nbytes),
                "code_bytes": int(eng.quant.codes.nbytes) if quant else 0,
                "bytes_scanned": _bytes_scanned(
                    engine, quant, n=n, d=d, k=k, mean_comps=mean_comps,
                    rerank=rerank),
            })
            if verbose:
                r = rows[-1]
                print(
                    f"  {engine:10s} {r['mode']:4s} recall@{k}={r['recall@k']:.3f} "
                    f"qps={r['qps']:8.0f} comps={r['mean_comparisons']:7.0f} "
                    f"scanned={r['bytes_scanned']:>9d}B mem={r['memory_bytes']}"
                )
    return rows


def write_artifact(rows, path="experiments/BENCH_quant.json") -> None:
    """Single owner of the machine-readable quantized-scan artifact
    (also called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--qbatch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,ivf_flat,infinity")
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--rerank", type=int, default=256)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--proj-sample", type=int, default=512)
    args = ap.parse_args()
    write_artifact(run(
        n=args.n, qbatch=args.qbatch, k=args.k, engines=args.engines,
        budget=args.budget, rerank=args.rerank, train_steps=args.train_steps,
        proj_sample=args.proj_sample,
    ))


if __name__ == "__main__":
    main()
