"""Fig. 2/10 + Theorem 1: infinity-metric VP search comparisons vs log2(n).

Builds ultrametric spaces (canonical inf-projection of Gaussian data) for
n in a sweep, searches with the levelized descent and reports worst/mean
comparisons against tree depth and ceil(log2 n).
"""
from __future__ import annotations

import math
import time

import jax.numpy as jnp
import numpy as np

from repro.core import metrics, qmetric, vptree
from repro.data import synthetic


def run(ns=(100, 300, 1000, 3000), n_queries=64, verbose=True):
    rows = []
    for n in ns:
        X = synthetic.make("clustered", n, d=16, seed=0)
        D = np.array(metrics.pairwise(jnp.asarray(X), jnp.asarray(X)))
        np.fill_diagonal(D, 0.0)
        Dinf = qmetric.canonical_projection(jnp.asarray(D), math.inf, row_block=16)
        t0 = time.perf_counter()
        tree = vptree.build_vptree(D=np.asarray(Dinf), seed=0)
        build_s = time.perf_counter() - t0
        rows_q = Dinf[: min(n_queries, n)]
        _, _, comps = vptree.descend_infty(tree, rows_q)
        comps = np.asarray(comps)
        rec = {
            "n": n,
            "depth": tree.depth,
            "log2n": math.ceil(math.log2(n)),
            "mean_comparisons": float(comps.mean()),
            "worst_comparisons": int(comps.max()),
            "build_s": build_s,
        }
        assert rec["worst_comparisons"] <= tree.depth  # Theorem 1
        rows.append(rec)
        if verbose:
            print(
                f"  n={n}: comparisons mean={rec['mean_comparisons']:.1f} "
                f"worst={rec['worst_comparisons']} <= depth={tree.depth} "
                f"(log2 n = {rec['log2n']})"
            )
    return rows


if __name__ == "__main__":
    run()
