"""Render the roofline readout: EXPERIMENTS.md §Roofline mesh tables from
``experiments/dryrun/*.json`` plus the per-program search profiles the
observatory stamps onto ``experiments/BENCH_*.json`` rows (DESIGN.md §17).

  PYTHONPATH=src python -m benchmarks.report_roofline [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

#: stamped artifacts whose rows may carry a ``roofline`` block
SEARCH_ARTIFACTS = ("BENCH_topk.json", "BENCH_serving.json",
                    "BENCH_infinity.json")


def fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    if x >= 1e-6:
        return f"{x*1e6:.0f}us"
    return f"{x*1e9:.0f}ns"


def fmt_n(x: float) -> str:
    """Engineering-notation flops/bytes."""
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")):
        if abs(x) >= div:
            return f"{x / div:.2f}{suf}"
    return f"{x:.0f}"


def load(mesh: str, d: str = "experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        base = os.path.basename(f)[: -len(".json")]
        if base.count("__") != 2:  # skip tagged §Perf variants
            continue
        rows.append(json.load(open(f)))
    return rows


def render(mesh: str = "16x16") -> str:
    rows = load(mesh)
    if not rows:
        return ""
    out = [
        f"| arch | shape | step | mem/dev GiB | t_compute | t_memory | t_collective | dominant | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        out.append(
            "| {arch} | {shape} | {step} | {mem:.1f} | {tc} | {tm} | {tl} | {dom} | {ur} | {frac} |".format(
                arch=r["arch"], shape=r["shape"], step=r["step"],
                mem=r["memory"]["peak_estimate_gib"],
                tc=fmt_t(rf["t_compute_s"]), tm=fmt_t(rf["t_memory_s"]),
                tl=fmt_t(rf["t_collective_s"]), dom=rf["dominant"],
                ur=f"{rf.get('useful_flops_ratio', 0):.2f}",
                frac=f"{rf.get('roofline_fraction', 0):.4f}",
            )
        )
    return "\n".join(out)


def search_profiles(d: str = "experiments") -> list:
    """(source, identity, block) triples from every stamped search
    artifact whose rows carry a ``roofline`` block (error blocks and
    unstamped files are skipped — this is a reader, not a validator)."""
    out = []
    for fname in SEARCH_ARTIFACTS:
        path = os.path.join(d, fname)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        rows = doc.get("rows", []) if isinstance(doc, dict) else []
        for r in rows:
            blocks = r.get("roofline")
            if not blocks:
                continue
            # topk rows hold a dict of variants; serving/infinity one block
            items = (blocks.items() if "program" not in blocks
                     else [(None, blocks)])
            ident = ",".join(
                f"{k}={r[k]}" for k in ("engine", "mode", "dtype", "q",
                                        "shards", "n")
                if k in r
            )
            for _, blk in items:
                if isinstance(blk, dict) and "program" in blk:
                    out.append((fname, ident, blk))
    return out


def render_search(d: str = "experiments") -> str:
    """The search-program roofline table: one line per captured compiled
    program across the stamped BENCH artifacts."""
    profs = search_profiles(d)
    if not profs:
        return ""
    out = [
        "| artifact | cell | program | flops | HBM bytes | AI | predicted | measured | %-of-peak | dominant |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for fname, ident, blk in profs:
        meas = blk.get("t_measured_s")
        pct = blk.get("pct_of_peak")
        out.append(
            "| {f} | {c} | {p} | {fl} | {hb} | {ai:.3f} | {tp} | {tm} | {pk} | {dom} |".format(
                f=fname.removeprefix("BENCH_").removesuffix(".json"),
                c=ident, p=blk["program"],
                fl=fmt_n(blk["flops"]), hb=fmt_n(blk["hbm_bytes"]),
                ai=blk["intensity"], tp=fmt_t(blk["t_predicted_s"]),
                tm=fmt_t(meas) if meas else "-",
                pk=f"{pct:.2%}" if pct else "-",
                dom=blk["dominant"],
            )
        )
    return "\n".join(out)


def render_all(mesh: str = "16x16", d: str = "experiments") -> str:
    parts = []
    mesh_tbl = render(mesh)
    if mesh_tbl:
        parts += [f"## Roofline — dry-run mesh {mesh}", "", mesh_tbl, ""]
    search_tbl = render_search(d)
    if search_tbl:
        parts += ["## Roofline — compiled search programs", "", search_tbl]
    return "\n".join(parts)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--dir", default="experiments")
    args = ap.parse_args()
    print(render_all(args.mesh, args.dir))
