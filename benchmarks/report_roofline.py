"""Render EXPERIMENTS.md §Roofline tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.report_roofline [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    if x >= 1e-6:
        return f"{x*1e6:.0f}us"
    return f"{x*1e9:.0f}ns"


def load(mesh: str, d: str = "experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        base = os.path.basename(f)[: -len(".json")]
        if base.count("__") != 2:  # skip tagged §Perf variants
            continue
        rows.append(json.load(open(f)))
    return rows


def render(mesh: str = "16x16") -> str:
    rows = load(mesh)
    out = [
        f"| arch | shape | step | mem/dev GiB | t_compute | t_memory | t_collective | dominant | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        out.append(
            "| {arch} | {shape} | {step} | {mem:.1f} | {tc} | {tm} | {tl} | {dom} | {ur} | {frac} |".format(
                arch=r["arch"], shape=r["shape"], step=r["step"],
                mem=r["memory"]["peak_estimate_gib"],
                tc=fmt_t(rf["t_compute_s"]), tm=fmt_t(rf["t_memory_s"]),
                tl=fmt_t(rf["t_collective_s"]), dom=rf["dominant"],
                ur=f"{rf.get('useful_flops_ratio', 0):.2f}",
                frac=f"{rf.get('roofline_fraction', 0):.4f}",
            )
        )
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print(render(args.mesh))
