"""Infinity-engine q-sweep: best_first vs beam, f32 vs int8 (DESIGN.md §15).

The paper's headline claim — "higher q => faster search, lower recall" —
crossed with the PR's headline claim — the one-dispatch beam traversal is an
order of magnitude faster than the per-node best-first loop at equal or
better recall.  For each q in the sweep the engine is built once per
(q, quant) cell and searched in both modes over the same query batch;
recorded per row: recall@k against the f32 brute-force oracle, batch p50
latency over ``repeats`` timed runs, QPS, mean comparisons and the beam
plan's static knobs.

``benchmarks/run.py`` writes the rows to ``experiments/BENCH_infinity.json``
(stamped with run provenance) and CI smoke-runs the standalone entry point
next to bench_quant.

  PYTHONPATH=src python benchmarks/bench_infinity.py --n 256 --qbatch 64 \
      --qs 2,inf --train-steps 30 --proj-sample 96
"""
from __future__ import annotations

import argparse
import math
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_infinity.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _parse_qs(spec: str) -> tuple[float, ...]:
    return tuple(
        math.inf if tok.strip() in ("inf", "infinity") else float(tok)
        for tok in spec.split(",") if tok.strip()
    )


def run(
    n=2048, qbatch=512, k=10, qs=(2.0, 4.0, 8.0, math.inf),
    modes="best_first,beam", budget=1024, rerank=256, train_steps=300,
    proj_sample=512, repeats=3, quant_modes=(False, True), verbose=True,
):
    """q x {best_first, beam} x {f32, int8} sweep; one row per cell.

    Telemetry rides along (DESIGN.md §16): each cell's row carries a
    ``stages`` breakdown — traversal / centroid_rank / bucket_scan /
    rerank comparisons and ms — so the q-sweep shows WHERE higher q saves
    work, not just that it does."""
    from benchmarks.common import recall_at_k, stage_breakdown
    from repro.core import index as index_lib
    from repro.core import profile as profile_lib
    from repro.core import telemetry as telem
    from repro.data import synthetic
    from repro.launch.serve import default_cfg

    telem.enable()

    pool = synthetic.make("manifold", n + qbatch, seed=0)
    corpus, queries = np.asarray(pool[:n]), np.asarray(pool[n:])
    d = corpus.shape[1]
    gt_idx = np.asarray(
        index_lib.build("brute", corpus, {}).search(queries, k=k).idx
    )

    mode_list = [m.strip() for m in modes.split(",") if m.strip()]
    rows = []
    for q in qs:
        for quant in quant_modes:
            cfg = default_cfg(
                "infinity", budget=budget, rerank=rerank,
                train_steps=train_steps, proj_sample=proj_sample,
            ) | {"q": q} | ({"quant": True} if quant else {})
            t0 = time.perf_counter()
            eng = index_lib.build("infinity", corpus, cfg)
            build_s = time.perf_counter() - t0
            for mode in mode_list:
                eng.search(queries[:8], k=k, mode=mode)  # compile out
                times = []
                reps = max(1, repeats if mode == "beam" else 1)
                telem.reset()  # stage window = this cell's timed runs only
                for _ in range(reps):
                    t0 = time.perf_counter()
                    res = eng.search(queries, k=k, mode=mode)
                    np.asarray(res.idx)
                    times.append(time.perf_counter() - t0)
                p50 = float(np.median(times))
                stages = stage_breakdown("infinity", repeats=reps)
                row = {
                    "engine": "infinity", "mode": mode,
                    "dtype": "int8" if quant else "f32",
                    "q": "inf" if math.isinf(q) else q,
                    "n": n, "d": d, "k": k, "budget": budget,
                    "build_s": round(build_s, 3),
                    "recall@k": recall_at_k(np.asarray(res.idx), gt_idx, k),
                    "p50_ms": round(p50 * 1e3, 3),
                    "qps": round(qbatch / p50, 1),
                    "mean_comparisons": float(
                        np.asarray(res.comparisons).mean()
                    ),
                    "stages": stages,
                    "validation": eng.train_history.get("validation"),
                }
                if mode == "beam":
                    # the beam traversal is ONE compiled program — profile
                    # it; best_first is a host-driven loop, so a single-HLO
                    # roofline would misrepresent it (DESIGN.md §17).
                    try:
                        prof = profile_lib.capture_search(
                            eng, queries, k=k, engine="infinity",
                            labels={"mode": mode, "dtype": row["dtype"],
                                    "q": str(row["q"])},
                            mode=mode,
                        )
                        row["roofline"] = prof.as_row()
                    except Exception as e:  # pragma: no cover
                        row["roofline"] = {
                            "error": f"{type(e).__name__}: {e}"[:200]}
                rows.append(row)
                if verbose:
                    print(
                        f"  q={row['q']!s:>4} {mode:10s} {row['dtype']:4s} "
                        f"recall@{k}={row['recall@k']:.3f} "
                        f"p50={row['p50_ms']:8.1f}ms qps={row['qps']:8.0f} "
                        f"comps={row['mean_comparisons']:7.0f}"
                    )
    return rows


def write_artifact(rows, path="experiments/BENCH_infinity.json") -> None:
    """Single owner of the machine-readable infinity q-sweep artifact
    (also called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--qbatch", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--qs", default="2,4,8,inf")
    ap.add_argument("--modes", default="best_first,beam")
    ap.add_argument("--budget", type=int, default=1024)
    ap.add_argument("--rerank", type=int, default=256)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--proj-sample", type=int, default=512)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--no-quant", action="store_true",
                    help="skip the int8 cells (smoke runs)")
    args = ap.parse_args()
    write_artifact(run(
        n=args.n, qbatch=args.qbatch, k=args.k, qs=_parse_qs(args.qs),
        modes=args.modes, budget=args.budget, rerank=args.rerank,
        train_steps=args.train_steps, proj_sample=args.proj_sample,
        repeats=args.repeats,
        quant_modes=(False,) if args.no_quant else (False, True),
    ))


if __name__ == "__main__":
    main()
