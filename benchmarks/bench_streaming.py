"""Streaming benchmark: the live subsystem under interleaved churn.

Drives ``core/live.LiveIndex`` per engine through a churn trace — every
step upserts a batch, deletes a slice of random alive rows, then answers a
query batch — and records recall-vs-churn (against a brute-force oracle on
the index's own logical corpus at that instant) plus per-step query
latency / QPS and the segment composition (delta fill, tombstones,
generation).  Compactions triggered by the trace are part of the measured
behavior: the generation column shows where they landed and what they did
to recall and latency.

``benchmarks/run.py`` writes the rows to ``experiments/BENCH_streaming.json``
— the streaming-perf trajectory regressed against by future PRs.  Runs
single-device (the live wrapper handles sharded engines, but churn
measurement doesn't need a mesh), so unlike bench_serving no child process
is involved.

  PYTHONPATH=src python benchmarks/bench_streaming.py \
      --n 1024 --steps 4 --engines brute,ivf_flat,nsw
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_streaming.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def run(
    n=2048, steps=6, ins=96, dels=48, qbatch=64, k=10,
    engines="brute,ivf_flat,nsw,infinity", delta_cap=256, budget=256,
    rerank=64, train_steps=200, proj_sample=512, verbose=True,
):
    """Churn sweep; returns one row per (engine, step)."""
    from benchmarks.common import recall_at_k
    from repro.core import index as index_lib
    from repro.data import synthetic
    from repro.launch.serve import default_cfg

    rng = np.random.default_rng(0)
    pool = synthetic.make("manifold", n + steps * ins + qbatch, seed=0)
    corpus, inserts, queries = (
        pool[:n], pool[n : n + steps * ins], pool[n + steps * ins :],
    )

    rows = []
    for engine in [e.strip() for e in engines.split(",") if e.strip()]:
        cfg = default_cfg(engine, budget=budget, rerank=rerank,
                          train_steps=train_steps, proj_sample=proj_sample)
        t0 = time.perf_counter()
        live = index_lib.build("live", corpus, {
            "engine": engine, "engine_cfg": cfg, "delta_cap": delta_cap,
            # refresh keeps infinity compactions at tree-rebuild cost (the
            # inductive-Phi path); every other engine rebuilds fully anyway
            "compact_mode": "refresh" if engine == "infinity" else "full",
        })
        build_s = time.perf_counter() - t0
        for step in range(steps):
            t0 = time.perf_counter()
            new_ids = live.upsert(inserts[step * ins : (step + 1) * ins])
            upsert_ms = (time.perf_counter() - t0) * 1e3
            # delete a random alive slice (never the rows just inserted —
            # churn should age the frozen segment, not cancel the upsert)
            s2l = live.slot_to_logical()
            alive = np.where(s2l >= 0)[0]
            alive = alive[~np.isin(alive, new_ids)]
            victims = rng.choice(alive, size=min(dels, len(alive)), replace=False)
            t0 = time.perf_counter()
            live.delete(victims)
            delete_ms = (time.perf_counter() - t0) * 1e3

            # oracle over the live logical corpus at this instant
            logical = live.corpus()
            gt = index_lib.build("brute", logical, {}).search(queries, k=k)
            live.search(queries, k=k)  # warm-up: compile out of the timing
            t0 = time.perf_counter()
            res = live.search(queries, k=k)
            np.asarray(res.idx)
            query_s = time.perf_counter() - t0

            s2l = live.slot_to_logical()
            idx = np.asarray(res.idx)
            mapped = np.where(idx >= 0, s2l[np.maximum(idx, 0)], -1)
            seg = live.stats()
            rows.append({
                "engine": engine, "step": step, "n": n, "k": k,
                "build_s": round(build_s, 3),
                "n_alive": seg["n_alive"], "delta_fill": seg["delta_fill"],
                "tombstones": seg["tombstones"],
                "generation": seg["generation"],
                "compactions": seg["compactions"],
                "recall@k": recall_at_k(mapped, np.asarray(gt.idx), k),
                "upsert_ms": round(upsert_ms, 3),
                "delete_ms": round(delete_ms, 3),
                "query_ms": round(query_s * 1e3, 3),
                "qps": round(qbatch / query_s, 1),
                "mean_comparisons": float(np.asarray(res.comparisons).mean()),
            })
            if verbose:
                r = rows[-1]
                print(
                    f"  {engine:10s} step={step} gen={r['generation']} "
                    f"alive={r['n_alive']:5d} delta={r['delta_fill']:4d} "
                    f"tomb={r['tombstones']:4d} recall@{k}={r['recall@k']:.3f} "
                    f"qps={r['qps']:8.0f} comps={r['mean_comparisons']:7.0f}"
                )
    return rows


def write_artifact(rows, path="experiments/BENCH_streaming.json") -> None:
    """Single owner of the machine-readable streaming-perf artifact
    (also called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--ins", type=int, default=96)
    ap.add_argument("--dels", type=int, default=48)
    ap.add_argument("--qbatch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,ivf_flat,nsw,infinity")
    ap.add_argument("--delta-cap", type=int, default=256)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--rerank", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--proj-sample", type=int, default=512)
    args = ap.parse_args()
    write_artifact(run(
        n=args.n, steps=args.steps, ins=args.ins, dels=args.dels,
        qbatch=args.qbatch, k=args.k, engines=args.engines,
        delta_cap=args.delta_cap, budget=args.budget, rerank=args.rerank,
        train_steps=args.train_steps, proj_sample=args.proj_sample,
    ))


if __name__ == "__main__":
    main()
