"""Serving benchmark: QPS / p50 / p99 / comparisons per engine x shard count.

Drives ``launch/serve.SearchServer`` (the registry-driven front end) over a
synthetic corpus for every engine at 1 and 2 corpus shards.  Multi-shard
runs need >1 device, so the measurement runs in a child process with forced
host-platform devices (the same isolation the dry-run and the dist tests
use — the parent keeps its single device).  ``benchmarks/run.py`` writes the
rows to ``experiments/BENCH_serving.json``, the serving-side perf
trajectory regressed against by future PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

if __name__ == "__main__":  # standalone: python benchmarks/bench_serving.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
MARK = "BENCH_SERVING_JSON:"


def _child_main(args) -> None:
    """Runs with forced host devices; prints one JSON line of result rows.

    Telemetry is enabled for the measured sweep (DESIGN.md §16): each row
    carries a ``stages`` breakdown (per-stage comparisons + ms for the
    cell) so the serving artifact shows where each engine's latency went."""
    import numpy as np

    from benchmarks.common import recall_at_k, stage_breakdown
    from repro.core import index as index_lib
    from repro.core import telemetry as telem
    from repro.data import synthetic
    from repro.launch.serve import SearchServer, default_cfg

    telem.enable()

    n, batch, batches, k = args.n, args.batch, args.batches, args.k
    n_q = batch * batches
    X = synthetic.make("manifold", n + n_q, seed=0)
    corpus, queries = X[:n], X[n:]
    qbatches = [queries[b * batch : (b + 1) * batch] for b in range(batches)]
    gt = index_lib.build("brute", corpus, {}).search(queries, k=k)
    gt_idx = np.asarray(gt.idx)

    rows = []
    server = None
    for engine in args.engines.split(","):
        cfg = default_cfg(engine, budget=args.budget, rerank=args.rerank,
                          train_steps=args.train_steps, proj_sample=args.proj_sample)
        for shards in sorted({1, args.shards}):
            if shards > 1 and n % shards != 0:
                # visible truncation: the artifact must not pretend the
                # sharded half of the sweep ran
                print(f"SKIP {engine} shards={shards}: n={n} not divisible",
                      file=sys.stderr)
                continue
            if server is None:
                server = SearchServer(corpus, engine=engine, shards=shards, cfg=cfg)
            else:
                server.swap(engine, shards=shards, cfg=cfg)
            telem.reset()  # stage window = this (engine, shards) cell only
            stats = server.serve(qbatches, k=k, budget=args.budget)
            res = server.query(queries, k=k, budget=args.budget)
            stats["recall@k"] = recall_at_k(np.asarray(res.idx), gt_idx, k)
            stats["n"] = n
            stats["stages"] = stage_breakdown(engine)
            # compiled-program roofline for this cell's serving dispatch
            # (DESIGN.md §17); degraded to an error note, never a crash
            try:
                profs = server.capture_roofline(batch=batch, k=k,
                                                budget=args.budget)
                stats["roofline"] = next(iter(profs.values()), None)
            except Exception as e:  # pragma: no cover
                stats["roofline"] = {"error": f"{type(e).__name__}: {e}"[:200]}
            rows.append(stats)
    print(MARK + json.dumps(rows))


def run(n=2048, batch=64, batches=8, k=10, engines="brute,ivf_flat,nsw,infinity",
        shards=2, budget=256, rerank=64, train_steps=200, proj_sample=512,
        verbose=True):
    """Spawn the measurement child with forced host devices; parse its rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={max(shards, 2)} "
        + env.get("XLA_FLAGS", "")
    )
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable, os.path.abspath(__file__), "--child",
        "--n", str(n), "--batch", str(batch), "--batches", str(batches),
        "--k", str(k), "--engines", engines, "--shards", str(shards),
        "--budget", str(budget), "--rerank", str(rerank),
        "--train-steps", str(train_steps), "--proj-sample", str(proj_sample),
    ]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"serving child failed:\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr}")
    rows = None
    for line in r.stdout.splitlines():
        if line.startswith(MARK):
            rows = json.loads(line[len(MARK):])
    if rows is None:
        raise RuntimeError(f"no result line in child output:\n{r.stdout}")
    for line in r.stderr.splitlines():
        if line.startswith("SKIP"):  # surface child-side sweep truncation
            print(f"  {line}")
    if verbose:
        for rec in rows:
            print(
                f"  {rec['engine']:10s} shards={rec['shards']} "
                f"p50={rec['p50_ms']:7.1f}ms p99={rec['p99_ms']:7.1f}ms "
                f"qps={rec['qps']:8.0f} comps={rec['mean_comparisons']:7.0f} "
                f"recall@{rec['k']}={rec['recall@k']:.3f}"
            )
    return rows


def write_artifact(rows, path="experiments/BENCH_serving.json") -> None:
    """Single owner of the machine-readable serving-perf artifact
    (also called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,ivf_flat,nsw,infinity")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--rerank", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--proj-sample", type=int, default=512)
    return ap.parse_args(argv)


if __name__ == "__main__":
    _args = _parse()
    if _args.child:
        _child_main(_args)
    else:
        write_artifact(run(
            n=_args.n, batch=_args.batch, batches=_args.batches, k=_args.k,
            engines=_args.engines, shards=_args.shards, budget=_args.budget,
            rerank=_args.rerank, train_steps=_args.train_steps,
            proj_sample=_args.proj_sample,
        ))
