"""Fig. 22 / App. F.5: two-stage Infinity Search (broad K then exact rerank).

Sweeps the candidate width K at fixed q = inf and shows recall recovery at
modest extra comparisons — the accuracy/speed knob of the final system.
Built and searched through the ``core/index`` registry: one engine build,
K swept as a per-call search override.
"""
from __future__ import annotations

import math
import os
import sys

if __name__ == "__main__":  # standalone: python benchmarks/bench_two_stage.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.data import synthetic
from benchmarks.common import ground_truth, rank_order_at_k, recall_at_k


def run(n=3000, n_queries=200, Ks=(1, 8, 32, 128), verbose=True):
    X = synthetic.make("manifold", n + n_queries, seed=1)
    Xtr, Q = jnp.asarray(X[:n]), jnp.asarray(X[n:])
    gt, _ = ground_truth(Xtr, Q, k=10)
    index = index_lib.build("infinity", Xtr, {
        "q": math.inf, "proj_sample": 1000, "train_steps": 800,
        "embed_dim": 32, "seed": 0, "mode": "best_first", "budget": 256,
    })
    out = []
    for K in Ks:
        ki, kd, comps = index.search(
            Q, k=min(10, max(K, 1)), rerank=K if K > 10 else 0,
        )
        rec = {
            "K": K,
            "mean_comparisons": float(np.mean(np.asarray(comps))),
            "recall@1": recall_at_k(np.asarray(ki), gt, 1),
            "rank_order@10": rank_order_at_k(np.asarray(ki), gt, min(10, ki.shape[1])),
        }
        out.append(rec)
        if verbose:
            print(
                f"  K={K}: comps={rec['mean_comparisons']:.0f} "
                f"R@1={rec['recall@1']:.3f} RO={rec['rank_order@10']:.2f}"
            )
    return out


if __name__ == "__main__":
    run()
