"""Fig. 6/25 / App. F.7: Infinity Search vs ANN baselines (JAX ports).

Speed measured BOTH as implementation-agnostic comparison counts (the
paper's primary metric) and QPS on this host.  Baselines: brute force,
IVF-Flat, IVF-PQ(+rerank), NSW beam search.  Includes the Kosarak-style
sparse/Jaccard setting where tree+rerank methods shine.
"""
from __future__ import annotations

import math
import time

import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.search import IndexConfig, InfinityIndex
from repro.data import synthetic
from benchmarks.common import ground_truth, recall_at_k


def _qps(fn, n_queries, iters=2):
    fn()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return n_queries * iters / (time.perf_counter() - t0)


def run(n=3000, n_queries=200, dataset="manifold", metric="euclidean",
        train_steps=800, verbose=True):
    X = synthetic.make(dataset, n + n_queries, seed=0)
    Xtr, Q = jnp.asarray(X[:n]), jnp.asarray(X[n:])
    gt, _ = ground_truth(Xtr, Q, k=10, metric=metric)
    out = []

    def record(name, ki, comps, qps):
        rec = {
            "method": name,
            "recall@1": recall_at_k(np.asarray(ki), gt, 1),
            "recall@10": recall_at_k(np.asarray(ki), gt, min(10, np.asarray(ki).shape[1])),
            "mean_comparisons": float(np.mean(np.asarray(comps))),
            "qps": round(qps, 1),
        }
        out.append(rec)
        if verbose:
            print(
                f"  {name:24s} R@1={rec['recall@1']:.3f} R@10={rec['recall@10']:.3f} "
                f"comps={rec['mean_comparisons']:.0f} qps={rec['qps']}"
            )
        return rec

    # brute force
    ki, _, comps = baselines.brute_force(Xtr, Q, k=10, metric=metric)
    record("brute-force", ki, comps, _qps(lambda: baselines.brute_force(Xtr, Q, k=10, metric=metric), n_queries))

    # IVF-Flat
    ivf = baselines.IVFFlat.build(Xtr, num_clusters=48, metric=metric)
    ki, _, comps = ivf.search(Q, k=10, nprobe=4)
    record("ivf-flat(np=4)", ki, comps, _qps(lambda: ivf.search(Q, k=10, nprobe=4), n_queries))

    # IVF-PQ
    if metric == "euclidean":
        pq = baselines.IVFPQ.build(Xtr, num_clusters=48, M=8, ksub=32, metric=metric)
        ki, _, comps = pq.search(Q, k=10, nprobe=4, rerank=64)
        record("ivf-pq(np=4,rr=64)", ki, comps, _qps(lambda: pq.search(Q, k=10, nprobe=4, rerank=64), n_queries))

    # NSW
    nsw = baselines.NSWGraph.build(Xtr, degree=14, metric=metric)
    ki, _, comps = nsw.search(Q, k=10, ef=48, max_steps=128)
    record("nsw(ef=48)", ki, comps, _qps(lambda: nsw.search(Q, k=10, ef=48, max_steps=128), n_queries))

    # Infinity Search (two operating points)
    cfg = IndexConfig(q=math.inf, metric=metric, proj_sample=1000,
                      train_steps=train_steps, embed_dim=32, seed=0)
    index = InfinityIndex.build(Xtr, cfg)
    for budget, rerank, tag in ((96, 0, "fast"), (256, 96, "accurate")):
        ki, _, comps = index.search(Q, k=10, mode="best_first",
                                    max_comparisons=budget, rerank=rerank)
        record(
            f"infinity-search({tag})", ki, comps,
            _qps(lambda b=budget, r=rerank: index.search(Q, k=10, mode="best_first", max_comparisons=b, rerank=r), n_queries),
        )
    return out


def run_jaccard(n=1200, n_queries=100, verbose=True):
    """The Kosarak regime: sparse binary + Jaccard, where most ANN libraries
    have no native support (paper §5.1)."""
    return run(n=n, n_queries=n_queries, dataset="sparse_binary",
               metric="jaccard", train_steps=600, verbose=verbose)


if __name__ == "__main__":
    print("euclidean / fashion-like:")
    run()
    print("jaccard / kosarak-like:")
    run_jaccard()
