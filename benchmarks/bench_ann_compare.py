"""Fig. 6/25 / App. F.7: Infinity Search vs ANN baselines (JAX ports).

Speed measured BOTH as implementation-agnostic comparison counts (the
paper's primary metric) and QPS on this host.  Every method goes through
the ``core/index`` registry — one ``build(name, X, cfg)`` / ``search(Q, k,
budget)`` contract, no per-baseline adapters — so adding an engine to the
registry automatically adds it to this sweep.  Includes the Kosarak-style
sparse/Jaccard setting where tree+rerank methods shine.
"""
from __future__ import annotations

import math
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_ann_compare.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.data import synthetic
from benchmarks.common import ground_truth, recall_at_k


def _qps(fn, n_queries, iters=2):
    fn()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return n_queries * iters / (time.perf_counter() - t0)


def engine_sweep(metric: str, train_steps: int) -> list[tuple[str, str, dict, dict]]:
    """(display name, registry key, build cfg, per-point search kwargs).

    The same registry key can appear at several operating points — the
    per-point kwargs override that call only."""
    sweep = [
        ("brute-force", "brute", {"metric": metric}, {}),
        ("ivf-flat(np=4)", "ivf_flat",
         {"num_clusters": 48, "metric": metric, "nprobe": 4}, {}),
    ]
    if metric == "euclidean":
        sweep.append(
            ("ivf-pq(np=4,rr=64)", "ivf_pq",
             {"num_clusters": 48, "M": 8, "ksub": 32, "metric": metric,
              "nprobe": 4, "rerank": 64}, {})
        )
    sweep.append(
        ("nsw(ef=48)", "nsw",
         {"degree": 14, "metric": metric, "ef": 48, "max_steps": 128}, {})
    )
    inf_cfg = {"q": math.inf, "metric": metric, "proj_sample": 1000,
               "train_steps": train_steps, "embed_dim": 32, "seed": 0,
               "mode": "best_first"}
    sweep.append(("infinity-search(fast)", "infinity", inf_cfg, {"budget": 96}))
    sweep.append(("infinity-search(accurate)", "infinity", inf_cfg,
                  {"budget": 256, "rerank": 96}))
    return sweep


def run(n=3000, n_queries=200, dataset="manifold", metric="euclidean",
        train_steps=800, verbose=True):
    X = synthetic.make(dataset, n + n_queries, seed=0)
    Xtr, Q = jnp.asarray(X[:n]), jnp.asarray(X[n:])
    gt, _ = ground_truth(Xtr, Q, k=10, metric=metric)
    out = []
    built: dict[tuple, object] = {}  # share builds across operating points

    for name, key, cfg, skw in engine_sweep(metric, train_steps):
        ck = (key, tuple(sorted(cfg.items())))
        if ck not in built:
            built[ck] = index_lib.build(key, Xtr, cfg)
        engine = built[ck]
        ki, _, comps = engine.search(Q, k=10, **skw)
        rec = {
            "method": name,
            "engine": key,
            "recall@1": recall_at_k(np.asarray(ki), gt, 1),
            "recall@10": recall_at_k(np.asarray(ki), gt, min(10, np.asarray(ki).shape[1])),
            "mean_comparisons": float(np.mean(np.asarray(comps))),
            "qps": round(_qps(lambda: engine.search(Q, k=10, **skw), n_queries), 1),
            "memory_bytes": engine.memory_bytes(),
        }
        out.append(rec)
        if verbose:
            print(
                f"  {name:24s} R@1={rec['recall@1']:.3f} R@10={rec['recall@10']:.3f} "
                f"comps={rec['mean_comparisons']:.0f} qps={rec['qps']}"
            )
    return out


def run_jaccard(n=1200, n_queries=100, verbose=True):
    """The Kosarak regime: sparse binary + Jaccard, where most ANN libraries
    have no native support (paper §5.1)."""
    return run(n=n, n_queries=n_queries, dataset="sparse_binary",
               metric="jaccard", train_steps=600, verbose=verbose)


if __name__ == "__main__":
    print("euclidean / fashion-like:")
    run()
    print("jaccard / kosarak-like:")
    run_jaccard()
