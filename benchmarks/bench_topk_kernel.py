"""Fused distance+top-k scan vs. materialize-then-top_k (DESIGN.md §4.3).

For each dataset size n, times three ways of answering "k nearest of n for
m queries":

* ``materialize`` — full (m, n) matrix via ``metrics.pairwise`` + top_k
  (the pre-scan-engine pipeline),
* ``scan_jnp``    — blocked running-merge (``core/scan`` jnp path),
* ``scan_pallas`` — the fused ``kernels/topk`` kernel.

Alongside wall time it reports the HBM *write* traffic of the selection
stage, which is what the fusion eliminates: the baseline writes the whole
m·n·4-byte matrix before selecting; the fused paths only ever write the
(m, k) result pair.  Reads of X/Y are identical across methods and are
reported separately for context.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":  # standalone: python benchmarks/bench_topk_kernel.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as metrics_lib
from repro.core import profile as profile_lib
from repro.core import scan as scan_lib
from benchmarks.common import timeit


def _materialize_topk(Q, Y, k, metric):
    D = metrics_lib.pairwise(Q, Y, metric=metric)
    neg, idx = jax.lax.top_k(-D, k)
    return -neg, idx


def hbm_bytes(m: int, n: int, d: int, k: int) -> dict:
    """Analytic selection-stage HBM traffic (f32)."""
    return {
        "read_inputs": 4 * (m * d + n * d),  # identical for every method
        "write_materialize": 4 * m * n + 8 * m * k,  # matrix + (dist, idx)
        "write_fused": 8 * m * k,  # (dist, idx) only
    }


def roofline_block(Q, Y, k, metric, measured: dict) -> dict:
    """Loop-aware roofline profiles (core/profile) of each compiled scan
    variant, reusing the wall-clock medians already measured by the bench
    for the predicted-vs-measured pair.  Per-variant failures (e.g. the
    pallas kernel unavailable on this backend) degrade to None."""
    n = int(Y.shape[0])
    out = {}
    variants = {
        "materialize": (lambda Q, Y: _materialize_topk(Q, Y, k, metric),
                        "t_materialize_s"),
        "scan_jnp": (lambda Q, Y: scan_lib.topk_scan(
            Q, Y, k=k, metric=metric, impl="jnp"), "t_scan_jnp_s"),
        "scan_pallas": (lambda Q, Y: scan_lib.topk_scan(
            Q, Y, k=k, metric=metric, impl="pallas"), "t_scan_pallas_s"),
    }
    for name, (fn, tkey) in variants.items():
        try:
            prof = profile_lib.capture_jit(
                f"topk:{name}", jax.jit(fn), Q, Y,
                labels={"n": n, "k": k},
                measured_s=measured.get(tkey),
            )
            out[name] = prof.as_row()
        except Exception as e:  # pragma: no cover - backend-specific
            out[name] = {"error": f"{type(e).__name__}: {e}"[:200]}
    return out


def run(ns=(4096, 65536, 524288), m=64, d=64, k=32, metric="euclidean",
        iters=3, verbose=True):
    """``iters``: timed repeats per variant (median) — the regression
    sentinel's --quick gate raises it, since its small/fast cells are the
    noise-sensitive ones."""
    rng = np.random.default_rng(0)
    Q = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    out = []
    for n in ns:
        Y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        t_mat = timeit(
            lambda: _materialize_topk(Q, Y, k, metric), warmup=1, iters=iters
        )
        t_jnp = timeit(
            lambda: scan_lib.topk_scan(Q, Y, k=k, metric=metric, impl="jnp"),
            warmup=1, iters=iters,
        )
        t_pal = timeit(
            lambda: scan_lib.topk_scan(Q, Y, k=k, metric=metric, impl="pallas"),
            warmup=1, iters=iters,
        )
        # parity guard: the benchmark is meaningless if results diverge
        d_m, i_m = _materialize_topk(Q, Y, k, metric)
        for d_s, i_s in (
            scan_lib.topk_scan(Q, Y, k=k, metric=metric, impl="jnp"),
            scan_lib.topk_scan(Q, Y, k=k, metric=metric, impl="pallas"),
        ):
            np.testing.assert_allclose(
                np.asarray(d_m), np.asarray(d_s), atol=1e-4, rtol=1e-4
            )
            np.testing.assert_array_equal(np.asarray(i_m), np.asarray(i_s))
        bts = hbm_bytes(m, n, d, k)
        rec = {
            "n": n, "m": m, "d": d, "k": k, "metric": metric,
            "t_materialize_s": t_mat,
            "t_scan_jnp_s": t_jnp,
            "t_scan_pallas_s": t_pal,
            "hbm_read_bytes": bts["read_inputs"],
            "hbm_write_bytes_materialize": bts["write_materialize"],
            "hbm_write_bytes_fused": bts["write_fused"],
            "hbm_write_reduction":
                bts["write_materialize"] / bts["write_fused"],
        }
        rec["roofline"] = roofline_block(Q, Y, k, metric, rec)
        out.append(rec)
        if verbose:
            print(
                f"  n={n:>7d}: materialize={t_mat * 1e3:8.1f}ms "
                f"scan_jnp={t_jnp * 1e3:8.1f}ms scan_pallas={t_pal * 1e3:8.1f}ms "
                f"write-reduction={rec['hbm_write_reduction']:.0f}x"
            )
    return out


def write_artifact(rows, path="experiments/BENCH_topk.json") -> None:
    """Single owner of the machine-readable perf-trajectory artifact
    (also called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)


if __name__ == "__main__":
    write_artifact(run())
