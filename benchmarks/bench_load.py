"""Open-loop load benchmark: latency / goodput / shed rate vs offered QPS
(DESIGN.md §18).

Drives the overload runtime (``launch/runtime.ServingRuntime``) with an
open-loop Poisson arrival process — arrivals are scheduled independently
of completions, the load pattern a closed-loop driver can never produce
and the one that actually exposes saturation.  Per engine:

1. arm a deterministic ``slow_search`` latency spike (``spike_ms`` per
   dispatch) so the service floor — and therefore the saturation knee —
   is set by the benchmark, not by generator speed;
2. measure saturation throughput (``sat_qps``) *through the runtime* —
   a warmed closed-loop burst submitted and drained end to end, so the
   number includes batch formation and per-request bookkeeping, not just
   engine compute;
3. sweep offered load at ``load_fracs`` × ``sat_qps`` and record, per
   cell: achieved offered QPS, goodput (answers that met their deadline,
   per second), shed rate (explicit sheds + admission rejections over all
   arrivals), p50/p99 end-to-end latency of answered requests, breaker
   trips, and recall@k of the admitted answers against the brute oracle.

The claim the artifact pins: past the knee the runtime *refuses* work
(bounded queue, explicit outcomes) while goodput holds near ``sat_qps``
and answered-request latency stays bounded by deadline + one dispatch —
overload degrades the offered curve, never the admitted one.  A ``knee``
summary row per engine records ``sat_qps`` and the best observed goodput.

``benchmarks/run.py`` writes ``experiments/BENCH_load.json`` (stamped);
``benchmarks/regress.py`` gates goodput / shed-rate / recall against it.

  PYTHONPATH=src python benchmarks/bench_load.py --quick
  PYTHONPATH=src python benchmarks/bench_load.py --engines brute,ivf_flat
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_load.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _open_loop_cell(runtime, queries, gt_idx, *, offered_qps, duration_s,
                    deadline_ms, k, seed):
    """One open-loop run at a fixed offered rate; returns the cell's
    measurements.  Arrival times are pre-scheduled (Poisson, seeded);
    when the generator falls behind it bursts to catch up rather than
    silently lowering the offered rate."""
    from repro.launch.runtime import Rejected

    rng = np.random.default_rng(seed)
    nq = len(queries)
    done_at = {}
    tickets = []  # (query_row, t_submit, ticket)
    rejected = rejected_breaker = 0
    t_start = time.monotonic()
    next_t, i = t_start, 0
    while True:
        next_t += float(rng.exponential(1.0 / offered_qps))
        if next_t - t_start > duration_s:
            break
        lag = next_t - time.monotonic()
        if lag > 0:
            time.sleep(lag)
        try:
            t = runtime.submit(queries[i % nq], k=k, deadline_ms=deadline_ms)
        except Rejected as e:
            rejected += 1
            rejected_breaker += e.reason == "breaker"
        else:
            t._future.add_done_callback(
                lambda f, s=t.seq: done_at.setdefault(s, time.monotonic()))
            tickets.append((i % nq, time.monotonic(), t))
        i += 1
    arrivals = i
    results, failed = [], 0
    for qi, ts, t in tickets:
        try:
            results.append((qi, ts, t.seq, t.result(timeout=120)))
        except Exception:  # injected dispatch fault surfaced: explicit too
            failed += 1
    wall_s = time.monotonic() - t_start

    ok = [(qi, ts, seq, r) for qi, ts, seq, r in results if r.outcome == "ok"]
    met = sum(1 for _, _, _, r in ok if r.deadline_met)
    shed = len(results) - len(ok)
    lat_ms = np.asarray(
        [(done_at[seq] - ts) * 1e3 for _, ts, seq, _ in ok])
    hits = total = 0
    for qi, _, _, r in ok:
        hits += len(set(r.idx[0].tolist()) & set(gt_idx[qi].tolist()))
        total += k
    return {
        "offered_qps": round(arrivals / wall_s, 1),
        "submitted": len(tickets), "completed": len(ok),
        "shed": shed, "rejected": rejected, "failed": failed,
        "rejected_breaker": rejected_breaker,
        "goodput_qps": round(met / wall_s, 1),
        "shed_rate": round((shed + rejected + failed) / max(1, arrivals), 4),
        "deadline_met_frac": round(met / max(1, len(ok)), 4),
        "p50_ok_ms": round(float(np.percentile(lat_ms, 50)), 3) if len(lat_ms) else None,
        "p99_ok_ms": round(float(np.percentile(lat_ms, 99)), 3) if len(lat_ms) else None,
        "breaker_trips": runtime.breaker.trips,
        "recall@k": round(hits / total, 4) if total else None,
        "duration_s": round(wall_s, 3),
    }


def run(
    n=2048, qpool=256, k=10, engines="brute,ivf_flat",
    load_fracs=(0.5, 1.0, 2.0), deadline_ms=60.0, duration_s=1.5,
    capacity=256, max_batch=16, flush_ms=2.0, spike_ms=5.0, budget=256,
    rerank=96, train_steps=200, proj_sample=512, verbose=True,
):
    """Open-loop sweep; one row per (engine, load_frac) + a knee row."""
    from repro.core import index as index_lib
    from repro.data import synthetic
    from repro.launch.runtime import OverloadPolicy, ServingRuntime
    from repro.launch.serve import SearchServer, default_cfg

    pool = synthetic.make("manifold", n + qpool, seed=0)
    corpus, queries = np.asarray(pool[:n]), np.asarray(pool[n:])
    gt_idx = np.asarray(index_lib.build("brute", corpus, {}).search(
        queries, k=k).idx)

    rows = []
    for engine in [e.strip() for e in engines.split(",") if e.strip()]:
        cfg = default_cfg(engine, budget=budget, rerank=rerank,
                          train_steps=train_steps, proj_sample=proj_sample)
        server = SearchServer(
            corpus, engine=engine, cfg=dict(cfg),
            chaos={"seed": 3, "rules": [
                # the controlled service floor: every dispatch stalls
                # spike_ms, making sat_qps a property of the runtime, not
                # of how fast this machine scans 2048 vectors
                {"site": "slow_search", "kind": "latency", "rate": 1.0,
                 "ms": spike_ms}]})
        # pre-warm every jit key the run can touch: pow2 buckets x the
        # budget-degradation ladder (watermark backpressure and the
        # deadline controller both halve the budget, and each distinct
        # budget is a fresh compile — unwarmed, those compiles land inside
        # the measured window as phantom 100ms+ latency spikes)
        ladder = {budget}
        bb = budget
        while bb > 8:
            bb //= 2
            ladder.add(max(8, bb))
        for b in (1, 2, 4, 8, max_batch):
            for bb in sorted(ladder):
                server.query(queries[:b], k=k, budget=bb, record=False)
        # saturation THROUGH the runtime: closed-loop burst, no deadlines —
        # the drain rate includes batch formation, locks and per-request
        # bookkeeping, which dominate engine compute at small n (a raw
        # server.query timing would overstate saturation ~2x)
        pol = OverloadPolicy(capacity=capacity, max_batch=max_batch,
                             flush_ms=flush_ms, budget=budget)
        runtime = ServingRuntime(server, pol).start()
        try:
            burst = min(200, capacity - 8)
            for rep in range(2):  # first pass warms, second measures
                t0 = time.monotonic()
                ts = [runtime.submit(queries[j % qpool], k=k)
                      for j in range(burst)]
                for t in ts:
                    t.result(timeout=120)
                sat_qps = burst / (time.monotonic() - t0)
        finally:
            runtime.stop()
        if verbose:
            print(f"  {engine}: sat={sat_qps:.0f} qps "
                  f"(closed-loop {burst}-burst)")

        best_goodput, best_frac = 0.0, None
        for frac in load_fracs:
            pol = OverloadPolicy(
                capacity=capacity, max_batch=max_batch, flush_ms=flush_ms,
                budget=budget, budget_floor=max(32, budget // 8),
                breaker_trip=10, breaker_cooldown_s=0.05)
            runtime = ServingRuntime(server, pol).start()
            try:
                cell = _open_loop_cell(
                    runtime, queries, gt_idx,
                    offered_qps=frac * sat_qps, duration_s=duration_s,
                    deadline_ms=deadline_ms, k=k, seed=17)
            finally:
                runtime.stop()
            row = {"engine": engine, "cell": "sweep",
                   "load_frac": float(frac), "n": n, "k": k,
                   "capacity": capacity, "max_batch": max_batch,
                   "deadline_ms": deadline_ms, "sat_qps": round(sat_qps, 1),
                   **cell}
            rows.append(row)
            if cell["goodput_qps"] > best_goodput:
                best_goodput, best_frac = cell["goodput_qps"], float(frac)
            if verbose:
                print(
                    f"  {engine:10s} x{frac:<4} offered={cell['offered_qps']:7.0f} "
                    f"goodput={cell['goodput_qps']:7.0f} "
                    f"shed={cell['shed_rate']:.2f} "
                    f"p99={cell['p99_ok_ms'] or float('nan'):6.1f}ms "
                    f"recall={cell['recall@k']}"
                )
        rows.append({
            "engine": engine, "cell": "knee", "n": n, "k": k,
            "capacity": capacity, "max_batch": max_batch,
            "sat_qps": round(sat_qps, 1),
            "knee_qps": round(best_goodput, 1),
            "knee_load_frac": best_frac,
        })
        if verbose:
            print(f"  {engine}: knee at {best_goodput:.0f} qps "
                  f"(x{best_frac} offered, saturation {sat_qps:.0f})")
    return rows


def write_artifact(rows, path="experiments/BENCH_load.json") -> None:
    """Single owner of the machine-readable overload artifact (also
    called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)
    print(f"wrote {path} ({len(rows)} rows)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,ivf_flat")
    ap.add_argument("--load-fracs", default="0.5,1.0,2.0",
                    help="offered load as multiples of measured saturation")
    ap.add_argument("--deadline-ms", type=float, default=60.0)
    ap.add_argument("--duration-s", type=float, default=1.5)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: brute only, short cells")
    ap.add_argument("--out", default="experiments/BENCH_load.json")
    args = ap.parse_args()
    rows = run(
        n=args.n, k=args.k,
        engines="brute" if args.quick else args.engines,
        load_fracs=tuple(float(f) for f in args.load_fracs.split(",")),
        deadline_ms=args.deadline_ms,
        duration_s=0.6 if args.quick else args.duration_s,
        train_steps=args.train_steps,
    )
    write_artifact(rows, args.out)


if __name__ == "__main__":
    main()
