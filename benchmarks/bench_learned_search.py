"""Fig. 4 / App. F.4: end-to-end InfinitySearch with the learned map Phi.

The full pipeline (sparse projection on a subset -> train Phi -> embed ->
VP tree), q sweep, comparisons vs Recall@k vs RankOrder@k, with and without
the comparison budget that traces the speed/accuracy Pareto front.
"""
from __future__ import annotations

import math
import time

import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.search import IndexConfig, InfinityIndex
from repro.data import synthetic
from benchmarks.common import ground_truth, rank_order_at_k, recall_at_k

QS = (2.0, 8.0, math.inf)


def run(n=4000, n_queries=200, qs=QS, train_steps=800, verbose=True):
    X = synthetic.make("manifold", n + n_queries, seed=0)
    Xtr = jnp.asarray(X[:n])
    Q = jnp.asarray(X[n:])
    gt, _ = ground_truth(Xtr, Q, k=10)
    out = []
    for q in qs:
        cfg = IndexConfig(
            q=q, metric="euclidean", proj_sample=1000, knn_k=14, num_hops=6,
            embed_dim=32, hidden=(256, 256), train_steps=train_steps, seed=0,
        )
        t0 = time.perf_counter()
        index = InfinityIndex.build(Xtr, cfg)
        build_s = time.perf_counter() - t0
        for budget, rerank in ((64, 0), (256, 64), (None, 128)):
            ki, kd, comps = index.search(
                Q, k=10, mode="best_first", max_comparisons=budget, rerank=rerank
            )
            rec = {
                "q": q, "budget": budget or n, "rerank": rerank,
                "build_s": round(build_s, 1),
                "mean_comparisons": float(np.mean(np.asarray(comps))),
                "recall@1": recall_at_k(np.asarray(ki), gt, 1),
                "recall@10": recall_at_k(np.asarray(ki), gt, 10),
                "rank_order@10": rank_order_at_k(np.asarray(ki), gt, 10),
            }
            out.append(rec)
            if verbose:
                print(
                    f"  q={q} budget={rec['budget']} rerank={rerank}: "
                    f"comps={rec['mean_comparisons']:.0f} R@1={rec['recall@1']:.3f} "
                    f"R@10={rec['recall@10']:.3f} RO@10={rec['rank_order@10']:.2f}"
                )
    return out


if __name__ == "__main__":
    run()
