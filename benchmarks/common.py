"""Shared benchmark utilities: metrics from the paper (App. F.1) + timing,
plus the provenance stamp every ``experiments/BENCH_*.json`` artifact
carries so the perf trajectory stays reconstructable across PRs."""
from __future__ import annotations

import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np


def env_stamp() -> dict:
    """Provenance of a benchmark run: git commit, jax version, backend and
    device count.  Two artifacts are only comparable when their stamps say
    they ran on comparable stacks — without this the numbers are anonymous."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        commit = "unknown"
    return {
        "git_commit": commit,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def write_stamped(path: str, rows) -> None:
    """The one artifact writer: ``{"meta": env_stamp(), "rows": rows}``.
    Every ``BENCH_*.json`` goes through here so the schema (and the stamp)
    cannot drift between benchmarks.  When ``core/telemetry`` is enabled a
    registry summary (per-stage comparison counters, stage latency
    count/sum/mean, dispatch regimes — DESIGN.md §16) rides along under
    ``meta["telemetry"]``, so every perf artifact carries its own
    breakdown of where the time and comparisons went."""
    meta = env_stamp()
    from repro.core import telemetry as telem

    if telem.enabled():
        meta["telemetry"] = telem.summary()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)


def stage_breakdown(engine: str, repeats: int = 1) -> dict:
    """Per-stage ``{comparisons, ms}`` for ``engine`` from the telemetry
    registry (DESIGN.md §16) — the q-sweep's answer to WHERE higher q
    saves work: traversal vs centroid ranking vs bucket scan vs rerank
    comparisons and milliseconds, averaged over ``repeats`` timed runs.
    Callers ``telem.reset()`` before the timed region so the window is one
    cell's; returns {} when telemetry is disabled."""
    from repro.core import telemetry as telem

    if not telem.enabled():
        return {}
    out: dict = {}

    def slot(stage):
        return out.setdefault(stage, {"comparisons": 0.0, "ms": 0.0})

    for lbl, v in telem.counter_series("comparisons_total"):
        if lbl.get("engine") == engine and "stage" in lbl:
            slot(lbl["stage"])["comparisons"] += v / repeats
    for lbl, rec in telem.histogram_series("stage_seconds"):
        if lbl.get("engine") == engine and "stage" in lbl:
            slot(lbl["stage"])["ms"] += rec["sum"] * 1e3 / repeats
    return {
        stage: {"comparisons": round(v["comparisons"], 1),
                "ms": round(v["ms"], 3)}
        for stage, v in sorted(out.items())
    }


def ground_truth(
    X, Q, *, k: int, metric: str = "euclidean", impl: str = "jnp",
) -> tuple[np.ndarray, np.ndarray]:
    """Exact (idx, dist) oracle for recall/rank-order metrics, streamed
    through ``core/scan.topk_scan`` so ground truth never materializes the
    (B, n) score matrix."""
    from repro.core import scan as scan_lib

    dists, idx = scan_lib.topk_scan(
        jnp.asarray(Q, jnp.float32), jnp.asarray(X, jnp.float32),
        k=k, metric=metric, impl=impl,
    )
    return np.asarray(idx), np.asarray(dists)


def recall_at_k(approx_idx: np.ndarray, true_idx: np.ndarray, k: int) -> float:
    """|approx ∩ true| / k, averaged over queries (Eq. 71)."""
    hits = [
        len(set(map(int, a[:k])) & set(map(int, t[:k]))) / k
        for a, t in zip(approx_idx, true_idx)
    ]
    return float(np.mean(hits))


def rank_order_at_k(approx_idx: np.ndarray, true_idx: np.ndarray, k: int) -> float:
    """Absolute RankOrder@k (Eq. 69): mean |i - pi(x_i)| with pi = position in
    the true ranking (k+1 when missing).  0 = perfect."""
    out = []
    for a, t in zip(approx_idx, true_idx):
        pos = {int(x): i + 1 for i, x in enumerate(t[:k])}
        s = sum(abs((i + 1) - pos.get(int(x), k + 1)) for i, x in enumerate(a[:k]))
        out.append(s / k)
    return float(np.mean(out))


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (seconds) with jax block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
