"""Shared benchmark utilities: metrics from the paper (App. F.1) + timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def ground_truth(
    X, Q, *, k: int, metric: str = "euclidean", impl: str = "jnp",
) -> tuple[np.ndarray, np.ndarray]:
    """Exact (idx, dist) oracle for recall/rank-order metrics, streamed
    through ``core/scan.topk_scan`` so ground truth never materializes the
    (B, n) score matrix."""
    from repro.core import scan as scan_lib

    dists, idx = scan_lib.topk_scan(
        jnp.asarray(Q, jnp.float32), jnp.asarray(X, jnp.float32),
        k=k, metric=metric, impl=impl,
    )
    return np.asarray(idx), np.asarray(dists)


def recall_at_k(approx_idx: np.ndarray, true_idx: np.ndarray, k: int) -> float:
    """|approx ∩ true| / k, averaged over queries (Eq. 71)."""
    hits = [
        len(set(map(int, a[:k])) & set(map(int, t[:k]))) / k
        for a, t in zip(approx_idx, true_idx)
    ]
    return float(np.mean(hits))


def rank_order_at_k(approx_idx: np.ndarray, true_idx: np.ndarray, k: int) -> float:
    """Absolute RankOrder@k (Eq. 69): mean |i - pi(x_i)| with pi = position in
    the true ranking (k+1 when missing).  0 = perfect."""
    out = []
    for a, t in zip(approx_idx, true_idx):
        pos = {int(x): i + 1 for i, x in enumerate(t[:k])}
        s = sum(abs((i + 1) - pos.get(int(x), k + 1)) for i, x in enumerate(a[:k]))
        out.append(s / k)
    return float(np.mean(out))


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (seconds) with jax block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
