"""Bench regression sentinel: the perf trajectory becomes a gate
(DESIGN.md §17).

  PYTHONPATH=src python -m benchmarks.regress --quick
  PYTHONPATH=src python -m benchmarks.regress --check        # stamps only
  PYTHONPATH=src python -m benchmarks.regress --baseline F --fresh F \
      --inject p50_ms=1.2 --inject-match engine=brute        # self-test

Loads the stamped ``experiments/BENCH_*.json`` baselines, runs a fresh
benchmark pass at the *same configuration* (``--quick`` restricts to the
cheap cells), matches rows by their identity columns (everything that is
not a measurement: engine, shards, n, k, ...), and compares with
noise-aware thresholds:

* **Speed normalization.**  Latency/QPS comparisons are normalized by the
  median fresh/baseline latency ratio across ALL matched rows — a
  uniformly slower machine (CI runner vs dev box) shifts every ratio and
  is factored out.  The normalizer is clamped at >= 1 for the hard gate:
  a row that is *absolutely* faster than its baseline is never a
  regression, even when the rest of the suite sped up more (machines
  speed up non-uniformly across program types; demanding proportional
  speedups would gate on hardware, not code).  Rows slower than the
  unclamped suite trend are surfaced as warnings.  With fewer than
  ``MIN_SCALE_SAMPLES`` ratios the scale stays 1.0 (no basis to
  normalize).
* **Relative latency/QPS tolerance** (``--rel-tol``, default 0.15): a row
  regresses when its p50 exceeds ``baseline * max(scale, 1) * (1 + tol)``
  (QPS: falls below ``baseline / max(scale, 1) / (1 + tol)``).  On a
  same-speed machine — and in the exact self-comparison mode CI's
  injection self-test runs — this catches a 20% single-row regression
  deterministically.
* **Absolute recall floor** (``--recall-tol``, default 0.05): recall is
  machine-independent, so the comparison is absolute — fresh recall below
  ``baseline - tol`` regresses regardless of speed.
* **Comparison-count creep** (``--comp-tol``, default 0.25): mean
  comparisons are deterministic given the config; growing past
  ``baseline * (1 + tol)`` regresses.
* **Overload economics** (``--shed-tol``, default 0.2): BENCH_load's
  ``goodput_qps`` / ``knee_qps`` gate like QPS (speed-normalized,
  relative), while ``shed_rate`` is a load *fraction* — machine-
  independent because offered load is expressed as multiples of the
  measured saturation — so it gates absolutely: fresh shedding more than
  ``baseline + tol`` of arrivals regresses.

Writes ``REGRESSIONS.md`` and exits 1 on any regression, 2 on malformed
input.  Unstamped artifacts (the pre-PR-5 bare-list/dict format) are
rejected with a pointer at ``benchmarks/migrate_legacy.py`` — anonymous
numbers cannot gate anything.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

EXPERIMENTS = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "experiments")

#: bench key -> committed artifact (the sentinel's baseline universe)
BASELINES = {
    "topk_kernel": "BENCH_topk.json",
    "serving": "BENCH_serving.json",
    "infinity": "BENCH_infinity.json",
    "load": "BENCH_load.json",
}

#: row keys that are measurements (never identity); nested blocks
#: (stages / validation / roofline) are excluded by being non-scalar
MEASUREMENT_KEYS = {
    "p50_ms", "p99_ms", "qps", "mean_comparisons", "build_s",
    "memory_bytes", "quant_bytes", "t_materialize_s", "t_scan_jnp_s",
    "t_scan_pallas_s", "hbm_read_bytes", "hbm_write_bytes_materialize",
    "hbm_write_bytes_fused", "hbm_write_reduction", "recall@k", "recall@1",
    "deadline_ms", "degraded_batches", "deadline_misses", "retries",
    "health", "window_batches",
    # BENCH_load: everything the open-loop run *measures* — identity is
    # (engine, cell, load_frac, n, k, capacity, max_batch) only
    "offered_qps", "goodput_qps", "shed_rate", "sat_qps", "knee_qps",
    "knee_load_frac", "submitted", "completed", "shed", "rejected", "failed",
    "rejected_breaker", "breaker_trips", "deadline_met_frac",
    "p50_ok_ms", "p99_ok_ms", "duration_s",
}

#: lower-is-better wall-clock metrics (speed-normalized, relative tol)
LATENCY_KEYS = ("p50_ms", "t_materialize_s", "t_scan_jnp_s", "t_scan_pallas_s")
MIN_SCALE_SAMPLES = 3


class UnstampedArtifact(ValueError):
    """A benchmark artifact without provenance cannot be a baseline."""


# ---------------------------------------------------------------- loading

def load_stamped(path: str) -> tuple[dict, list]:
    """Read one ``{"meta": ..., "rows": [...]}`` artifact; reject the
    legacy unstamped formats with an actionable error."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, (list,)) or (
            isinstance(doc, dict) and not ({"meta", "rows"} <= set(doc))):
        raise UnstampedArtifact(
            f"{path} is unstamped (pre-PR-5 format: bare rows without a "
            "provenance stamp); run `python -m benchmarks.migrate_legacy` "
            "to convert it, or regenerate via benchmarks/run.py"
        )
    meta, rows = doc["meta"], doc["rows"]
    if not isinstance(meta, dict) or "git_commit" not in meta:
        raise UnstampedArtifact(
            f"{path} carries no git_commit in its stamp; regenerate it")
    return meta, list(rows)


def load_baselines(dir: str = EXPERIMENTS,
                   benches: dict = BASELINES) -> dict:
    """bench key -> (meta, rows) for every committed artifact present."""
    out = {}
    for bench, fname in benches.items():
        path = os.path.join(dir, fname)
        if os.path.exists(path):
            out[bench] = load_stamped(path)
    return out


def load_bundle(path: str) -> dict:
    """Read a ``--save-fresh`` bundle: ``{"meta":..., "benches": {...}}``
    (same stamp discipline as the per-bench artifacts)."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "meta" not in doc or "benches" not in doc:
        raise UnstampedArtifact(
            f"{path} is not a stamped regress bundle "
            '({"meta":..., "benches":...}); re-save with --save-fresh')
    return {b: (doc["meta"], rows) for b, rows in doc["benches"].items()}


def save_bundle(path: str, fresh: dict) -> None:
    from benchmarks.common import env_stamp

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"meta": env_stamp(),
                   "benches": {b: rows for b, (_, rows) in fresh.items()}},
                  f, indent=1)


# ------------------------------------------------------------ fresh runs

def run_fresh(quick: bool, only: str = "") -> dict:
    """Re-measure at the committed configuration.  ``--quick`` keeps to
    the cheap cells: the fused-scan sizes the committed BENCH_topk holds
    and the scan-engine half of the serving sweep (same n/k/batches, so
    rows match identity-for-identity)."""
    from benchmarks import bench_serving, bench_topk_kernel

    out = {}
    if not only or "topk" in only:
        print("== fresh: topk_kernel ==", flush=True)
        # iters=9: the quick cells finish in ms, where a 3-sample median
        # swings well past the tolerance on a shared machine
        rows = bench_topk_kernel.run(
            ns=(4096, 16384) if quick else (4096, 65536, 524288), iters=9)
        out["topk_kernel"] = ({}, rows)
    if not only or "serving" in only:
        print("== fresh: serving ==", flush=True)
        rows = bench_serving.run(
            n=2048, batches=8, k=10, shards=2, budget=256, rerank=64,
            engines="brute,ivf_flat" if quick
            else "brute,ivf_flat,nsw,infinity",
            train_steps=150 if quick else 300)
        out["serving"] = ({}, rows)
    if not only or "load" in only:
        from benchmarks import bench_load

        print("== fresh: load ==", flush=True)
        # same identity columns as the committed artifact (n/k/capacity/
        # max_batch/load_fracs); quick keeps to brute and shorter cells —
        # duration is a measurement, not identity
        rows = bench_load.run(
            n=2048, k=10, engines="brute" if quick else "brute,ivf_flat",
            load_fracs=(0.5, 1.0, 2.0),
            duration_s=0.8 if quick else 1.5,
            train_steps=150 if quick else 200)
        out["load"] = ({}, rows)
    if not quick and (not only or "infinity" in only):
        from benchmarks import bench_infinity
        import math

        print("== fresh: infinity ==", flush=True)
        rows = bench_infinity.run(
            n=2048, qbatch=512, qs=(2.0, 4.0, 8.0, math.inf),
            budget=1024, rerank=256, train_steps=300, proj_sample=512,
            repeats=3)
        out["infinity"] = ({}, rows)
    return out


# ------------------------------------------------------------ comparison

def _scalar(v) -> bool:
    return isinstance(v, (str, int, float, bool)) or v is None


def row_identity(row: dict) -> tuple:
    """The non-measurement scalar columns — what makes two rows "the same
    cell" across runs."""
    return tuple(sorted(
        (k, str(v)) for k, v in row.items()
        if k not in MEASUREMENT_KEYS and _scalar(v)
    ))


def match_rows(base_rows: list, fresh_rows: list) -> list:
    """[(identity, base_row, fresh_row)] — duplicate identities (e.g. the
    topk size sweep keyed only by metric) pair up by ordinal."""
    def group(rows):
        g: dict = {}
        for r in rows:
            g.setdefault(row_identity(r), []).append(r)
        return g

    gb, gf = group(base_rows), group(fresh_rows)
    out = []
    for ident, brs in gb.items():
        frs = gf.get(ident, [])
        for b, f in zip(brs, frs):
            out.append((ident, b, f))
    return out


def speed_scale(matched_all: list) -> tuple[float, int]:
    """Median fresh/baseline latency ratio across every matched row of
    every bench — the machine-speed normalizer.  Clamped to [1/8, 8]; 1.0
    when there are too few samples to estimate."""
    ratios = []
    for _, b, f in matched_all:
        for key in LATENCY_KEYS:
            if key in b and key in f and b[key] and f[key]:
                ratios.append(float(f[key]) / float(b[key]))
    if len(ratios) < MIN_SCALE_SAMPLES:
        return 1.0, len(ratios)
    return float(np.clip(np.median(ratios), 1 / 8, 8)), len(ratios)


def compare(bench: str, matched: list, *, scale: float, rel_tol: float,
            recall_tol: float, comp_tol: float,
            shed_tol: float = 0.2) -> list:
    """Threshold policy (module docstring) over one bench's matched rows;
    returns finding dicts, ``regression=True`` where a hard limit was
    crossed, ``warn=True`` where only the unclamped suite trend was."""
    findings = []
    gate = max(scale, 1.0)  # never demand fresh be *faster* than baseline

    def add(ident, metric, base, fresh, limit, bad, better, warn=False):
        findings.append({
            "bench": bench, "identity": dict(ident), "metric": metric,
            "baseline": base, "fresh": fresh, "limit": limit,
            "better": better, "regression": bool(bad),
            "warn": bool(warn and not bad),
        })

    for ident, b, f in matched:
        for key in LATENCY_KEYS:
            if key in b and key in f and b[key]:
                limit = float(b[key]) * gate * (1.0 + rel_tol)
                trend = float(b[key]) * scale * (1.0 + rel_tol)
                add(ident, key, float(b[key]), float(f[key]), limit,
                    float(f[key]) > limit, "lower",
                    warn=float(f[key]) > trend)
        for key in ("qps", "goodput_qps", "knee_qps"):
            if key in b and key in f and b[key]:
                limit = float(b[key]) / gate / (1.0 + rel_tol)
                trend = float(b[key]) / scale / (1.0 + rel_tol)
                add(ident, key, float(b[key]), float(f[key]), limit,
                    float(f[key]) < limit, "higher",
                    warn=float(f[key]) < trend)
        if "shed_rate" in b and "shed_rate" in f and b["shed_rate"] is not None:
            # a fraction of offered load, offered as multiples of measured
            # saturation: machine-independent, absolute band
            limit = float(b["shed_rate"]) + shed_tol
            add(ident, "shed_rate", float(b["shed_rate"]),
                float(f["shed_rate"]), limit,
                float(f["shed_rate"]) > limit, "lower")
        for key in b:
            if key.startswith("recall") and key in f \
                    and _scalar(b[key]) and b[key] is not None:
                limit = float(b[key]) - recall_tol
                add(ident, key, float(b[key]), float(f[key]), limit,
                    float(f[key]) < limit, "higher")
        if "mean_comparisons" in b and "mean_comparisons" in f and b["mean_comparisons"]:
            limit = float(b["mean_comparisons"]) * (1.0 + comp_tol)
            add(ident, "mean_comparisons", float(b["mean_comparisons"]),
                float(f["mean_comparisons"]), limit,
                float(f["mean_comparisons"]) > limit, "lower")
    return findings


def inject(fresh: dict, spec: str, match: str) -> int:
    """Multiply ``metric`` by ``factor`` on fresh rows whose columns carry
    every ``key=val`` of ``match`` — the synthetic-regression self-test
    CI runs to prove the sentinel trips."""
    metric, factor = spec.split("=", 1)
    factor = float(factor)
    wanted = dict(kv.split("=", 1) for kv in match.split(",")) if match else {}
    hit = 0
    for _, (_, rows) in fresh.items():
        for r in rows:
            if metric not in r:
                continue
            if all(str(r.get(k)) == v for k, v in wanted.items()):
                r[metric] = float(r[metric]) * factor
                hit += 1
    return hit


# --------------------------------------------------------------- report

def render_report(findings: list, *, scale: float, scale_n: int,
                  rel_tol: float, recall_tol: float, comp_tol: float,
                  unmatched: dict, injected: int) -> str:
    regs = [f for f in findings if f["regression"]]
    warns = [f for f in findings if f.get("warn")]
    lines = [
        "# Bench regression report",
        "",
        f"- compared: **{len(findings)}** metric cells across "
        f"{len({f['bench'] for f in findings})} benches",
        f"- regressions: **{len(regs)}** (warnings: {len(warns)} — slower "
        "than the suite-median speedup but not than baseline)",
        f"- speed scale (median fresh/baseline latency ratio over "
        f"{scale_n} samples): **{scale:.3f}**",
        f"- thresholds: latency/QPS ±{rel_tol:.0%} (speed-normalized), "
        f"recall floor −{recall_tol}, comparisons +{comp_tol:.0%}",
    ]
    if injected:
        lines.append(f"- synthetic injection active on {injected} row(s) "
                     "(self-test mode)")
    for bench, n in unmatched.items():
        if n:
            lines.append(f"- note: {n} baseline row(s) in `{bench}` had no "
                         "fresh counterpart (not re-run at this config)")
    lines += ["", "| bench | cell | metric | baseline | fresh | limit | verdict |",
              "|---|---|---|---|---|---|---|"]

    def fmt(x):
        return f"{x:.4g}" if isinstance(x, float) else str(x)

    for f in sorted(findings, key=lambda f: (not f["regression"],
                                             not f.get("warn"), f["bench"])):
        ident = ",".join(f"{k}={v}" for k, v in sorted(f["identity"].items())
                         if k in ("engine", "mode", "dtype", "q", "shards",
                                  "n", "metric", "cell", "load_frac"))
        lines.append(
            f"| {f['bench']} | {ident} | {f['metric']} | "
            f"{fmt(f['baseline'])} | {fmt(f['fresh'])} | {fmt(f['limit'])} | "
            f"{'**REGRESSION**' if f['regression'] else 'warn (suite trend)' if f.get('warn') else 'ok'} |"
        )
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------------ CLI

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="cheap cells only (the CI gate)")
    ap.add_argument("--only", default="", help="substring filter on benches")
    ap.add_argument("--check", action="store_true",
                    help="validate committed artifacts are stamped; no run")
    ap.add_argument("--dir", default=EXPERIMENTS)
    ap.add_argument("--rel-tol", type=float, default=0.15)
    ap.add_argument("--recall-tol", type=float, default=0.05)
    ap.add_argument("--comp-tol", type=float, default=0.25)
    ap.add_argument("--shed-tol", type=float, default=0.2,
                    help="absolute shed-rate band for BENCH_load rows")
    ap.add_argument("--baseline", default=None, metavar="BUNDLE",
                    help="compare against this saved bundle instead of the "
                         "committed artifacts")
    ap.add_argument("--fresh", default=None, metavar="BUNDLE",
                    help="reuse saved fresh rows instead of re-measuring")
    ap.add_argument("--save-fresh", default=None, metavar="PATH")
    ap.add_argument("--inject", default=None, metavar="METRIC=FACTOR",
                    help="self-test: scale a fresh metric before comparing")
    ap.add_argument("--inject-match", default="", metavar="K=V[,K=V]")
    ap.add_argument("--report", default="REGRESSIONS.md")
    args = ap.parse_args(argv)

    # stamp validation runs on every path (the --check fast path stops here)
    try:
        baselines = (load_bundle(args.baseline) if args.baseline
                     else load_baselines(args.dir))
        if not args.baseline:
            import glob as glob_lib

            for path in sorted(
                    glob_lib.glob(os.path.join(args.dir, "BENCH_*.json"))):
                load_stamped(path)
    except UnstampedArtifact as e:
        print(f"REJECTED: {e}", file=sys.stderr)
        return 2
    if args.check:
        print(f"all artifacts under {args.dir} are stamped")
        return 0
    if not baselines:
        print("no stamped baselines found: nothing to gate", file=sys.stderr)
        return 2

    if args.fresh:
        try:
            fresh = load_bundle(args.fresh)
        except UnstampedArtifact as e:
            print(f"REJECTED: {e}", file=sys.stderr)
            return 2
    else:
        fresh = run_fresh(args.quick, args.only)
    if args.save_fresh:
        save_bundle(args.save_fresh, fresh)
        print(f"fresh rows -> {args.save_fresh}")

    injected = 0
    if args.inject:
        injected = inject(fresh, args.inject, args.inject_match)
        if injected == 0:
            print("WARNING: --inject matched no fresh rows", file=sys.stderr)

    matched_by_bench, unmatched = {}, {}
    for bench in sorted(set(baselines) & set(fresh)):
        if args.only and args.only not in bench:
            continue
        m = match_rows(baselines[bench][1], fresh[bench][1])
        matched_by_bench[bench] = m
        unmatched[bench] = len(baselines[bench][1]) - len(m)
    all_matched = [t for m in matched_by_bench.values() for t in m]
    if not all_matched:
        print("no fresh row matched any baseline row: the committed "
              "artifacts were produced at a different config", file=sys.stderr)
        return 2

    scale, scale_n = speed_scale(all_matched)
    findings = []
    for bench, m in matched_by_bench.items():
        findings += compare(bench, m, scale=scale, rel_tol=args.rel_tol,
                            recall_tol=args.recall_tol,
                            comp_tol=args.comp_tol, shed_tol=args.shed_tol)
    report = render_report(
        findings, scale=scale, scale_n=scale_n, rel_tol=args.rel_tol,
        recall_tol=args.recall_tol, comp_tol=args.comp_tol,
        unmatched=unmatched, injected=injected)
    with open(args.report, "w") as f:
        f.write(report)
    regs = [f for f in findings if f["regression"]]
    print(f"{len(findings)} cells compared, scale={scale:.3f}, "
          f"{len(regs)} regression(s) -> {args.report}")
    for f in regs:
        print(f"  REGRESSION {f['bench']} {f['metric']}: "
              f"{f['fresh']:.4g} vs limit {f['limit']:.4g} "
              f"(baseline {f['baseline']:.4g})")
    return 1 if regs else 0


if __name__ == "__main__":
    sys.exit(main())
