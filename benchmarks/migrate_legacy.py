"""One-shot migration of pre-PR-5 benchmark artifacts to the stamped
format the regression sentinel (``benchmarks/regress.py``) requires.

  PYTHONPATH=src python -m benchmarks.migrate_legacy [--dir experiments]

Two legacy shapes exist:

* ``experiments/bench_results.json`` — the orphan aggregate dict
  (``{"topk_kernel": [...], "serving": [...]}``) ``benchmarks/run.py``
  used to write next to the per-bench artifacts.  Each known key is
  folded into its per-bench ``write_stamped`` file (only where that file
  is missing or itself unstamped — a stamped artifact is never clobbered
  by provenance-free rows), then the orphan is deleted.
* bare-list ``BENCH_*.json`` files — rows written before the stamp
  discipline.  They are wrapped in a fresh ``{"meta", "rows"}`` envelope
  in place.

Migrated stamps carry ``migrated_from`` so a reader knows the rows are
older than the stamp's commit/timestamp.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

#: legacy aggregate key -> per-bench artifact filename
LEGACY_KEYS = {
    "topk_kernel": "BENCH_topk.json",
    "serving": "BENCH_serving.json",
    "streaming": "BENCH_streaming.json",
    "filtered": "BENCH_filtered.json",
    "quant": "BENCH_quant.json",
    "infinity": "BENCH_infinity.json",
    "fault": "BENCH_fault.json",
}


def _is_stamped(path: str) -> bool:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return False
    return isinstance(doc, dict) and {"meta", "rows"} <= set(doc)


def _write_migrated(path: str, rows, source: str) -> None:
    from benchmarks.common import env_stamp

    meta = env_stamp() | {"migrated_from": source}
    with open(path, "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)


def migrate(dir: str = "experiments", verbose: bool = True) -> list[str]:
    """Returns the list of actions taken (for tests and the CLI echo)."""
    actions = []

    # bare-list BENCH_*.json -> wrapped in place
    for path in sorted(glob.glob(os.path.join(dir, "BENCH_*.json"))):
        if _is_stamped(path):
            continue
        with open(path) as f:
            doc = json.load(f)
        if isinstance(doc, list):
            _write_migrated(path, doc, os.path.basename(path) + " (unstamped)")
            actions.append(f"stamped {path} in place")

    # the aggregate orphan -> per-bench files, then deleted
    orphan = os.path.join(dir, "bench_results.json")
    if os.path.exists(orphan):
        with open(orphan) as f:
            doc = json.load(f)
        if isinstance(doc, dict):
            for key, rows in doc.items():
                fname = LEGACY_KEYS.get(key)
                if fname is None or not isinstance(rows, list):
                    actions.append(f"skipped unknown legacy key {key!r}")
                    continue
                target = os.path.join(dir, fname)
                if _is_stamped(target):
                    actions.append(
                        f"kept stamped {target} (legacy {key!r} rows dropped)")
                    continue
                _write_migrated(target, rows, "bench_results.json")
                actions.append(f"migrated {key!r} -> {target}")
        os.remove(orphan)
        actions.append(f"deleted {orphan}")

    if verbose:
        for a in actions:
            print(a)
        if not actions:
            print(f"nothing to migrate under {dir}")
    return actions


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments")
    args = ap.parse_args()
    migrate(args.dir)
