"""Fault-injection benchmark: recall / latency vs injected fault rate
(DESIGN.md §14).

For each engine in the sweep and each fault rate, arms a deterministic
``core/chaos.FaultPlan`` (latency spikes + transient search failures at
the given per-call probability) on a ``SearchServer`` and drives a batch
trace through ``query(deadline_ms=...)``.  Recorded per (engine, rate):
recall@k against the brute-force oracle, p50/p99 latency, retries the
controller absorbed, degraded answers, deadline misses, and the plan's own
injection totals — the measurable claim is that recall and p99 degrade
*gracefully* as the fault rate rises, with zero unhandled exceptions.

A combined **fault × load** cell (``cell="fault_x_load"``) then drives the
async overload runtime (DESIGN.md §18) at its measured saturation while a
chaos ``slow_search`` rule stalls and occasionally fails dispatches: the
row records breaker trips, shed rate and the p99 of *admitted* answers —
the claim being that the circuit breaker + shedding keep admitted-request
latency bounded even when faults and overload arrive together.

``benchmarks/run.py`` writes the rows to ``experiments/BENCH_fault.json``
(stamped with run provenance) and CI smoke-runs the standalone entry point
next to bench_quant.

  PYTHONPATH=src python benchmarks/bench_fault.py --n 1024 \
      --engines brute,ivf_flat --rates 0,0.1,0.3
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_fault.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def fault_load_rows(
    corpus, queries, gt_idx, *, engines, cfgs, k=10, storm=(60, 72),
    spike_ms=10.0, deadline_ms=60.0, duration_s=1.0, budget=256,
    verbose=True,
):
    """The combined fault x load cell: open-loop traffic at the runtime's
    measured saturation while the chaos ``slow_search`` site stalls every
    dispatch ``spike_ms`` — and, mid-run, a scripted *fault storm*
    (dispatch callno window ``storm``) fails every dispatch outright.
    Consecutive storm failures trip the circuit breaker, which is the
    point: the row's ``breaker_trips`` > 0 while ``p99_ok_ms`` (admitted
    answers) stays bounded — fast-fail instead of pile-up."""
    from benchmarks.bench_load import _open_loop_cell
    from repro.launch.runtime import OverloadPolicy, ServingRuntime
    from repro.launch.serve import SearchServer

    rows = []
    for engine in engines:
        server = SearchServer(
            corpus, engine=engine, cfg=dict(cfgs[engine]),
            chaos={"seed": 11, "rules": [
                {"site": "slow_search", "kind": "latency", "rate": 1.0,
                 "ms": spike_ms},
                # the storm: every dispatch in the callno window fails
                # (the saturation bursts below consume ~20-45 callnos, so
                # the window lands inside the measured open-loop cell)
                {"site": "slow_search", "kind": "error",
                 "start": storm[0], "stop": storm[1]},
            ]})
        for b in (1, 2, 4, 8, 16):
            for bb in (8, 16, 32, 64, 128, budget):
                server.query(queries[:b], k=k, budget=bb, record=False)
        pol = OverloadPolicy(capacity=256, max_batch=16, flush_ms=2.0,
                             budget=budget, budget_floor=32,
                             breaker_trip=5, breaker_cooldown_s=0.05)
        runtime = ServingRuntime(server, pol).start()
        try:  # closed-loop warm burst = the saturation measurement
            for rep in range(2):
                t0 = time.perf_counter()
                ts = []
                for j in range(128):
                    try:
                        ts.append(runtime.submit(queries[j % len(queries)],
                                                 k=k))
                    except Exception:
                        pass
                for t in ts:
                    try:
                        t.result(timeout=120)
                    except Exception:
                        pass  # injected dispatch faults: expected here
                sat_qps = 128 / (time.perf_counter() - t0)
        finally:
            runtime.stop()
        runtime = ServingRuntime(server, pol).start()
        try:
            cell = _open_loop_cell(
                runtime, queries, gt_idx, offered_qps=sat_qps,
                duration_s=duration_s, deadline_ms=deadline_ms, k=k,
                seed=23)
        finally:
            runtime.stop()
        rows.append({
            "engine": engine, "cell": "fault_x_load",
            "n": len(corpus), "k": k,
            "storm_calls": storm[1] - storm[0],
            "deadline_ms": deadline_ms, "sat_qps": round(sat_qps, 1),
            **cell,
        })
        if verbose:
            print(
                f"  {engine:10s} fault_x_load trips={cell['breaker_trips']} "
                f"shed={cell['shed_rate']:.2f} "
                f"goodput={cell['goodput_qps']:.0f} "
                f"p99_ok={cell['p99_ok_ms']}ms"
            )
    return rows


def run(
    n=2048, qbatch=64, batches=8, k=10, engines="brute,ivf_flat",
    rates=(0.0, 0.1, 0.3), deadline_ms=250.0, spike_ms=5.0, budget=256,
    rerank=96, train_steps=200, proj_sample=512, verbose=True,
    load_cell=True,
):
    """Fault-rate sweep; returns one row per (engine, rate), plus the
    combined fault x load cell per engine (``load_cell=False`` skips)."""
    from benchmarks.common import recall_at_k
    from repro.core import chaos as chaos_lib
    from repro.core import index as index_lib
    from repro.data import synthetic
    from repro.launch.serve import SearchServer, default_cfg

    pool = synthetic.make("manifold", n + qbatch * batches, seed=0)
    corpus, queries = np.asarray(pool[:n]), np.asarray(pool[n:])
    gt = index_lib.build("brute", corpus, {}).search(queries, k=k)
    gt_idx = np.asarray(gt.idx)
    qbatches = [queries[i * qbatch : (i + 1) * qbatch] for i in range(batches)]

    rows = []
    cfgs = {}
    for engine in [e.strip() for e in engines.split(",") if e.strip()]:
        cfg = default_cfg(engine, budget=budget, rerank=rerank,
                          train_steps=train_steps, proj_sample=proj_sample)
        cfgs[engine] = cfg
        for rate in rates:
            rules = []
            if rate > 0:
                rules = [
                    {"site": "search", "kind": "latency",
                     "rate": rate, "ms": spike_ms},
                    # transient failures at half the spike rate: each costs
                    # a backoff-retry, the deterministic draws make the
                    # injection sequence identical across runs
                    {"site": "search", "kind": "error", "rate": rate / 2},
                ]
            plan = chaos_lib.FaultPlan(seed=7, rules=rules)
            server = SearchServer(corpus, engine=engine, cfg=dict(cfg),
                                  chaos=plan)
            # warm-up outside the measured trace (and outside the plan's
            # retry budget accounting below)
            server.query(qbatches[0], k=k, budget=budget, record=False)
            lat, idx_rows = [], []
            retries = degraded = misses = 0
            for qb in qbatches:
                t0 = time.perf_counter()
                res = server.query(qb, k=k, budget=budget,
                                   deadline_ms=deadline_ms)
                lat.append(time.perf_counter() - t0)
                idx_rows.append(res.idx)
                retries += res.retries
                degraded += int(res.degraded)
                misses += int(not res.deadline_met)
            lat_ms = np.asarray(lat) * 1e3
            rows.append({
                "engine": engine, "fault_rate": float(rate),
                "n": n, "k": k, "deadline_ms": deadline_ms,
                "recall@k": recall_at_k(np.concatenate(idx_rows), gt_idx, k),
                "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
                "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
                "retries": retries,
                "degraded_batches": degraded,
                "deadline_misses": misses,
                "injected": dict(plan.counters),
                "health": server.health,
            })
            if verbose:
                r = rows[-1]
                print(
                    f"  {engine:10s} rate={rate:<4} recall@{k}={r['recall@k']:.3f} "
                    f"p50={r['p50_ms']:7.2f}ms p99={r['p99_ms']:7.2f}ms "
                    f"retries={retries} injected={sum(plan.counters.values())}"
                )
    if load_cell:
        rows += fault_load_rows(
            corpus, queries[:256], gt_idx[:256], engines=list(cfgs),
            cfgs=cfgs, k=k, budget=budget, verbose=verbose)
    return rows


def write_artifact(rows, path="experiments/BENCH_fault.json") -> None:
    """Single owner of the machine-readable fault-tolerance artifact
    (also called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)
    print(f"wrote {path} ({len(rows)} rows)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--qbatch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,ivf_flat")
    ap.add_argument("--rates", default="0,0.1,0.3",
                    help="comma-separated per-call fault probabilities")
    ap.add_argument("--deadline-ms", type=float, default=250.0)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--out", default="experiments/BENCH_fault.json")
    args = ap.parse_args()
    rows = run(
        n=args.n, qbatch=args.qbatch, batches=args.batches, k=args.k,
        engines=args.engines,
        rates=tuple(float(r) for r in args.rates.split(",")),
        deadline_ms=args.deadline_ms, train_steps=args.train_steps,
    )
    write_artifact(rows, args.out)


if __name__ == "__main__":
    main()
