"""Fault-injection benchmark: recall / latency vs injected fault rate
(DESIGN.md §14).

For each engine in the sweep and each fault rate, arms a deterministic
``core/chaos.FaultPlan`` (latency spikes + transient search failures at
the given per-call probability) on a ``SearchServer`` and drives a batch
trace through ``query(deadline_ms=...)``.  Recorded per (engine, rate):
recall@k against the brute-force oracle, p50/p99 latency, retries the
controller absorbed, degraded answers, deadline misses, and the plan's own
injection totals — the measurable claim is that recall and p99 degrade
*gracefully* as the fault rate rises, with zero unhandled exceptions.

``benchmarks/run.py`` writes the rows to ``experiments/BENCH_fault.json``
(stamped with run provenance) and CI smoke-runs the standalone entry point
next to bench_quant.

  PYTHONPATH=src python benchmarks/bench_fault.py --n 1024 \
      --engines brute,ivf_flat --rates 0,0.1,0.3
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__":  # standalone: python benchmarks/bench_fault.py
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def run(
    n=2048, qbatch=64, batches=8, k=10, engines="brute,ivf_flat",
    rates=(0.0, 0.1, 0.3), deadline_ms=250.0, spike_ms=5.0, budget=256,
    rerank=96, train_steps=200, proj_sample=512, verbose=True,
):
    """Fault-rate sweep; returns one row per (engine, rate)."""
    from benchmarks.common import recall_at_k
    from repro.core import chaos as chaos_lib
    from repro.core import index as index_lib
    from repro.data import synthetic
    from repro.launch.serve import SearchServer, default_cfg

    pool = synthetic.make("manifold", n + qbatch * batches, seed=0)
    corpus, queries = np.asarray(pool[:n]), np.asarray(pool[n:])
    gt = index_lib.build("brute", corpus, {}).search(queries, k=k)
    gt_idx = np.asarray(gt.idx)
    qbatches = [queries[i * qbatch : (i + 1) * qbatch] for i in range(batches)]

    rows = []
    for engine in [e.strip() for e in engines.split(",") if e.strip()]:
        cfg = default_cfg(engine, budget=budget, rerank=rerank,
                          train_steps=train_steps, proj_sample=proj_sample)
        for rate in rates:
            rules = []
            if rate > 0:
                rules = [
                    {"site": "search", "kind": "latency",
                     "rate": rate, "ms": spike_ms},
                    # transient failures at half the spike rate: each costs
                    # a backoff-retry, the deterministic draws make the
                    # injection sequence identical across runs
                    {"site": "search", "kind": "error", "rate": rate / 2},
                ]
            plan = chaos_lib.FaultPlan(seed=7, rules=rules)
            server = SearchServer(corpus, engine=engine, cfg=dict(cfg),
                                  chaos=plan)
            # warm-up outside the measured trace (and outside the plan's
            # retry budget accounting below)
            server.query(qbatches[0], k=k, budget=budget, record=False)
            lat, idx_rows = [], []
            retries = degraded = misses = 0
            for qb in qbatches:
                t0 = time.perf_counter()
                res = server.query(qb, k=k, budget=budget,
                                   deadline_ms=deadline_ms)
                lat.append(time.perf_counter() - t0)
                idx_rows.append(res.idx)
                retries += res.retries
                degraded += int(res.degraded)
                misses += int(not res.deadline_met)
            lat_ms = np.asarray(lat) * 1e3
            rows.append({
                "engine": engine, "fault_rate": float(rate),
                "n": n, "k": k, "deadline_ms": deadline_ms,
                "recall@k": recall_at_k(np.concatenate(idx_rows), gt_idx, k),
                "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
                "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
                "retries": retries,
                "degraded_batches": degraded,
                "deadline_misses": misses,
                "injected": dict(plan.counters),
                "health": server.health,
            })
            if verbose:
                r = rows[-1]
                print(
                    f"  {engine:10s} rate={rate:<4} recall@{k}={r['recall@k']:.3f} "
                    f"p50={r['p50_ms']:7.2f}ms p99={r['p99_ms']:7.2f}ms "
                    f"retries={retries} injected={sum(plan.counters.values())}"
                )
    return rows


def write_artifact(rows, path="experiments/BENCH_fault.json") -> None:
    """Single owner of the machine-readable fault-tolerance artifact
    (also called by benchmarks/run.py); stamped with run provenance."""
    from benchmarks.common import write_stamped

    write_stamped(path, rows)
    print(f"wrote {path} ({len(rows)} rows)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--qbatch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,ivf_flat")
    ap.add_argument("--rates", default="0,0.1,0.3",
                    help="comma-separated per-call fault probabilities")
    ap.add_argument("--deadline-ms", type=float, default=250.0)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--out", default="experiments/BENCH_fault.json")
    args = ap.parse_args()
    rows = run(
        n=args.n, qbatch=args.qbatch, batches=args.batches, k=args.k,
        engines=args.engines,
        rates=tuple(float(r) for r in args.rates.split(",")),
        deadline_ms=args.deadline_ms, train_steps=args.train_steps,
    )
    write_artifact(rows, args.out)


if __name__ == "__main__":
    main()
