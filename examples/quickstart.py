"""Quickstart: build an Infinity Search index and query it.

  PYTHONPATH=src python examples/quickstart.py
"""
import math
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.search import IndexConfig, InfinityIndex
from repro.data import synthetic


def main() -> None:
    # 1) data: 3k manifold-structured vectors, 200 held-out queries
    X = synthetic.make("manifold", 3200, seed=0)
    Xtr, Q = jnp.asarray(X[:3000]), jnp.asarray(X[3000:])

    # 2) build the index (sparse canonical projection -> learned Phi -> VP
    # tree).  q interpolates speed vs accuracy (paper §2): q=2 is the
    # accurate end; q=inf reaches the Theorem-1 descent (<= depth
    # comparisons) at lower recall.
    cfg = IndexConfig(q=2.0, metric="euclidean", proj_sample=1000,
                      train_steps=1000, embed_dim=32)
    print("building index (projection + Phi training + tree)...")
    index = InfinityIndex.build(Xtr, cfg)
    print(f"  tree: {index.tree.num_nodes} nodes, depth {index.tree.depth}")

    # 3) search: budgeted best-first, and accurate two-stage
    gt, _, _ = baselines.brute_force(Xtr, Q, k=1)
    for name, kwargs in [
        ("fast (budget=64)", dict(mode="best_first", max_comparisons=64)),
        ("two-stage (K=96)", dict(mode="best_first", max_comparisons=256, rerank=96)),
    ]:
        idx, dist, comps = index.search(Q, k=1, **kwargs)
        recall = float(np.mean(np.asarray(idx)[:, 0] == np.asarray(gt)[:, 0]))
        print(f"  {name}: recall@1={recall:.3f} "
              f"mean comparisons={float(np.mean(np.asarray(comps))):.0f} "
              f"(vs {Xtr.shape[0]} brute-force)")


if __name__ == "__main__":
    main()
