"""The paper's technique wired into the recsys serving path: retrieval_cand
scores one user against a large candidate set either exactly (batched dot —
the dry-run default) or through an InfinitySearch index over the candidate
embeddings (sub-linear comparisons at high recall).

  PYTHONPATH=src python examples/recsys_retrieval.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.search import IndexConfig, InfinityIndex
from repro.models import params as plib, recsys


def main() -> None:
    cfg = configs.get_reduced("fm")
    decls = recsys.recsys_decls(cfg)
    params = plib.init_params(jax.random.PRNGKey(0), decls)
    rng = np.random.default_rng(0)
    n_cand, n_users = 20000, 32

    cand = jnp.asarray(rng.normal(size=(n_cand, cfg.embed_dim)).astype(np.float32))
    ids = jnp.asarray(np.stack(
        [rng.integers(0, v, size=n_users) for v in cfg.vocabs[: cfg.n_sparse]], axis=1
    ).astype(np.int32))
    users = recsys.user_embedding(params, ids, cfg)

    # exact: batched dot + top-k
    t0 = time.perf_counter()
    s_exact, i_exact = recsys.retrieval_score(users, cand, k=10)
    jax.block_until_ready(i_exact)
    t_exact = time.perf_counter() - t0
    print(f"exact dot scoring: {t_exact*1e3:.1f} ms for {n_users}x{n_cand}")

    # approximate: InfinitySearch over L2-NORMALIZED candidates with the
    # euclidean metric (monotone in cosine; raw negative-dot violates the
    # projection's non-negativity assumption — paper footnote 3)
    cn = cand / jnp.linalg.norm(cand, axis=1, keepdims=True)
    un = users / jnp.linalg.norm(users, axis=1, keepdims=True)
    icfg = IndexConfig(q=2.0, metric="euclidean", proj_sample=1000,
                       train_steps=800, embed_dim=16, hidden=(128, 128))
    index = InfinityIndex.build(cn, icfg)
    idx, dist, comps = index.search(un, k=10, mode="best_first",
                                    max_comparisons=384, rerank=128)
    # reference: top-10 by cosine (the normalized objective)
    s_cos = jnp.einsum("bd,nd->bn", un, cn)
    i_cos = np.asarray(jnp.argsort(-s_cos, axis=1)[:, :10])
    rec = np.mean([
        len(set(map(int, a)) & set(map(int, t))) / 10
        for a, t in zip(np.asarray(idx), i_cos)
    ])
    print(f"infinity-search: recall@10={rec:.3f} "
          f"mean comparisons={float(np.mean(np.asarray(comps))):.0f} "
          f"(exact scans {n_cand})")


if __name__ == "__main__":
    main()
