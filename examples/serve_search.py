"""End-to-end serving driver (the paper's kind is a retrieval system): build
an Infinity Search index over a corpus and serve batched query traffic,
reporting latency percentiles, throughput and recall — the production shape
of Fig. 18's online path.

  PYTHONPATH=src python examples/serve_search.py [--n 10000] [--batches 20]
"""
import argparse
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.search import IndexConfig, InfinityIndex
from repro.data import synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    X = synthetic.make("manifold", args.n + args.batch * args.batches, seed=0)
    Xtr = jnp.asarray(X[: args.n])
    queries = X[args.n :]

    t0 = time.perf_counter()
    cfg = IndexConfig(q=2.0, metric="euclidean", proj_sample=1200,
                      train_steps=900, embed_dim=32)
    index = InfinityIndex.build(Xtr, cfg)
    print(f"index built over n={args.n} in {time.perf_counter()-t0:.1f}s "
          f"(tree depth {index.tree.depth})")

    # compile the serving path once
    warm = jnp.asarray(queries[: args.batch])
    index.search(warm, k=args.k, mode="best_first", max_comparisons=256, rerank=64)

    lat, recs = [], []
    for b in range(args.batches):
        qb = jnp.asarray(queries[b * args.batch : (b + 1) * args.batch])
        t0 = time.perf_counter()
        idx, dist, comps = index.search(
            qb, k=args.k, mode="best_first", max_comparisons=256, rerank=64
        )
        jax.block_until_ready(idx)
        lat.append(time.perf_counter() - t0)
        gt, _, _ = baselines.brute_force(Xtr, qb, k=args.k)
        hit = np.mean([
            len(set(map(int, a)) & set(map(int, t))) / args.k
            for a, t in zip(np.asarray(idx), np.asarray(gt))
        ])
        recs.append(hit)
    lat_ms = np.asarray(lat) * 1e3
    print(f"served {args.batches} batches x {args.batch} queries:")
    print(f"  latency p50={np.percentile(lat_ms,50):.1f}ms "
          f"p99={np.percentile(lat_ms,99):.1f}ms  "
          f"throughput={args.batch/np.mean(lat):.0f} qps")
    print(f"  recall@{args.k}={np.mean(recs):.3f}")


if __name__ == "__main__":
    main()
