"""End-to-end serving demo: one corpus, every engine, hot-swapped live.

Builds a ``SearchServer`` over a synthetic corpus, then swaps the serving
engine through the ``core/index`` registry (brute -> ivf_flat -> nsw ->
infinity by default) WITHOUT reloading the corpus — the production shape of
Fig. 18's online path behind one uniform ``build/search`` contract.  Each
engine reports p50/p99 latency, QPS, comparisons/query and recall against
the registry's own brute-force oracle.

  PYTHONPATH=src python examples/serve_search.py [--n 10000] [--shards 2] \
      [--engines ivf_flat,nsw,infinity]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks.common import recall_at_k
from repro.core import index as index_lib
from repro.data import synthetic
from repro.launch.serve import SearchServer, default_cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--rerank", type=int, default=64)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--engines", default="brute,ivf_flat,nsw,infinity",
                    help="comma list of registry keys to hot-swap through")
    ap.add_argument("--train-steps", type=int, default=900)
    args = ap.parse_args()

    n_q = args.batch * args.batches
    X = synthetic.make("manifold", args.n + n_q, seed=0)
    corpus, queries = X[: args.n], X[args.n :]
    batches = [queries[b * args.batch : (b + 1) * args.batch]
               for b in range(args.batches)]

    # oracle once, reused for every engine's recall
    gt = index_lib.build("brute", corpus, {}).search(queries, k=args.k)
    gt_idx = np.asarray(gt.idx)

    engines = [e.strip() for e in args.engines.split(",") if e.strip()]
    server = None
    print(f"corpus n={args.n}, {n_q} queries, k={args.k}, shards={args.shards}")
    for engine in engines:
        cfg = default_cfg(engine, budget=args.budget, rerank=args.rerank,
                          train_steps=args.train_steps)
        if server is None:
            server = SearchServer(corpus, engine=engine, shards=args.shards, cfg=cfg)
        else:
            server.swap(engine, shards=args.shards, cfg=cfg)  # hot-swap
        stats = server.serve(batches, k=args.k, budget=args.budget)
        res = server.query(queries, k=args.k, budget=args.budget)
        recall = recall_at_k(np.asarray(res.idx), gt_idx, args.k)
        print(
            f"  {engine:10s} build={stats['build_s']:6.1f}s "
            f"p50={stats['p50_ms']:6.1f}ms p99={stats['p99_ms']:6.1f}ms "
            f"qps={stats['qps']:7.0f} comps={stats['mean_comparisons']:7.0f} "
            f"recall@{args.k}={recall:.3f}"
        )


if __name__ == "__main__":
    main()
