"""End-to-end serving demo: one corpus, every engine, hot-swapped live.

Builds a ``SearchServer`` over a synthetic corpus, then swaps the serving
engine through the ``core/index`` registry (brute -> ivf_flat -> nsw ->
infinity by default) WITHOUT reloading the corpus — the production shape of
Fig. 18's online path behind one uniform ``build/search`` contract.  Each
engine reports p50/p99 latency, QPS, comparisons/query and recall against
the registry's own brute-force oracle.

  PYTHONPATH=src python examples/serve_search.py [--n 10000] [--shards 2] \
      [--engines ivf_flat,nsw,infinity] [--live]

``--live`` serves every engine through the ``core/live`` mutable wrapper
and runs a churn burst (upserts + deletes) before the measurement;
``server.stats()`` then shows the segment composition — frozen size, delta
fill, tombstones, generation — alongside p50/p99/QPS, the numbers an
operator watches to see compaction pressure.

``--filter-demo`` attaches demo attribute columns (``category`` c0..c7,
``score`` uniform [0,1)) and, after the engine sweep, answers one query
twice against the running server — unfiltered, then with a categorical +
range predicate — printing the top-k side by side so the constrained
answer is visibly drawn from the passing rows only.

``--quant`` serves every engine with the reserved ``quant`` registry cfg
key (``core/quant``, DESIGN.md §13): the corpus is mirrored as
per-dimension int8 codes, the scan engines' first pass reads 1 byte/dim
and a pow2 shortlist is exactly reranked in f32; ``server.stats()`` then
reports ``quant_bytes`` — the code-store footprint — next to memory/QPS.

``--beam-demo`` runs the infinity engine's two traversal modes head to
head on the same query batch (DESIGN.md §15): the per-query best-first
host loop vs the one-dispatch batched beam, printing p50 latency, QPS,
comparisons and recall side by side.  Batched serving auto-routes to the
beam (``mode="auto"``); this flag makes the win visible.

``--metrics-port`` enables the ``core/telemetry`` registry and serves its
Prometheus text exposition at ``http://127.0.0.1:PORT/metrics`` from a
stdlib ``http.server`` thread for the whole run (DESIGN.md §16);
``--hold-metrics SECONDS`` keeps the process (and the endpoint) alive
after the sweep so a scraper can collect the final counters, and
``--trace-out PATH`` writes the bounded trace ring as Chrome/Perfetto
``trace_event`` JSON on exit — load it at ui.perfetto.dev for the
per-stage flamegraph.

``--deadline-ms`` / ``--chaos`` exercise fault-tolerant serving
(DESIGN.md §14): ``--chaos JSON`` arms a deterministic
``core/chaos.FaultPlan`` (e.g. ``'{"seed": 0, "rules": [{"site":
"search", "kind": "latency", "rate": 0.1, "ms": 20}]}'``) on every
served engine, and ``--deadline-ms`` runs each request through the
degradation controller — the comparison budget shrinks with the
remaining deadline, transient faults retry with capped backoff, dead
shards are masked out of the merge.  The per-engine line then reports
degraded/retry counts and the server's health next to recall.

Reading the observatory (DESIGN.md §17)
---------------------------------------

``--probe-rate R`` arms the online recall probe: a seeded deterministic
R-fraction of served queries is shadowed through the exact brute-force
oracle and a sliding-window recall@k estimate with its Wilson 95%
interval accumulates as the sweep runs.  ``--probe-slo FLOOR`` adds the
quality SLO: if the interval's *upper* bound sits below FLOOR over
enough probes, the server walks its health machine to DEGRADED and
counts ``quality_degraded_total``.  The per-engine stats line grows a
``quality`` segment (estimate [lo, hi] over probed count), and the
Prometheus exposition carries ``recall_estimate{engine=...,q=...,k=...}``
/ ``probe_total`` — recall as a *live time series*, not a post-hoc bench
column.

``--roofline`` profiles each engine's compiled serving program after its
measurement: the batched ``search`` dispatch is lowered and compiled
AOT, its optimized HLO pushed through the loop-aware ``dist/roofline``
accounting, and the per-program flops / HBM bytes / arithmetic intensity
/ predicted-vs-measured time printed and exported as
``roofline_*{program=search:<engine>}`` gauges — ``roofline_pct_of_peak``
says how close that program runs to the modeled hardware ceiling (tiny
on the CPU demo backend, by design honest).

Together with ``--metrics-port`` this is the full observatory: scrape
``/metrics`` and you get latency (``search_seconds``), quality
(``recall_estimate`` + CI bounds), and efficiency (``roofline_*``) for
the serving process in one pull.

``--load-demo`` mounts the async overload runtime (DESIGN.md §18) on the
last served engine and pushes a deliberately over-capacity burst through
it: a small bounded queue admits what fits, rejects the rest with
``retry_after``, forms continuous batches, and reports every outcome
explicitly.  The point of the demo is the metric surface — after it runs
the exposition carries ``queue_depth``, ``admission_total{outcome=...}``,
``shed_total{reason=...}``, ``batch_fill`` and ``breaker_state``, so a
scraper sees the overload series next to the latency/quality/efficiency
ones (CI greps exactly these).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks.common import recall_at_k
from repro.core import index as index_lib
from repro.core import telemetry as telem
from repro.data import synthetic
from repro.launch.serve import SearchServer, default_cfg


def start_metrics_server(port: int):
    """Serve ``telem.metrics_text()`` at /metrics on a daemon thread.

    Stdlib-only (DESIGN.md §16): a tiny ``http.server`` handler that
    renders the process-wide registry fresh on every GET — the pull model
    Prometheus expects.  Returns the bound (host, port) so callers can
    print the scrape target (port 0 binds an ephemeral port)."""
    import http.server
    import threading

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (stdlib handler contract)
            if self.path.rstrip("/") in ("", "/metrics".rstrip("/")):
                body = telem.metrics_text().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            else:
                self.send_error(404)

        def log_message(self, *a):  # keep the demo's stdout clean
            pass

    httpd = http.server.ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    return httpd.server_address


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--rerank", type=int, default=64)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--engines", default="brute,ivf_flat,nsw,infinity",
                    help="comma list of registry keys to hot-swap through")
    ap.add_argument("--train-steps", type=int, default=900)
    ap.add_argument("--live", action="store_true",
                    help="serve through the mutable live wrapper with a churn burst")
    ap.add_argument("--delta-cap", type=int, default=512)
    ap.add_argument("--filter-demo", action="store_true",
                    help="attach demo attribute columns and print a filtered "
                         "vs. unfiltered top-k comparison after the sweep")
    ap.add_argument("--quant", action="store_true",
                    help="serve on int8 corpus codes (the 'quant' registry "
                         "cfg key): 1 byte/dim first pass + exact f32 rerank")
    ap.add_argument("--beam-demo", action="store_true",
                    help="after the sweep, race the infinity engine's "
                         "best_first and beam traversals on one batch "
                         "(DESIGN.md §15)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline: budget shrinks as it drains, "
                         "transient faults retry, dead shards are masked "
                         "out (DESIGN.md §14)")
    ap.add_argument("--chaos", default=None, metavar="JSON",
                    help="deterministic core/chaos FaultPlan spec armed on "
                         "every served engine; sites: search/shard/build/"
                         "compact/delta/snapshot")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="enable core/telemetry and serve Prometheus "
                         "exposition at http://127.0.0.1:PORT/metrics "
                         "(0 = ephemeral port) for the whole run")
    ap.add_argument("--hold-metrics", type=float, default=0.0,
                    metavar="SECONDS",
                    help="keep the process (and /metrics) alive this long "
                         "after the sweep so a scraper can collect the "
                         "final counters")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the telemetry trace ring as Chrome/Perfetto "
                         "trace_event JSON on exit (enables telemetry)")
    ap.add_argument("--probe-rate", type=float, default=None, metavar="R",
                    help="shadow this fraction of served queries through "
                         "the exact oracle: sliding-window recall@k with "
                         "Wilson CI in stats()['quality'] and the "
                         "recall_estimate gauge (DESIGN.md §17)")
    ap.add_argument("--probe-slo", type=float, default=None, metavar="FLOOR",
                    help="sustained probe recall below FLOOR walks server "
                         "health to DEGRADED (requires --probe-rate)")
    ap.add_argument("--load-demo", action="store_true",
                    help="after the sweep, serve an over-capacity burst "
                         "through the async overload runtime so the "
                         "queue_depth / admission_total / breaker_state "
                         "series exist in /metrics (DESIGN.md §18)")
    ap.add_argument("--roofline", action="store_true",
                    help="after each engine's sweep, profile its compiled "
                         "serving program (flops/HBM/intensity/%%-of-peak) "
                         "and export roofline_* gauges")
    args = ap.parse_args()

    if args.metrics_port is not None or args.trace_out:
        telem.enable()
    if args.metrics_port is not None:
        host, port = start_metrics_server(args.metrics_port)
        print(f"metrics: http://{host}:{port}/metrics", flush=True)

    n_q = args.batch * args.batches
    X = synthetic.make("manifold", args.n + n_q, seed=0)
    corpus, queries = X[: args.n], X[args.n :]
    attrs = None
    if args.filter_demo:
        from repro.launch.serve import demo_attrs

        attrs = demo_attrs(args.n)
    batches = [queries[b * args.batch : (b + 1) * args.batch]
               for b in range(args.batches)]

    # oracle once, reused for every engine's recall
    gt = index_lib.build("brute", corpus, {}).search(queries, k=args.k)
    gt_idx = np.asarray(gt.idx)

    engines = [e.strip() for e in args.engines.split(",") if e.strip()]
    server = None
    print(f"corpus n={args.n}, {n_q} queries, k={args.k}, shards={args.shards}")
    for engine in engines:
        cfg = default_cfg(engine, budget=args.budget, rerank=args.rerank,
                          train_steps=args.train_steps)
        if server is None:
            import json as json_lib

            probe = None
            if args.probe_rate is not None:
                probe = {"rate": args.probe_rate, "k": args.k}
                if args.probe_slo is not None:
                    probe["slo_floor"] = args.probe_slo
            server = SearchServer(corpus, engine=engine, shards=args.shards,
                                  cfg=cfg, live=args.live,
                                  delta_cap=args.delta_cap, attrs=attrs,
                                  quant=args.quant,
                                  probe=probe,
                                  chaos=json_lib.loads(args.chaos)
                                  if args.chaos else None)
        else:
            server.swap(engine, shards=args.shards, cfg=cfg)  # hot-swap
        if args.live:
            # churn burst BEFORE measuring: the delta + tombstones are live
            # during the latency sweep, which is the realistic serving state
            rng = np.random.default_rng(7)
            new_ids = server.upsert(
                rng.normal(size=(args.batch, corpus.shape[1])).astype(np.float32))
            server.delete(new_ids[: len(new_ids) // 2])
        stats = server.serve(batches, k=args.k, budget=args.budget,
                             deadline_ms=args.deadline_ms)
        res = server.query(queries, k=args.k, budget=args.budget,
                           deadline_ms=args.deadline_ms)
        if args.live:
            # the churn changed the served corpus: score against an oracle
            # over the index's own logical view, with slot ids mapped to it
            logical = server.index.corpus()
            gt_live = index_lib.build("brute", logical, {}).search(
                queries, k=args.k)
            s2l = server.index.slot_to_logical()
            idx = np.asarray(res.idx)
            mapped = np.where(idx >= 0, s2l[np.maximum(idx, 0)], -1)
            recall = recall_at_k(mapped, np.asarray(gt_live.idx), args.k)
        else:
            recall = recall_at_k(np.asarray(res.idx), gt_idx, args.k)
        print(
            f"  {engine:10s} build={stats['build_s']:6.1f}s "
            f"p50={stats['p50_ms']:6.1f}ms p99={stats['p99_ms']:6.1f}ms "
            f"qps={stats['qps']:7.0f} comps={stats['mean_comparisons']:7.0f} "
            f"recall@{args.k}={recall:.3f}"
        )
        # the operator view: cumulative latency percentiles + (when --live)
        # the segment composition that signals when a compaction is due
        s = server.stats()
        line = (f"    stats: queries={s['queries']} p50={s.get('p50_ms', 0):.1f}ms "
                f"p99={s.get('p99_ms', 0):.1f}ms qps={s.get('qps', 0):.0f}")
        if s["live"]:
            line += (f" | gen={s['generation']} frozen={s['frozen_size']} "
                     f"delta={s['delta_fill']}/{s['delta_cap']} "
                     f"tombstones={s['tombstones']} alive={s['n_alive']}")
        if s.get("quant_bytes"):
            line += (f" | quant={s['quant_bytes']}B codes "
                     f"of {s['memory_bytes']}B total")
        if args.deadline_ms is not None or args.chaos:
            line += (f" | health={s['health']} "
                     f"degraded={stats.get('degraded_batches', 0)} "
                     f"misses={stats.get('deadline_misses', 0)} "
                     f"retries={stats.get('retries', 0)}")
        if "quality" in s:
            qq = s["quality"]
            line += (f" | quality={qq['recall_estimate']:.3f} "
                     f"[{qq['ci_low']:.3f},{qq['ci_high']:.3f}] "
                     f"probed={qq['probed']}/{qq['seen']}")
            if qq.get("breached"):
                line += " BREACHED"
        print(line)
        if args.roofline:
            # profile THIS engine's compiled serving program while it is
            # still the one mounted (swap would recapture a different one)
            try:
                profs = server.capture_roofline(k=args.k, budget=args.budget)
                for name, blk in profs.items():
                    print(f"    roofline: {name} flops={blk['flops']:.3g} "
                          f"hbm={blk['hbm_bytes']:.3g}B "
                          f"AI={blk['intensity']:.3f} "
                          f"predicted={blk['t_predicted_s'] * 1e6:.0f}us "
                          f"measured={blk.get('t_measured_s', 0) * 1e6:.0f}us "
                          f"pct_of_peak={blk.get('pct_of_peak') or 0:.4%} "
                          f"({blk['dominant']}-bound)")
            except Exception as e:
                print(f"    roofline: capture failed ({type(e).__name__}: {e})")

    if args.beam_demo:
        # same engine, same queries, both traversals: the host best-first
        # loop pays one device round trip per node pop; the beam pays one
        # dispatch per batch (DESIGN.md §15)
        import time

        cfg = default_cfg("infinity", budget=args.budget, rerank=args.rerank,
                          train_steps=args.train_steps)
        eng = index_lib.build("infinity", corpus, cfg)
        print(f"\n  beam demo: infinity engine, {n_q} queries, "
              f"budget={args.budget}")
        for mode in ("best_first", "beam"):
            eng.search(queries[: min(8, n_q)], k=args.k, mode=mode)  # warm
            t0 = time.perf_counter()
            res = eng.search(queries, k=args.k, mode=mode)
            np.asarray(res.idx)
            dt = time.perf_counter() - t0
            print(f"    {mode:10s} p50={dt * 1e3:8.1f}ms "
                  f"qps={n_q / dt:8.0f} "
                  f"comps={float(np.asarray(res.comparisons).mean()):7.0f} "
                  f"recall@{args.k}="
                  f"{recall_at_k(np.asarray(res.idx), gt_idx, args.k):.3f}")

    if args.filter_demo:
        # filtered vs. unfiltered, side by side, against the RUNNING server
        # (whatever engine the sweep ended on — live wrapper included): a
        # categorical isin clause AND a numeric range clause
        flt = {"category": {"isin": ["c0", "c1"]}, "score": {"range": [0.25, None]}}
        q1 = queries[:1]
        plain = server.query(q1, k=args.k, budget=args.budget)
        filt = server.query(q1, k=args.k, budget=args.budget, filter=flt)
        cats, scores = attrs["category"], np.asarray(attrs["score"])

        def describe(i):
            if i < 0:
                return "--"
            if i < args.n:
                return f"{i:5d} {cats[i]}/{scores[i]:.2f}"
            return f"{i:5d} (delta row)"

        print(f"\n  filtered-query demo on {server.engine!r}: {flt}")
        print(f"  {'unfiltered top-k':28s}   filtered top-k")
        for a, da, b, db in zip(plain.idx[0], plain.dist[0],
                                filt.idx[0], filt.dist[0]):
            print(f"    {describe(int(a)):20s} d={da:6.3f}   "
                  f"{describe(int(b)):20s} d={db:6.3f}")
        passing = [int(i) for i in filt.idx[0]
                   if 0 <= int(i) < args.n]
        assert all(cats[i] in ("c0", "c1") and scores[i] >= 0.25
                   for i in passing), "filtered answer leaked a non-passing row"
        print("  every filtered result satisfies the predicate")

    if args.load_demo:
        # over-capacity burst through the async runtime on whatever engine
        # the sweep ended on: capacity 64 vs 128 submits guarantees visible
        # rejected_capacity outcomes (and therefore the admission_total
        # series CI greps for) without needing a sustained load generator
        from repro.launch.runtime import (OverloadPolicy, Rejected,
                                          ServingRuntime)

        pol = OverloadPolicy(capacity=64, max_batch=8, flush_ms=2.0,
                             budget=args.budget)
        runtime = ServingRuntime(server, pol).start()
        outcomes: dict = {}
        rejected = 0
        try:
            tickets = []
            for j in range(128):
                try:
                    tickets.append(runtime.submit(
                        queries[j % n_q], k=args.k,
                        deadline_ms=args.deadline_ms or 250.0))
                except Rejected:
                    rejected += 1
            for t in tickets:
                r = t.result(timeout=60.0)
                outcomes[r.outcome] = outcomes.get(r.outcome, 0) + 1
        finally:
            runtime.stop()
        rs = runtime.stats()
        print(f"\n  load demo on {server.engine!r}: 128 submits through "
              f"capacity={pol.capacity} queue")
        print(f"    admitted={rs['admitted']} "
              f"rejected_capacity={rejected} outcomes={outcomes} "
              f"batches={rs['batches']} breaker={rs['breaker_state']}")

    if args.trace_out:
        print(f"trace -> {telem.dump_trace(args.trace_out)}", flush=True)
    if args.metrics_port is not None and args.hold_metrics > 0:
        import time as time_lib

        print(f"holding /metrics open for {args.hold_metrics:.0f}s", flush=True)
        time_lib.sleep(args.hold_metrics)


if __name__ == "__main__":
    main()
