"""Train an LM for a few hundred steps with the full substrate (optimizer,
fault supervisor, async checkpoints).  CPU-sized by default (reduced
config); the same driver runs the full configs on hardware.

  PYTHONPATH=src python examples/train_lm.py --arch smollm-135m --steps 200
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main  # the launcher IS the example driver

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + (sys.argv[1:] or
        ["--arch", "smollm-135m", "--steps", "200", "--batch", "8",
         "--seq-len", "64", "--ckpt-every", "50"])
    main()
