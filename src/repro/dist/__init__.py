"""Distribution layer: sharding policies, roofline accounting, gradient
compression and sharded embedding lookup (DESIGN.md §6)."""
