"""Gradient compression for the cross-pod axis (DESIGN.md §6).

``fake_int8_roundtrip`` models int8 quantize->transmit->dequantize with
per-leaf absmax scaling — numerically identical to what the wire would
carry, without needing an int8 collective.  ``ErrorFeedback`` carries the
quantization residual into the next step (1-bit-Adam-style memory), which
keeps the *accumulated* transmitted gradient unbiased.

The quantizer itself is ``core/quant.fake_quant`` — the ONE absmax int8
definition repo-wide, shared with the corpus-code scan subsystem
(DESIGN.md §13): same scale formula, same clipping, same eps floor.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quant import fake_quant as _quantize_leaf

PyTree = Any


def fake_int8_roundtrip(grads: PyTree) -> PyTree:
    """Per-leaf absmax int8 quantize + dequantize (max error = scale/2)."""
    return jax.tree_util.tree_map(_quantize_leaf, grads)


class ErrorFeedback:
    """Residual-carrying compression: sent_t = Q(g_t + r_t); r_{t+1} = g_t +
    r_t - sent_t.  Stateless namespace (the residual tree is the state)."""

    @staticmethod
    def init(grads: PyTree) -> PyTree:
        return jax.tree_util.tree_map(jnp.zeros_like, grads)

    @staticmethod
    def apply(grads: PyTree, residual: PyTree) -> tuple[PyTree, PyTree]:
        total = jax.tree_util.tree_map(lambda g, r: g + r, grads, residual)
        sent = jax.tree_util.tree_map(_quantize_leaf, total)
        new_resid = jax.tree_util.tree_map(lambda t, s: t - s, total, sent)
        return sent, new_resid
