"""Sharded embedding lookup (recsys hot path, DESIGN.md §6).

The table is row-sharded over every mesh axis (``recsys_policy``); a plain
``jnp.take`` under GSPMD becomes the gather-from-owning-shard pattern, and
the output is constrained to the batch sharding so the dense tower starts
from the layout the policy chose.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import DistCtx


def embedding_lookup(
    table: jax.Array, ids: jax.Array, dctx: Optional[DistCtx] = None
) -> jax.Array:
    """table (V, D), ids (...,) int -> (..., D)."""
    out = jnp.take(table, ids, axis=0)
    if dctx is None:
        return out
    spec = P(dctx.a_rules.get("batch"), *([None] * (out.ndim - 1)))
    return jax.lax.with_sharding_constraint(out, NamedSharding(dctx.mesh, spec))
