"""Roofline accounting from optimized HLO text (DESIGN.md §Dry-run).

XLA's ``cost_analysis()`` counts a while-loop (scan) body ONCE — for scanned
layer stacks and microbatch loops that underestimates flops by the trip
count.  ``hlo_stats`` re-derives loop-aware flops/bytes by walking the HLO
call graph (entry -> fusions / while bodies) and multiplying every dot by
the product of enclosing trip counts.  Trip counts come from the canonical
XLA loop-condition shape ``compare(counter, constant(N)), direction=LT``;
loops whose bound cannot be recovered fall back to ``default_trip`` (the
microbatch count the caller knows).

``parse_collectives`` applies the same loop scaling to collective bytes so
the collective roofline term sees the per-step traffic, not one iteration's.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

# per-chip hardware model (TPU v5p-class): bf16 peak, HBM and ICI bandwidth
PEAK_FLOPS = 4.59e14
HBM_BW = 2.76e12
ICI_BW = 9.0e10

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_TRIP_HINT_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: float
    count: int
    loop_trip_counts: dict


@dataclasses.dataclass
class HloStats:
    flops: float
    bytes: float
    dot_count: int


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of every typed array in an HLO shape string (handles
    tuples by summing members)."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> float:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0.0
    n = 1.0
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation name -> its instruction lines."""
    comps: dict[str, list[str]] = {}
    current: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_RE.match(stripped)
        if m and stripped.endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None and stripped:
            comps[current].append(line.rstrip())
    return comps


def _loop_bounds(comps: dict[str, list[str]], default_trip: int):
    """(body name -> trips, cond name -> body name) from while instructions."""
    trips: dict[str, int] = {}
    for lines in comps.values():
        for line in lines:
            if "while(" not in line:
                continue
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            if not (mc and mb):
                continue
            cond, body = mc.group(1), mb.group(1)
            hint = _TRIP_HINT_RE.search(line)  # XLA-annotated trip count
            if hint:
                trips[body] = int(hint.group(1))
            else:
                trips[body] = _trip_count_from_cond(comps.get(cond, []), default_trip)
    return trips


def _trip_count_from_cond(cond_lines: list[str], default_trip: int) -> int:
    """Recover N from the canonical ``i < N`` loop condition."""
    has_lt = any("direction=LT" in l for l in cond_lines)
    if not has_lt:
        return default_trip
    consts = []
    for l in cond_lines:
        m = re.search(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)", l)
        if m:
            consts.append(int(m.group(1)))
    return consts[-1] if consts else default_trip


def _multipliers(comps, body_trips, default_trip: int, entry: str) -> dict[str, float]:
    """Computation -> product of enclosing loop trip counts (call graph walk
    from the entry; while bodies multiply by their trip count)."""
    mult: dict[str, float] = {entry: 1.0}
    stack = [entry]
    while stack:
        name = stack.pop()
        m = mult[name]
        for line in comps.get(name, []):
            callees = _CALL_RE.findall(line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            if mb:
                callees.append(mb.group(1))
            if mc:
                callees.append(mc.group(1))
            for callee in callees:
                factor = body_trips.get(callee, 1) if (mb and callee == mb.group(1)) else 1
                new = m * factor
                if mult.get(callee, 0.0) < new:
                    mult[callee] = new
                    stack.append(callee)
    return mult


def _entry_name(hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.MULTILINE)
    return m.group(1) if m else "main"


def parse_collectives(hlo: str, *, default_trip: int = 1) -> CollectiveStats:
    comps = _split_computations(hlo)
    body_trips = _loop_bounds(comps, default_trip)
    mult = _multipliers(comps, body_trips, default_trip, _entry_name(hlo))
    bytes_by_kind: dict[str, float] = {}
    count = 0
    for name, lines in comps.items():
        m = mult.get(name, body_trips.get(name, 1))
        for line in lines:
            for kind in _COLLECTIVES:
                if f" {kind}(" in line:
                    shape_str = line.split(f" {kind}(")[0].split("=", 1)[-1]
                    b = _shape_bytes(shape_str) * m
                    bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + b
                    count += 1
                    break
    total = float(sum(bytes_by_kind.values()))
    return CollectiveStats(
        bytes_by_kind={k: int(v) for k, v in bytes_by_kind.items()},
        total_bytes=total,
        count=count,
        loop_trip_counts=dict(body_trips),
    )


def hlo_stats(hlo: str, *, default_trip: int = 1) -> HloStats:
    """Loop-aware flops (dots) and HBM bytes (instruction outputs)."""
    comps = _split_computations(hlo)
    body_trips = _loop_bounds(comps, default_trip)
    mult = _multipliers(comps, body_trips, default_trip, _entry_name(hlo))
    flops = 0.0
    bytes_total = 0.0
    dot_count = 0
    for name, lines in comps.items():
        m = mult.get(name, body_trips.get(name, 1))
        shapes: dict[str, str] = {}
        for line in lines:
            d = _DEF_RE.match(line)
            if d:
                shapes[d.group(1)] = d.group(2)
        for line in lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            out_shape, op = d.group(2), d.group(3)
            bytes_total += _shape_bytes(out_shape) * m
            if op != "dot":
                continue
            dot_count += 1
            # operands may carry type prefixes: dot(f32[16,32]{1,0} %a, ...)
            inner = re.search(r"dot\(([^)]*)\)", line)
            ops = re.findall(r"%([\w\.\-]+)", inner.group(1)) if inner else []
            lhs = ops[0] if ops else None
            cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            k = 1.0
            if lhs is not None and lhs in shapes and cdims:
                lm = _SHAPE_RE.search(shapes[lhs])
                if lm:
                    dims = [int(x) for x in lm.group(2).split(",") if x]
                    for ci in cdims.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
            flops += 2.0 * _shape_elems(out_shape) * k * m
    return HloStats(flops=flops, bytes=bytes_total, dot_count=dot_count)


def roofline_terms(cost: dict, coll: CollectiveStats, *, chips: int,
                   model_flops: Optional[float] = None) -> dict:
    """Three-term roofline: compute, HBM, collective — per chip."""
    flops = float(cost.get("flops", 0.0))
    mem_bytes = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = mem_bytes / HBM_BW
    t_collective = coll.total_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    total = max(t_compute + t_memory + t_collective, 1e-30)
    out = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "roofline_fraction": terms[dominant] / total,
    }
    if model_flops:
        out["useful_flops_ratio"] = float(model_flops) / max(flops * chips, 1e-30)
    return out
