"""Sharding policies: logical-axis rules -> PartitionSpecs (DESIGN.md §6).

Models never name mesh axes.  They declare parameters with *logical* axis
names (``models/params.py``) and wrap activations in ``act(dctx, x, *names)``;
a per-(arch, mesh, input-shape) policy maps those names to mesh axes:

* ``w_rules`` — logical weight axis -> mesh axis (None = replicated).  The
  derived ``DistCtx.shard_w(decls)`` tree of PartitionSpecs drives
  ``jax.device_put`` / ``in_shardings``.
* ``a_rules`` — activation axis name -> mesh axis, applied as
  ``with_sharding_constraint`` inside the model so GSPMD keeps the layout
  the policy chose instead of re-deriving one per op.

``lm_policy`` encodes the standard decision tree: tensor-parallel attention
over heads when the head count divides the model axis (else sequence-parallel
attention), FSDP over the data axis above a parameter threshold, expert
sharding per ``models.moe.ep_mode``, and decode-time KV-cache sequence
sharding that absorbs whichever axes the (tiny) decode batch cannot use.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import params as plib

# FSDP pays one weight all-gather per layer; below ~1B parameters the
# weights fit replicated and the gather is pure overhead.
FSDP_PARAM_THRESHOLD = 1_000_000_000


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions: the entry point moved (experimental ->
    top-level) and the replication-check kwarg was renamed (check_rep ->
    check_vma) in separate releases, so resolve each independently.  Shared
    by the MoE expert-parallel path and the sharded search engine."""
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    import inspect

    kwarg = (
        "check_vma" if "check_vma" in inspect.signature(sm).parameters
        else "check_rep"
    )
    return sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{kwarg: False}
    )


@dataclasses.dataclass
class DistCtx:
    """Mesh + resolved rules for one (arch, mesh, shape) cell."""

    mesh: Any
    w_rules: dict[str, Any]
    a_rules: dict[str, Any]
    options: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def batch_axes(self) -> tuple[str, ...]:
        b = self.a_rules.get("batch")
        if b is None:
            return ()
        return tuple(b) if isinstance(b, (tuple, list)) else (b,)

    def opt(self, key: str, default: Any = None) -> Any:
        return self.options.get(key, default)

    def shard_w(self, decls) -> Any:
        """Param declarations -> PartitionSpec tree via w_rules."""
        return jax.tree_util.tree_map(
            lambda p: P(*(self.w_rules.get(n) for n in p.logical)),
            decls,
            is_leaf=plib.is_param,
        )


def act(dctx: Optional[DistCtx], x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain activation ``x`` so dim i lives on ``a_rules[names[i]]``.

    ``None`` entries (either the name or an unmapped rule) replicate that
    dim.  No-op without a ctx so single-device paths stay constraint-free.
    """
    if dctx is None:
        return x
    spec = P(*(dctx.a_rules.get(n) if n is not None else None for n in names))
    return jax.lax.with_sharding_constraint(x, NamedSharding(dctx.mesh, spec))


# ---------------------------------------------------------------------------
# policy helpers
# ---------------------------------------------------------------------------

def _axis(mesh, name: str) -> int:
    return int(mesh.shape.get(name, 1))


def _batch_rule(mesh, batch: int):
    """Shard the batch over (pod, data) — largest prefix that divides it."""
    axes = [a for a in ("pod", "data") if _axis(mesh, a) > 1]
    while axes:
        shards = math.prod(_axis(mesh, a) for a in axes)
        if batch % shards == 0 and batch >= shards:
            return tuple(axes) if len(axes) > 1 else axes[0]
        axes.pop(0)  # drop pod first, then give up
    return None


# ---------------------------------------------------------------------------
# LM policy
# ---------------------------------------------------------------------------

def lm_policy(
    cfg,
    mesh,
    *,
    kind: str = "train",
    batch: int = 1,
    fsdp: Optional[bool] = None,
    moe_impl: str = "gathered",
) -> DistCtx:
    msz = _axis(mesh, "model")
    tp_heads = msz > 1 and cfg.num_heads % msz == 0
    if fsdp is None:
        from repro.models.transformer import lm_decls

        fsdp = plib.param_count(lm_decls(cfg)) >= FSDP_PARAM_THRESHOLD
    fsdp_axis = "data" if (fsdp and _axis(mesh, "data") > 1) else None

    w_rules: dict[str, Any] = {
        "layers": None,
        # embedding table: vocab rows over model, d_model over the FSDP axis
        "vocab_in": "model" if (msz > 1 and cfg.vocab_size % msz == 0) else None,
        "embed_tbl": fsdp_axis,
        "vocab": "model" if (msz > 1 and cfg.vocab_size % msz == 0) else None,
        "embed": fsdp_axis,
        "embed2": None,
        # attention: TP over heads when divisible, else replicated weights
        "q_heads": "model" if tp_heads else None,
        "kv_heads": "model" if (tp_heads and cfg.num_kv_heads % msz == 0) else None,
        "head_dim": None,
        "q_lora": None,
        "kv_lora": None,
        # dense MLP: megatron column/row split over model
        "mlp": "model" if (msz > 1 and cfg.d_ff % msz == 0) else None,
        "experts_r": None,
    }
    if cfg.moe:
        from repro.models.moe import ep_mode

        if moe_impl == "zero3":
            w_rules.update(experts="model", embed_x="data", expert_mlp=None)
        else:
            mode = ep_mode(cfg, mesh)
            if mode == "2d":
                w_rules.update(experts=("model", "data"), embed_x=None, expert_mlp=None)
            elif mode == "fslice":
                w_rules.update(experts="model", embed_x=None, expert_mlp="data")
            else:
                w_rules.update(experts="model", embed_x=None, expert_mlp=None)

    batch_rule = _batch_rule(mesh, batch)
    a_rules: dict[str, Any] = {
        "batch": batch_rule,
        "seq": None,
        # no TP over heads -> shard the attention inputs over sequence instead
        "attn_seq": None if tp_heads else ("model" if msz > 1 else None),
        "embed_act": None,
        "vocab": w_rules["vocab"],
        "layers": None,
        "kv_heads": w_rules["kv_heads"],
        "head_dim": None,
        "kv_lora": None,
        "rope": None,
        "kv_seq": None,
    }
    if kind == "decode":
        # decode batches are small: the KV-cache sequence axis absorbs the
        # model axis, plus the data axis when the batch can't use it.
        a_rules["kv_seq"] = "model" if batch_rule is not None else ("data", "model")
    elif kind == "prefill":
        a_rules["kv_seq"] = "model" if tp_heads else None
    return DistCtx(
        mesh=mesh, w_rules=w_rules, a_rules=a_rules,
        options={"moe_impl": moe_impl, "kind": kind, "fsdp": bool(fsdp)},
    )


# ---------------------------------------------------------------------------
# GNN / RecSys policies
# ---------------------------------------------------------------------------

def gnn_policy(cfg, mesh) -> DistCtx:
    """Full-graph GCN: tiny weights stay replicated; the edge list (the only
    O(E) tensor) shards over every mesh axis."""
    edge_axes = tuple(a for a in ("pod", "data", "model") if _axis(mesh, a) > 1)
    w_rules = {"feat": None, "hidden": None}
    a_rules = {
        "batch": None,
        "edges": edge_axes if len(edge_axes) != 1 else edge_axes[0],
    }
    return DistCtx(mesh=mesh, w_rules=w_rules, a_rules=a_rules)


def search_policy(mesh) -> DistCtx:
    """Sharded vector search (`core/index.ShardedIndex`): the corpus — and
    every per-shard index array stacked on its leading shard axis — lives on
    "data"; query batches are replicated (every shard answers every query)
    and results meet in the host-side running merge."""
    return DistCtx(
        mesh=mesh,
        w_rules={"corpus": "data"},
        a_rules={"batch": None, "corpus": "data"},
    )


def recsys_policy(cfg, mesh, *, batch: int = 1) -> DistCtx:
    """CTR models: the ~38M-row embedding table is row-sharded over every
    axis (dist.embedlookup gathers hit rows); dense tower replicated."""
    all_axes = tuple(a for a in ("pod", "data", "model") if _axis(mesh, a) > 1)
    table_rule = all_axes if len(all_axes) != 1 else (all_axes[0] if all_axes else None)
    w_rules = {
        "table": table_rule,
        "edim": None,
        "hidden": None,  # appears on both dims of MLP weights — keep replicated
        "cin": None,
        "fields": None,
        "heads": None,
        "attn": None,
    }
    a_rules = {
        "batch": _batch_rule(mesh, batch),
        "fields": None,
        "edim": None,
        "cand": table_rule,  # retrieval candidates: sharded like the table
    }
    return DistCtx(mesh=mesh, w_rules=w_rules, a_rules=a_rules)
