"""Columnar attribute store: per-row metadata for filtered search (DESIGN.md §12).

Production retrieval is dominated by constrained queries — "nearest
neighbors *among* rows matching a predicate".  The store holds the
predicate side of that question: named columns aligned with corpus rows,
two kinds only:

* **numeric**  — one float32 value per row.  Missing values are NaN, and
  NaN compares false under every clause, so unattributed rows never pass a
  numeric filter.
* **categorical** — one int32 vocabulary code per row plus the vocabulary
  itself (a host-side tuple of labels, insertion-ordered so snapshots are
  deterministic).  Missing values are code -1, which no vocabulary entry
  maps to, so unattributed rows never pass a categorical filter either.

Columns live as host numpy arrays (the live subsystem mutates them in
place on upsert) with a lazily-built device mirror, exactly the
``_Generation.device_view`` pattern of ``core/live`` — the hot query path
re-uploads nothing until a mutation invalidates the cache.  ``place()``
lets ``ShardedIndex`` pin the mirror onto its mesh's data axis so compiled
masks are row-sharded alongside the corpus.

The store is deliberately dumb: it knows nothing about predicates.
``core/filter.py`` compiles predicate ASTs against ``device_columns()``
and caches the resulting masks here (``mask_cache``, cleared on every
mutation) so a serving loop re-evaluating the same filter pays one
compile, zero re-evaluations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence

import jax.numpy as jnp
import numpy as np

#: numpy kinds stored as numeric float32 columns; everything else (strings,
#: objects, bools) becomes a categorical vocabulary.
_NUMERIC_KINDS = ("i", "u", "f")


@dataclasses.dataclass
class AttributeStore:
    """Named per-row columns: ``numeric[name] -> (cap,) f32`` host array,
    ``categorical[name] -> ((cap,) i32 codes, vocab list)``.

    ``n`` is the logical row count (== every column's length for frozen
    engines; the live subsystem over-allocates to slot capacity and tracks
    fill itself — the store's arrays always span the full capacity)."""

    numeric: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    categorical: dict[str, tuple[np.ndarray, list]] = dataclasses.field(
        default_factory=dict
    )
    # device mirror + compiled-mask / selectivity caches, rebuilt lazily
    # after a mutation
    _dev: Optional[dict] = dataclasses.field(default=None, repr=False)
    _sharding: Any = dataclasses.field(default=None, repr=False)
    mask_cache: dict = dataclasses.field(default_factory=dict, repr=False)
    sel_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, values: Mapping[str, Sequence], n: int) -> "AttributeStore":
        """One store from a plain cfg mapping ``{column: per-row values}``.

        Every value sequence must have exactly ``n`` entries (corpus-row
        aligned); int/float sequences become numeric columns, anything else
        a categorical vocabulary in first-appearance order."""
        store = cls()
        for name, vals in dict(values or {}).items():
            _check_name(name)
            arr = np.asarray(vals)
            if arr.ndim != 1 or arr.shape[0] != n:
                raise ValueError(
                    f"attrs[{name!r}]: need {n} per-row values, got shape {arr.shape}"
                )
            if arr.dtype.kind in _NUMERIC_KINDS:
                store.numeric[name] = arr.astype(np.float32)
            else:
                vocab: list = []
                seen: dict = {}
                codes = np.empty((n,), np.int32)
                for i, v in enumerate(arr.tolist()):
                    if v is None:  # the missing sentinel, never a label —
                        codes[i] = -1  # to_values round-trips missing-ness
                        continue
                    code = seen.get(v)
                    if code is None:
                        code = seen[v] = len(vocab)
                        vocab.append(v)
                    codes[i] = code
                store.categorical[name] = (codes, vocab)
        return store

    # -------------------------------------------------------------- accessors
    @property
    def n(self) -> int:
        for col in self.numeric.values():
            return int(col.shape[0])
        for codes, _ in self.categorical.values():
            return int(codes.shape[0])
        return 0

    def columns(self) -> tuple[str, ...]:
        return tuple(sorted((*self.numeric, *self.categorical)))

    def kind(self, name: str) -> str:
        if name in self.numeric:
            return "numeric"
        if name in self.categorical:
            return "categorical"
        raise KeyError(
            f"unknown attribute column {name!r}; have {list(self.columns())}"
        )

    def encode(self, name: str, value) -> int:
        """Categorical label -> vocabulary code (-1 = never matches)."""
        _, vocab = self.categorical[name]
        try:
            return vocab.index(value)
        except ValueError:
            return -1

    def invalidate(self) -> None:
        self._dev = None
        self.mask_cache.clear()
        self.sel_cache.clear()

    def place(self, sharding) -> None:
        """Pin the device mirror onto ``sharding`` (ShardedIndex places
        columns on its mesh's data axis so compiled masks shard with the
        corpus rows)."""
        self._sharding = sharding
        self.invalidate()

    def device_columns(self) -> dict[str, jnp.ndarray]:
        """{name: (cap,) device array} — f32 for numeric, i32 codes for
        categorical — uploaded once per mutation, not per query."""
        if self._dev is None:
            import jax

            def up(x):
                x = jnp.asarray(x)
                if self._sharding is not None:
                    x = jax.device_put(x, self._sharding)
                return x

            dev = {name: up(col) for name, col in self.numeric.items()}
            dev.update(
                {name: up(codes) for name, (codes, _) in self.categorical.items()}
            )
            self._dev = dev
        return self._dev

    # -------------------------------------------------------------- mutation
    def validate_rows(self, values: Optional[Mapping[str, Sequence]],
                      count: int) -> None:
        """Raise on unknown column names or wrong per-row value counts —
        callable BEFORE any destructive step (live ``upsert`` tombstones
        replaced ids first, so validation must not wait for the write)."""
        for name, vals in dict(values or {}).items():
            if name not in self.numeric and name not in self.categorical:
                raise KeyError(
                    f"upsert attrs: unknown column {name!r}; have "
                    f"{list(self.columns())}"
                )
            if len(np.atleast_1d(np.asarray(vals))) != count:
                raise ValueError(
                    f"upsert attrs[{name!r}]: need {count} values"
                )

    def set_rows(self, start: int, values: Optional[Mapping[str, Sequence]],
                 count: int) -> None:
        """Write ``count`` rows at ``start`` (live upsert hook).  Columns
        absent from ``values`` — and ``None`` entries within a column —
        get the missing sentinel (NaN / -1) so unattributed rows never
        match a filter; unknown column names raise (a typo'd attribute
        silently never matching would be a debugging trap).  New
        categorical labels extend the vocabulary in place."""
        values = dict(values or {})
        self.validate_rows(values, count)
        for name, col in self.numeric.items():
            if name in values:
                col[start : start + count] = np.asarray(
                    values[name], np.float32
                )
            else:
                col[start : start + count] = np.nan
        for name, (codes, vocab) in self.categorical.items():
            if name in values:
                seen = {v: i for i, v in enumerate(vocab)}
                for j, v in enumerate(np.asarray(values[name]).tolist()):
                    if v is None:
                        codes[start + j] = -1
                        continue
                    code = seen.get(v)
                    if code is None:
                        code = seen[v] = len(vocab)
                        vocab.append(v)
                    codes[start + j] = code
            else:
                codes[start : start + count] = -1
        self.invalidate()

    def take(self, idx: np.ndarray, *, capacity: Optional[int] = None
             ) -> "AttributeStore":
        """Row-gathered copy (compaction: ``take(alive_slots)``), optionally
        padded with missing sentinels up to ``capacity`` rows."""
        idx = np.asarray(idx, np.int64)
        pad = 0 if capacity is None else int(capacity) - idx.shape[0]
        if pad < 0:
            raise ValueError(f"take: capacity {capacity} < {idx.shape[0]} rows")
        out = AttributeStore()
        for name, col in self.numeric.items():
            out.numeric[name] = np.concatenate(
                [col[idx], np.full((pad,), np.nan, np.float32)]
            )
        for name, (codes, vocab) in self.categorical.items():
            out.categorical[name] = (
                np.concatenate([codes[idx], np.full((pad,), -1, np.int32)]),
                list(vocab),
            )
        return out

    def to_values(self, idx=None) -> dict:
        """The inverse of ``build``: {column: host per-row values},
        optionally row-gathered by ``idx`` — categorical codes decode
        through the vocabulary (missing -> None, which ``build`` /
        ``set_rows`` re-encode as the missing sentinel, so missing-ness
        round-trips), numeric stays f32 (missing NaN survives and still
        fails every clause).  ``SearchServer.restore`` uses this to carry
        columns across ``swap()`` rebuilds."""
        out: dict = {}
        for name, col in self.numeric.items():
            out[name] = col if idx is None else col[np.asarray(idx, np.int64)]
        for name, (codes, vocab) in self.categorical.items():
            c = codes if idx is None else codes[np.asarray(idx, np.int64)]
            out[name] = [vocab[int(j)] if j >= 0 else None for j in c]
        return out

    def memory_bytes(self) -> int:
        total = sum(c.nbytes for c in self.numeric.values())
        total += sum(codes.nbytes for codes, _ in self.categorical.values())
        return int(total)

    # -------------------------------------------------------------- snapshot
    def snapshot_state(self) -> tuple[dict, dict]:
        """(arrays, statics) under the ``core/store`` hook contract — the
        store rides inside every engine snapshot as the format-v2 payload."""
        arrays = {f"num_{k}": v for k, v in self.numeric.items()}
        arrays.update(
            {f"cat_{k}": codes for k, (codes, _) in self.categorical.items()}
        )
        statics = {
            "numeric": sorted(self.numeric),
            "categorical": {
                k: list(vocab) for k, (_, vocab) in self.categorical.items()
            },
        }
        return arrays, statics

    @classmethod
    def from_snapshot(cls, arrays: dict, statics: dict) -> "AttributeStore":
        store = cls()
        for name in statics["numeric"]:
            store.numeric[name] = np.asarray(arrays[f"num_{name}"], np.float32)
        for name, vocab in statics["categorical"].items():
            store.categorical[name] = (
                np.asarray(arrays[f"cat_{name}"], np.int32), list(vocab)
            )
        return store


def _check_name(name: str) -> None:
    if not isinstance(name, str) or not name:
        raise ValueError(f"attribute column names must be non-empty str: {name!r}")
    if "/" in name:
        # snapshot arrays flatten to /-joined npz keys (core/store.py)
        raise ValueError(f"attribute column names may not contain '/': {name!r}")
