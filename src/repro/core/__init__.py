"""Core of the paper's contribution: q-metric projections, VP trees,
learned embedding operator and the InfinitySearch index."""

from repro.core import metrics  # noqa: F401
from repro.core import qmetric  # noqa: F401
from repro.core import vptree  # noqa: F401
from repro.core import knn_graph  # noqa: F401
from repro.core import index  # noqa: F401  (registry; engines load lazily)
