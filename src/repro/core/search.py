"""InfinitySearch — the paper's end-to-end pipeline (Fig. 18).

Offline (build):
  1. sample a projection subset S of the dataset (the paper trains P*_q on a
     fixed 100K subset and applies Phi inductively; we scale this down),
  2. compute the kNN graph of S and the sparse canonical projection D_q
     (Algorithms 6/7),
  3. fit the embedding operator Phi on (S, D_q)  (Eq. 73),
  4. embed the FULL dataset with Phi and build a VP tree over the embedding
     with the Euclidean metric (whose values now approximate q-distances).

Online (search):
  embed the query batch, search the VP tree — single-path descent for q=inf
  (Theorem 1) or budgeted best-first for finite q (Algorithm 2) — and
  optionally rerank the top-K candidates with the ORIGINAL dissimilarity
  (two-stage search, Appendix F.5).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding as embed_lib
from repro.core import index as index_lib
from repro.core import knn_graph as knn_lib
from repro.core import metrics as metrics_lib
from repro.core import qmetric
from repro.core import quant as quant_lib
from repro.core import scan as scan_lib
from repro.core import vptree as vptree_lib
from repro.core.index import SearchResult


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    q: float = math.inf
    metric: str = "euclidean"  # original dissimilarity
    # sparse projection
    knn_k: int = 16
    num_hops: int = 6  # doubling schedule: paths up to 2^num_hops edges
    extra_links: int = 2  # random long-range edges per node (connectivity)
    proj_sample: int = 2048
    # embedding operator
    embed_dim: int = 32
    hidden: tuple[int, ...] = (256, 256)
    train_steps: int = 2000
    batch_pairs: int = 1024
    lr: float = 1e-3
    alpha_t: float = 0.0
    dropout: float = 0.0
    local_frac: float = 0.5
    stress_weight: str = "sammon"
    # misc
    seed: int = 0
    impl: str = "jnp"  # 'pallas' routes pairwise/semiring through kernels/


@index_lib.register_index("infinity")
@dataclasses.dataclass
class InfinityIndex:
    """The paper's pipeline: sparse q-metric projection, learned embedding
    Phi, VP-tree search in embedding space, two-stage original-metric
    rerank."""

    config: IndexConfig
    X: jax.Array  # (n, d) original vectors
    Z: jax.Array  # (n, s) embedded vectors
    phi_params: dict
    tree: vptree_lib.VPTree
    train_history: dict
    search_defaults: dict = dataclasses.field(default_factory=dict)

    #: the best-first budget is a traced while-loop gate, so ShardedIndex
    #: can hand this engine its exact per-shard share (incl. remainder)
    shard_traced_budget = True
    #: ShardedIndex passes the filter's (bucketed) global selectivity so the
    #: per-shard rerank width scales identically to the single-device path
    shard_uses_selectivity = True

    # ------------------------------------------------------------------ build
    @classmethod
    def registry_build(cls, X, cfg=None) -> "InfinityIndex":
        """Registry entry: cfg is an ``IndexConfig`` or a mapping whose keys
        split into IndexConfig fields and search defaults (mode / budget /
        max_comparisons / rerank)."""
        if isinstance(cfg, IndexConfig):
            return cls.build(X, cfg)
        cfg = dict(cfg or {})
        search_keys = ("mode", "budget", "max_comparisons", "rerank")
        sdef = {k: cfg.pop(k) for k in search_keys if k in cfg}
        fields = {f.name for f in dataclasses.fields(IndexConfig)}
        unknown = set(cfg) - fields
        if unknown:
            raise TypeError(f"infinity: unknown cfg keys {sorted(unknown)}")
        idx = cls.build(X, IndexConfig(**cfg))
        idx.search_defaults = sdef
        return idx

    @classmethod
    def build(cls, X: jax.Array, config: IndexConfig = IndexConfig()) -> "InfinityIndex":
        X = jnp.asarray(X, jnp.float32)
        n = X.shape[0]
        rng = np.random.default_rng(config.seed)

        # 1) projection subset
        if n > config.proj_sample:
            sub = np.sort(rng.choice(n, size=config.proj_sample, replace=False))
            S = X[jnp.asarray(sub)]
        else:
            S = X

        # 2) sparse canonical projection on the subset.  kNN graphs of
        # clustered data can be disconnected — a handful of random long-range
        # edges per node restores connectivity (NSW-style) so the projection
        # assigns finite q-distances to (nearly) all pairs.
        ns = S.shape[0]
        idx, _ = knn_lib.knn_graph(
            S, k=min(config.knn_k, ns - 1), metric=config.metric,
            impl=config.impl,
        )
        mask = knn_lib.knn_mask(idx, ns)
        if config.extra_links > 0:
            links = jnp.asarray(
                rng.integers(0, ns, size=(ns, config.extra_links)), jnp.int32
            )
            mask = mask | knn_lib.knn_mask(links, ns)
        D = metrics_lib.pairwise(S, S, metric=config.metric, impl=config.impl)
        D = jnp.where(jnp.eye(ns, dtype=bool), 0.0, D)
        Dq = qmetric.sparse_canonical_projection(
            D, mask, config.q, num_hops=config.num_hops, impl=config.impl,
            schedule="doubling",
        )

        # 3) fit Phi
        ecfg = embed_lib.EmbedConfig(
            in_dim=X.shape[1],
            out_dim=config.embed_dim,
            hidden=config.hidden,
            dropout=config.dropout,
            q=config.q,
            lr=config.lr,
            steps=config.train_steps,
            batch_pairs=config.batch_pairs,
            alpha_t=config.alpha_t,
            seed=config.seed,
            local_frac=config.local_frac,
            weight=config.stress_weight,
        )
        phi_params, history = embed_lib.train_embedding(
            S, Dq, ecfg, knn_idx=idx, log_every=100
        )

        # 4) embed the full dataset, build the VP tree in embedding space
        Z = embed_lib.apply(phi_params, X)
        tree = vptree_lib.build_vptree(np.asarray(Z), metric="euclidean", seed=config.seed)
        return cls(
            config=config, X=X, Z=Z, phi_params=phi_params, tree=tree,
            train_history=history,
        )

    # ----------------------------------------------------------------- search
    def search(
        self,
        Q: jax.Array,
        k: int = 1,
        *,
        mode: Optional[str] = None,
        max_comparisons: Optional[int] = None,
        rerank: Optional[int] = None,
        budget: Optional[int] = None,
        filter=None,
    ) -> SearchResult:
        """Returns ``SearchResult``: indices (B, k), distances (B, k) in the
        ORIGINAL metric (ascending), comparisons (B,).

        mode: 'descend' (Theorem-1 single path, k=1 effective),
              'best_first' (Algorithm 2 with the index's q),
              'auto' = descend for q=inf & k==1 & no rerank, else best_first.
        budget: uniform-contract alias for ``max_comparisons`` (the explicit
        kwarg wins when both are given).
        rerank: two-stage width K (0 = off). Comparisons count tree visits
        plus reranked candidates (each rerank candidate costs one original-
        metric comparison, matching the paper's accounting in F.5).
        filter: predicate spec / (n,) bool mask.  The tree accepts only
        passing candidates (every visit still counts against the budget),
        descent mode is disabled (a single path may hold no passing point),
        and the two-stage width is scaled by 1/selectivity so recall holds
        on narrow filters (DESIGN.md §12).
        Unset kwargs fall back to the instance's ``search_defaults`` (set by
        the registry from leftover cfg keys).
        """
        from repro.core import filter as filter_lib

        sd = self.search_defaults
        mode = index_lib.resolve(mode, sd, "mode", "auto")
        if max_comparisons is None:
            budget = index_lib.resolve(budget, sd, "budget")
            max_comparisons = budget if budget is not None else (sd or {}).get("max_comparisons")
        rerank = int(index_lib.resolve(rerank, sd, "rerank", 0))
        filter = index_lib.resolve(filter, sd, "filter")
        mask = filter_lib.resolve_mask(
            filter, getattr(self, "attrs", None), self.X.shape[0]
        )
        Q = jnp.asarray(Q, jnp.float32)
        Zq = embed_lib.apply(self.phi_params, Q)
        K = max(k, rerank)
        if mask is not None and rerank:
            # two-stage under a filter: widen the candidate stage by
            # 1/selectivity (power-of-two bucketed) so the rerank still sees
            # ~rerank passing candidates' worth of tree frontier.  The
            # fraction caches next to the compiled mask, so the hot serving
            # path pays the device sync once per distinct predicate
            sel = filter_lib.bucket_selectivity(filter_lib.cached_selectivity(
                filter, getattr(self, "attrs", None), mask))
            K = filter_lib.scaled_width(K, sel, self.X.shape[0])
        if mask is None and self._use_descend(mode, self.config.q, K):
            bi, bd, comps = vptree_lib.descend_infty(
                self.tree, Zq, X=self.Z, metric="euclidean"
            )
            idx = bi[:, None]
        else:
            idx, _, comps = vptree_lib.search_best_first(
                self.tree, Zq, q=self.config.q, k=K, X=self.Z, metric="euclidean",
                max_comparisons=max_comparisons, valid=mask,
            )
        if rerank and K > k:
            idx, dists = self._rerank(Q, idx, k)
            comps = comps + K
        else:
            # same scan-engine path as the rerank branch: the k survivors are
            # scored in the ORIGINAL metric and returned ascending.  comps
            # keeps counting tree visits only (embedding-space evaluations);
            # the k final scores are reporting, not search work — the
            # paper's accounting, see the SearchResult caveat in core/index.
            idx, dists = self._rerank(Q, idx[:, :k], k)
        return SearchResult(idx, dists, comps)

    @staticmethod
    def _use_descend(mode: str, q: float, K: int) -> bool:
        """One mode policy for the instance and shard paths: Theorem-1
        descent when asked for, or automatically at q=inf with a single
        survivor (its prune conditions are complementary only there)."""
        return mode == "descend" or (mode == "auto" and math.isinf(q) and K == 1)

    def _rerank(self, Q: jax.Array, idx: jax.Array, k: int):
        """Specific search (F.5): original-metric distances to K candidates,
        keep the best k — per-query candidate scoring + selection routed
        through the ``core/scan`` engine (invalid slots masked in the merge).

        With a ``quant`` store attached the two-stage rerank itself goes
        two-stage: the K tree candidates are first scored on int8 codes and
        only a ``quant.shortlist_width``-wide sub-shortlist touches the f32
        rows — at serving widths (K in the hundreds) the rerank's f32 reads
        drop ~4x with the exact final ordering preserved for the top k."""
        k = int(k)
        qs = getattr(self, "quant", None)
        if qs is not None:
            w = quant_lib.shortlist_width(k, self.X.shape[0])
            if idx.shape[1] > w:
                codes, scales, _ = qs.device_view()
                idx = _quant_prefilter(
                    Q, idx, codes, scales, k=w, metric=self.config.metric
                )
        return _scan_rerank(Q, idx, self.X, k=k, metric=self.config.metric)

    def memory_bytes(self) -> int:
        return index_lib.pytree_nbytes(
            (self.X, self.Z, self.phi_params,
             (self.tree.vantage, self.tree.mu, self.tree.left, self.tree.right))
        ) + index_lib.side_store_bytes(self)

    # -------------------------------------------------------------- sharding
    def shard_state(self):
        sd = self.search_defaults or {}
        arrays = {
            "X": self.X, "Z": self.Z, "phi": self.phi_params,
            "vantage": self.tree.vantage, "mu": self.tree.mu,
            "left": self.tree.left, "right": self.tree.right,
        }
        static = {
            "q": self.config.q, "metric": self.config.metric,
            "depth": self.tree.depth,
            "mode": sd.get("mode", "auto"),
            "rerank": int(sd.get("rerank") or 0),
            "budget": sd.get("budget", sd.get("max_comparisons")),
        }
        return arrays, static

    @classmethod
    def merge_shard_static(cls, statics: list[dict]) -> dict:
        """Per-shard trees differ only in depth — take the max (a too-deep
        fori bound just iterates on node=-1, a no-op)."""
        merged = dict(statics[0])
        merged["depth"] = max(s["depth"] for s in statics)
        for s in statics[1:]:
            rest = {k: v for k, v in s.items() if k != "depth"}
            if rest != {k: v for k, v in merged.items() if k != "depth"}:
                raise ValueError(f"shard statics disagree: {merged} vs {s}")
        return merged

    @classmethod
    def shard_search(cls, state, Q, *, k, budget, static, budget_t=None,
                     valid=None, sel=None):
        # budget_t: traced per-shard comparison budget (base + remainder
        # share from ShardedIndex) — overrides the static floor when given.
        # valid: the shard's row slice of the global filter mask; sel: the
        # GLOBAL bucketed selectivity (a static — per-shard passing
        # fractions are traced, so the width must come from outside).
        if budget_t is not None:
            budget = budget_t
        elif budget is None:
            budget = static.get("budget")
        rerank = int(static.get("rerank") or 0)
        mode = static.get("mode", "auto")
        tree = vptree_lib.VPTree(
            vantage=state["vantage"], mu=state["mu"], left=state["left"],
            right=state["right"], depth=int(static["depth"]),
        )
        Zq = embed_lib.apply(state["phi"], Q)
        K = max(k, rerank)
        if valid is not None and rerank:
            from repro.core import filter as filter_lib

            K = filter_lib.scaled_width(
                K, 1.0 if sel is None else sel, state["Z"].shape[0]
            )
        # same mode resolution as search(): a cfg that picks descend on one
        # device picks it per shard too
        if valid is None and cls._use_descend(mode, static["q"], K):
            bi, _, comps = vptree_lib.descend_infty(
                tree, Zq, X=state["Z"], metric="euclidean"
            )
            idx = bi[:, None]
        else:
            idx, _, comps = vptree_lib.search_best_first(
                tree, Zq, q=static["q"], k=K, X=state["Z"], metric="euclidean",
                max_comparisons=budget, valid=valid,
            )
        if rerank and K > k:
            idx, dists = _scan_rerank(Q, idx, state["X"], k=k, metric=static["metric"])
            comps = comps + K
        else:
            idx, dists = _scan_rerank(Q, idx[:, :k], state["X"], k=k, metric=static["metric"])
        return idx, dists, comps

    # --------------------------------------------------------------- refresh
    def refresh(self, X: jax.Array, *, Z: Optional[jax.Array] = None) -> "InfinityIndex":
        """New index over a mutated corpus WITHOUT retraining Phi.

        The paper's inductive argument: Phi was fit on the projection subset
        and applies to unseen points, so a changed corpus only needs (a) the
        new rows embedded (``Z=None`` embeds everything here; the live
        subsystem passes embeddings it computed at upsert time) and (b) the
        VP tree rebuilt over the new embedding — no gradient steps.  The
        drift cost is quality, not correctness: Phi was fit against the OLD
        subset's q-metric, which a ``full`` compaction re-projects away.
        """
        X = jnp.asarray(X, jnp.float32)
        Z = embed_lib.apply(self.phi_params, X) if Z is None else jnp.asarray(Z)
        tree = vptree_lib.build_vptree(
            np.asarray(Z), metric="euclidean", seed=self.config.seed
        )
        new = InfinityIndex(
            config=self.config, X=X, Z=Z, phi_params=self.phi_params, tree=tree,
            train_history=self.train_history,
        )
        new.search_defaults = dict(self.search_defaults)
        return new

    # -------------------------------------------------------------- snapshot
    def snapshot_state(self):
        arrays = {
            "X": self.X, "Z": self.Z, "phi": self.phi_params,
            "vantage": self.tree.vantage, "mu": self.tree.mu,
            "left": self.tree.left, "right": self.tree.right,
        }
        cfg = dataclasses.asdict(self.config)  # tuples -> lists in JSON
        statics = {
            "config": cfg,
            "depth": self.tree.depth,
            "search_defaults": self.search_defaults,
        }
        return arrays, statics

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "InfinityIndex":
        cfg = dict(statics["config"])
        cfg["hidden"] = tuple(cfg["hidden"])
        tree = vptree_lib.VPTree(
            vantage=jnp.asarray(arrays["vantage"], jnp.int32),
            mu=jnp.asarray(arrays["mu"], jnp.float32),
            left=jnp.asarray(arrays["left"], jnp.int32),
            right=jnp.asarray(arrays["right"], jnp.int32),
            depth=int(statics["depth"]),
        )
        phi = jax.tree_util.tree_map(jnp.asarray, arrays["phi"])
        inst = cls(
            config=IndexConfig(**cfg),
            X=jnp.asarray(arrays["X"], jnp.float32),
            Z=jnp.asarray(arrays["Z"], jnp.float32),
            phi_params=phi, tree=tree,
            train_history={},  # training curves are build telemetry, not state
        )
        inst.search_defaults = dict(statics.get("search_defaults") or {})
        return inst


def _scan_rerank(Q: jax.Array, idx: jax.Array, X: jax.Array, *, k: int, metric: str):
    """Batch original-metric scoring of candidate id lists via ``core/scan``."""
    return jax.vmap(
        lambda q, cand: scan_lib.topk_candidates(q, cand, X, k=k, metric=metric)
    )(Q, idx)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _quant_prefilter(Q, idx, codes, scales, *, k: int, metric: str):
    """Shrink candidate lists on int8 codes: (B, K) ids -> the (B, k) best
    by code-space distance (the quantized stage of the two-stage rerank)."""
    out, _ = jax.vmap(
        lambda q, cand: scan_lib.quant_candidates(
            q, cand, codes, scales, k=k, metric=metric
        )
    )(Q, idx)
    return out
