"""InfinitySearch — the paper's end-to-end pipeline (Fig. 18).

Offline (build):
  1. sample a projection subset S of the dataset (the paper trains P*_q on a
     fixed 100K subset and applies Phi inductively; we scale this down),
  2. compute the kNN graph of S and the sparse canonical projection D_q
     (Algorithms 6/7),
  3. fit the embedding operator Phi on (S, D_q)  (Eq. 73),
  4. embed the FULL dataset with Phi and build a VP tree over the embedding
     with the Euclidean metric (whose values now approximate q-distances).

Online (search):
  embed the query batch, search the VP tree — single-path descent for q=inf
  (Theorem 1), budgeted best-first for finite q (Algorithm 2), or the
  level-synchronous BEAM traversal over the flattened/bucketed tree (one
  jitted dispatch per batch, DESIGN.md §15; the default for large batches)
  — and optionally rerank the top-K candidates with the ORIGINAL
  dissimilarity (two-stage search, Appendix F.5).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding as embed_lib
from repro.core import index as index_lib
from repro.core import knn_graph as knn_lib
from repro.core import metrics as metrics_lib
from repro.core import qmetric
from repro.core import quant as quant_lib
from repro.core import scan as scan_lib
from repro.core import telemetry as telem
from repro.core import vptree as vptree_lib
from repro.core.index import SearchResult


def _note_stages(engine: str, qv: float, dt_s: float, stages: dict) -> None:
    """Record the beam's jit-threaded stage counters (DESIGN.md §16).

    The three traversal stages run inside ONE fused dispatch, so their
    wall-clock split cannot be measured on the host — each stage's span
    duration is the dispatch time apportioned by its comparison share,
    flagged ``estimated`` in the trace args.  Counters are exact."""
    if not telem.enabled():
        return
    vals = {name: int(np.asarray(arr).sum()) for name, arr in stages.items()}
    total = sum(vals.values())
    qs = telem.q_label(qv)
    ts = telem.now_us() - dt_s * 1e6
    for name, v in vals.items():
        telem.count("comparisons_total", v, engine=engine, stage=name, q=qs)
        share = dt_s * (v / total) if total else 0.0
        telem.emit_span(name, share, ts_us=ts, engine=engine,
                        args={"comparisons": v, "estimated": True})
        ts += share * 1e6


def _note_comps(engine: str, stage: str, qv: float, comps) -> None:
    """Count a branch's total comparisons (syncs the device scalar — only
    when telemetry is enabled, so the disabled path never blocks)."""
    if not telem.enabled():
        return
    telem.count("comparisons_total", int(np.asarray(comps).sum()),
                engine=engine, stage=stage, q=telem.q_label(qv))


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    q: float = math.inf
    metric: str = "euclidean"  # original dissimilarity
    # sparse projection
    knn_k: int = 16
    num_hops: int = 6  # doubling schedule: paths up to 2^num_hops edges
    extra_links: int = 2  # random long-range edges per node (connectivity)
    proj_sample: int = 2048
    # embedding operator
    embed_dim: int = 32
    hidden: tuple[int, ...] = (256, 256)
    train_steps: int = 2000
    batch_pairs: int = 1024
    lr: float = 1e-3
    alpha_t: float = 0.0
    dropout: float = 0.0
    local_frac: float = 0.5
    stress_weight: str = "sammon"
    # embedding validation (held-out pairs vs the canonical projection):
    # Phi is retrained (fresh seed) up to ``max_retrain`` extra times while
    # its held-out neighbor overlap stays below ``val_target``; the best
    # attempt wins and the metrics land in train_history["validation"]
    val_pairs: int = 1024
    val_target: float = 0.0  # 0 = always accept the first fit (validate only)
    max_retrain: int = 2
    # beam traversal (flattened tree, DESIGN.md §15)
    leaf_size: int = 16
    # misc
    seed: int = 0
    impl: str = "jnp"  # 'pallas' routes pairwise/semiring through kernels/


#: ``mode='auto'`` batch threshold: batches at least this large take the
#: one-dispatch beam traversal; smaller (latency-insensitive) batches keep
#: the budget-exact best-first path, whose traced while-gate the sharded
#: remainder split relies on.
AUTO_BEAM_MIN_BATCH = 64


@index_lib.register_index("infinity")
@dataclasses.dataclass
class InfinityIndex:
    """The paper's pipeline: sparse q-metric projection, learned embedding
    Phi, VP-tree search in embedding space, two-stage original-metric
    rerank."""

    config: IndexConfig
    X: jax.Array  # (n, d) original vectors
    Z: jax.Array  # (n, s) embedded vectors
    phi_params: dict
    tree: vptree_lib.VPTree
    train_history: dict
    search_defaults: dict = dataclasses.field(default_factory=dict)
    #: lazily-built beam state: {"flat": FlatVPTree, "Zf": Z[perm],
    #: "zcodes": (int8 codes of Zf, scales) once a quant store is attached}
    _flat: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    #: the best-first budget is a traced while-loop gate, so ShardedIndex
    #: can hand this engine its exact per-shard share (incl. remainder)
    shard_traced_budget = True
    #: ShardedIndex passes the filter's (bucketed) global selectivity so the
    #: per-shard rerank width scales identically to the single-device path
    shard_uses_selectivity = True

    # ------------------------------------------------------------------ build
    @classmethod
    def registry_build(cls, X, cfg=None) -> "InfinityIndex":
        """Registry entry: cfg is an ``IndexConfig`` or a mapping whose keys
        split into IndexConfig fields and search defaults (mode / budget /
        max_comparisons / rerank)."""
        if isinstance(cfg, IndexConfig):
            return cls.build(X, cfg)
        cfg = dict(cfg or {})
        search_keys = ("mode", "budget", "max_comparisons", "rerank",
                       "beam_width", "bucket_cap")
        sdef = {k: cfg.pop(k) for k in search_keys if k in cfg}
        fields = {f.name for f in dataclasses.fields(IndexConfig)}
        unknown = set(cfg) - fields
        if unknown:
            raise TypeError(f"infinity: unknown cfg keys {sorted(unknown)}")
        idx = cls.build(X, IndexConfig(**cfg))
        idx.search_defaults = sdef
        return idx

    @classmethod
    def build(cls, X: jax.Array, config: IndexConfig = IndexConfig()) -> "InfinityIndex":
        X = jnp.asarray(X, jnp.float32)
        n = X.shape[0]
        rng = np.random.default_rng(config.seed)

        # 1) projection subset
        if n > config.proj_sample:
            sub = np.sort(rng.choice(n, size=config.proj_sample, replace=False))
            S = X[jnp.asarray(sub)]
        else:
            S = X

        # 2) sparse canonical projection on the subset.  kNN graphs of
        # clustered data can be disconnected — a handful of random long-range
        # edges per node restores connectivity (NSW-style) so the projection
        # assigns finite q-distances to (nearly) all pairs.
        ns = S.shape[0]
        idx, _ = knn_lib.knn_graph(
            S, k=min(config.knn_k, ns - 1), metric=config.metric,
            impl=config.impl,
        )
        mask = knn_lib.knn_mask(idx, ns)
        if config.extra_links > 0:
            links = jnp.asarray(
                rng.integers(0, ns, size=(ns, config.extra_links)), jnp.int32
            )
            mask = mask | knn_lib.knn_mask(links, ns)
        D = metrics_lib.pairwise(S, S, metric=config.metric, impl=config.impl)
        D = jnp.where(jnp.eye(ns, dtype=bool), 0.0, D)
        Dq = qmetric.sparse_canonical_projection(
            D, mask, config.q, num_hops=config.num_hops, impl=config.impl,
            schedule="doubling",
        )

        # 3) fit Phi
        ecfg = embed_lib.EmbedConfig(
            in_dim=X.shape[1],
            out_dim=config.embed_dim,
            hidden=config.hidden,
            dropout=config.dropout,
            q=config.q,
            lr=config.lr,
            steps=config.train_steps,
            batch_pairs=config.batch_pairs,
            alpha_t=config.alpha_t,
            seed=config.seed,
            local_frac=config.local_frac,
            weight=config.stress_weight,
        )
        phi_params, history = embed_lib.train_embedding(
            S, Dq, ecfg, knn_idx=idx, log_every=100
        )

        # 3b) validate Phi against the canonical projection on held-out
        # pairs; retrain from a fresh seed while the neighbor overlap misses
        # the configured target, keeping the best attempt (F.3's check that
        # the learned operator actually reproduces the projected geometry)
        val = _phi_validation(phi_params, S, Dq, config)
        attempts = 1
        while (val["nn_overlap10"] < config.val_target
               and attempts <= config.max_retrain):
            ecfg2 = dataclasses.replace(ecfg, seed=config.seed + 1000 * attempts)
            params2, hist2 = embed_lib.train_embedding(
                S, Dq, ecfg2, knn_idx=idx, log_every=100
            )
            val2 = _phi_validation(params2, S, Dq, config)
            if val2["nn_overlap10"] > val["nn_overlap10"]:
                phi_params, history, val = params2, hist2, val2
            attempts += 1
        history["validation"] = dict(val, attempts=attempts)

        # 4) embed the full dataset, build the VP tree in embedding space
        Z = embed_lib.apply(phi_params, X)
        tree = vptree_lib.build_vptree(np.asarray(Z), metric="euclidean", seed=config.seed)
        return cls(
            config=config, X=X, Z=Z, phi_params=phi_params, tree=tree,
            train_history=history,
        )

    # ----------------------------------------------------------------- search
    def search(
        self,
        Q: jax.Array,
        k: int = 1,
        *,
        mode: Optional[str] = None,
        max_comparisons: Optional[int] = None,
        rerank: Optional[int] = None,
        budget: Optional[int] = None,
        beam_width: Optional[int] = None,
        bucket_cap: Optional[int] = None,
        filter=None,
    ) -> SearchResult:
        """Returns ``SearchResult``: indices (B, k), distances (B, k) in the
        ORIGINAL metric (ascending), comparisons (B,).

        mode: 'descend' (Theorem-1 single path, k=1 effective),
              'best_first' (Algorithm 2 with the index's q),
              'beam' (level-synchronous traversal of the flattened tree —
              one jitted dispatch per batch, DESIGN.md §15; ``beam_width``/
              ``bucket_cap`` override the budget-derived plan),
              'auto' = descend for q=inf & k==1 & no rerank, beam for
              batches of at least ``AUTO_BEAM_MIN_BATCH`` queries, else
              best_first (whose traced budget gate stays comparison-exact).
        budget: uniform-contract alias for ``max_comparisons`` (the explicit
        kwarg wins when both are given).  The beam consumes it as a PLAN —
        levels x width frontier evaluations plus bucket rows — rather than
        a traced gate, so its counts are bounded by, not equal to, the
        budget.
        rerank: two-stage width K (0 = off). Comparisons count tree visits
        plus reranked candidates (each rerank candidate costs one original-
        metric comparison, matching the paper's accounting in F.5).
        filter: predicate spec / (n,) bool mask.  The tree accepts only
        passing candidates (every visit still counts against the budget),
        descent mode is disabled (a single path may hold no passing point),
        and the two-stage width is scaled by 1/selectivity so recall holds
        on narrow filters (DESIGN.md §12).
        Unset kwargs fall back to the instance's ``search_defaults`` (set by
        the registry from leftover cfg keys).
        """
        from repro.core import filter as filter_lib

        sd = self.search_defaults
        mode = index_lib.resolve(mode, sd, "mode", "auto")
        if max_comparisons is None:
            budget = index_lib.resolve(budget, sd, "budget")
            max_comparisons = budget if budget is not None else (sd or {}).get("max_comparisons")
        rerank = int(index_lib.resolve(rerank, sd, "rerank", 0))
        beam_width = index_lib.resolve(beam_width, sd, "beam_width")
        bucket_cap = index_lib.resolve(bucket_cap, sd, "bucket_cap")
        filter = index_lib.resolve(filter, sd, "filter")
        mask = filter_lib.resolve_mask(
            filter, getattr(self, "attrs", None), self.X.shape[0]
        )
        Q = jnp.asarray(Q, jnp.float32)
        with telem.span("embed", engine="infinity"):
            Zq = embed_lib.apply(self.phi_params, Q)
            if telem.enabled():
                jax.block_until_ready(Zq)
        K = max(k, rerank)
        if mask is not None and rerank:
            # two-stage under a filter: widen the candidate stage by
            # 1/selectivity (power-of-two bucketed) so the rerank still sees
            # ~rerank passing candidates' worth of tree frontier.  The
            # fraction caches next to the compiled mask, so the hot serving
            # path pays the device sync once per distinct predicate
            sel = filter_lib.bucket_selectivity(filter_lib.cached_selectivity(
                filter, getattr(self, "attrs", None), mask))
            K = filter_lib.scaled_width(K, sel, self.X.shape[0])
        if mask is None and self._use_descend(mode, self.config.q, K):
            with telem.span("traversal", engine="infinity", mode="descend"):
                bi, bd, comps = vptree_lib.descend_infty(
                    self.tree, Zq, X=self.Z, metric="euclidean"
                )
                if telem.enabled():
                    jax.block_until_ready(comps)
            _note_comps("infinity", "traversal", self.config.q, comps)
            idx = bi[:, None]
        elif self._use_beam(mode, Q.shape[0]):
            if rerank:
                # the beam reaches whole buckets, so widening the two-stage
                # shortlist is nearly free — take at least the quant-rule
                # width (8x-k: the flattened frontier is coarser than a
                # per-node descent, see DESIGN.md §15 on the recall budget)
                K = max(K, quant_lib.shortlist_width(k, self.X.shape[0], mult=8))
            flat, Zf, zc = self._flat_view()
            codes, scales = zc if zc is not None else (None, None)
            t0 = time.perf_counter()
            idx, _, comps, stages = vptree_lib.search_beam(
                flat, Zq, q=self.config.q, k=K, X=Zf, metric="euclidean",
                max_comparisons=None if max_comparisons is None
                else int(max_comparisons),
                beam_width=beam_width, bucket_cap=bucket_cap, valid=mask,
                codes=codes, scales=scales, with_stages=True,
            )
            if telem.enabled():
                jax.block_until_ready(comps)
                _note_stages("infinity", self.config.q,
                             time.perf_counter() - t0, stages)
        else:
            with telem.span("traversal", engine="infinity", mode="best_first"):
                idx, _, comps = vptree_lib.search_best_first(
                    self.tree, Zq, q=self.config.q, k=K, X=self.Z,
                    metric="euclidean",
                    max_comparisons=max_comparisons, valid=mask,
                )
                if telem.enabled():
                    jax.block_until_ready(comps)
            _note_comps("infinity", "traversal", self.config.q, comps)
        if rerank and K > k:
            with telem.span("rerank", engine="infinity"):
                idx, dists = self._rerank(Q, idx, k)
                if telem.enabled():
                    jax.block_until_ready(idx)
            # each reranked candidate costs one original-metric comparison
            if telem.enabled():
                telem.count("comparisons_total", int(K) * int(idx.shape[0]),
                            engine="infinity", stage="rerank",
                            q=telem.q_label(self.config.q))
            comps = comps + K
        else:
            # same scan-engine path as the rerank branch: the k survivors are
            # scored in the ORIGINAL metric and returned ascending.  comps
            # keeps counting tree visits only (embedding-space evaluations);
            # the k final scores are reporting, not search work — the
            # paper's accounting, see the SearchResult caveat in core/index.
            idx, dists = self._rerank(Q, idx[:, :k], k)
        return SearchResult(idx, dists, comps)

    @staticmethod
    def _use_descend(mode: str, q: float, K: int) -> bool:
        """One mode policy for the instance and shard paths: Theorem-1
        descent when asked for, or automatically at q=inf with a single
        survivor (its prune conditions are complementary only there)."""
        return mode == "descend" or (mode == "auto" and math.isinf(q) and K == 1)

    @staticmethod
    def _use_beam(mode: str, batch: int) -> bool:
        """Beam policy shared with the shard path: explicit 'beam', or
        'auto' once the batch is large enough that one fused dispatch beats
        per-budget while-loop lockstep (small batches keep best-first's
        comparison-exact traced gate)."""
        return mode == "beam" or (mode == "auto" and batch >= AUTO_BEAM_MIN_BATCH)

    def _flat_view(self):
        """The lazily-built beam state: flattened tree, layout-ordered
        embedding rows, and (with a quant store attached) their int8 codes.
        Built on first beam search so snapshots/build cost are unchanged;
        ``refresh`` returns a new instance, which resets it."""
        if self._flat is None:
            flat = vptree_lib.flatten_vptree(
                self.tree, leaf_size=self.config.leaf_size,
                Z=np.asarray(self.Z), metric="euclidean",
            )
            object.__setattr__(self, "_flat", {
                "flat": flat, "Zf": self.Z[flat.perm], "zcodes": None,
            })
        cache = self._flat
        if getattr(self, "quant", None) is not None and cache["zcodes"] is None:
            # bucket scans read EMBEDDING rows, so they need codes of Zf —
            # the attached store quantizes the ORIGINAL rows for the rerank
            scales = quant_lib.absmax_scales(cache["Zf"], axis=0)
            cache["zcodes"] = (quant_lib.encode(cache["Zf"], scales), scales)
        zc = cache["zcodes"] if getattr(self, "quant", None) is not None else None
        return cache["flat"], cache["Zf"], zc

    def _rerank(self, Q: jax.Array, idx: jax.Array, k: int):
        """Specific search (F.5): original-metric distances to K candidates,
        keep the best k — per-query candidate scoring + selection routed
        through the ``core/scan`` engine (invalid slots masked in the merge).

        With a ``quant`` store attached the two-stage rerank itself goes
        two-stage: the K tree candidates are first scored on int8 codes and
        only a ``quant.shortlist_width``-wide sub-shortlist touches the f32
        rows — at serving widths (K in the hundreds) the rerank's f32 reads
        drop ~4x with the exact final ordering preserved for the top k."""
        k = int(k)
        qs = getattr(self, "quant", None)
        if qs is not None:
            w = quant_lib.shortlist_width(k, self.X.shape[0])
            if idx.shape[1] > w:
                codes, scales, _ = qs.device_view()
                idx = _quant_prefilter(
                    Q, idx, codes, scales, k=w, metric=self.config.metric
                )
        return _scan_rerank(Q, idx, self.X, k=k, metric=self.config.metric)

    def memory_bytes(self) -> int:
        total = index_lib.pytree_nbytes(
            (self.X, self.Z, self.phi_params,
             (self.tree.vantage, self.tree.mu, self.tree.left, self.tree.right))
        ) + index_lib.side_store_bytes(self)
        if self._flat is not None:
            flat = self._flat["flat"]
            total += index_lib.pytree_nbytes(
                (flat.mu, flat.child_in, flat.child_out, flat.rad_in,
                 flat.rad_out, flat.centroids, flat.bucket_rows,
                 flat.perm, self._flat["Zf"], self._flat["zcodes"])
            )
        return total

    # -------------------------------------------------------------- sharding
    def shard_state(self):
        sd = self.search_defaults or {}
        flat, Zf, _ = self._flat_view()
        arrays = {
            "X": self.X, "Z": self.Z, "phi": self.phi_params,
            "vantage": self.tree.vantage, "mu": self.tree.mu,
            "left": self.tree.left, "right": self.tree.right,
            # flattened beam state — pad-safe across shards: the stacker's
            # -1 (int) / +inf (float) fills produce phantom nodes no real
            # child pointer reaches and phantom buckets no node points to
            "fmu": flat.mu, "fcin": flat.child_in, "fcout": flat.child_out,
            "frin": flat.rad_in, "frout": flat.rad_out,
            "fcent": flat.centroids,
            "fbuckets": flat.bucket_rows, "fperm": flat.perm, "Zf": Zf,
        }
        static = {
            "q": self.config.q, "metric": self.config.metric,
            "depth": self.tree.depth,
            "flat_depth": flat.depth, "leaf_size": flat.leaf_size,
            "mode": sd.get("mode", "auto"),
            "rerank": int(sd.get("rerank") or 0),
            "budget": sd.get("budget", sd.get("max_comparisons")),
            "beam_width": sd.get("beam_width"),
            "bucket_cap": sd.get("bucket_cap"),
        }
        return arrays, static

    @classmethod
    def merge_shard_static(cls, statics: list[dict]) -> dict:
        """Per-shard trees differ only in their depths — take the max (a
        too-deep fori bound just iterates on an empty frontier / node=-1,
        a no-op)."""
        depth_keys = ("depth", "flat_depth")
        merged = dict(statics[0])
        for key in depth_keys:
            merged[key] = max(s[key] for s in statics)
        for s in statics[1:]:
            rest = {k: v for k, v in s.items() if k not in depth_keys}
            if rest != {k: v for k, v in merged.items() if k not in depth_keys}:
                raise ValueError(f"shard statics disagree: {merged} vs {s}")
        return merged

    @classmethod
    def shard_search(cls, state, Q, *, k, budget, static, budget_t=None,
                     valid=None, sel=None):
        # budget_t: traced per-shard comparison budget (base + remainder
        # share from ShardedIndex) — overrides the static floor when given.
        # valid: the shard's row slice of the global filter mask; sel: the
        # GLOBAL bucketed selectivity (a static — per-shard passing
        # fractions are traced, so the width must come from outside).
        # the STATIC per-shard base share (pre-override) — the beam plans
        # its knobs from this, since a traced value can't size static shapes
        plan_budget = budget if budget is not None else static.get("budget")
        if budget_t is not None:
            budget = budget_t
        elif budget is None:
            budget = static.get("budget")
        rerank = int(static.get("rerank") or 0)
        mode = static.get("mode", "auto")
        tree = vptree_lib.VPTree(
            vantage=state["vantage"], mu=state["mu"], left=state["left"],
            right=state["right"], depth=int(static["depth"]),
        )
        Zq = embed_lib.apply(state["phi"], Q)
        K = max(k, rerank)
        if valid is not None and rerank:
            from repro.core import filter as filter_lib

            K = filter_lib.scaled_width(
                K, 1.0 if sel is None else sel, state["Z"].shape[0]
            )
        # same mode resolution as search(): a cfg that picks descend on one
        # device picks it per shard too
        if valid is None and cls._use_descend(mode, static["q"], K):
            bi, _, comps = vptree_lib.descend_infty(
                tree, Zq, X=state["Z"], metric="euclidean"
            )
            idx = bi[:, None]
        elif cls._use_beam(mode, Q.shape[0]):
            if rerank:
                K = max(K, quant_lib.shortlist_width(
                    k, state["Z"].shape[0], mult=8))
            flat = vptree_lib.FlatVPTree(
                mu=state["fmu"], child_in=state["fcin"],
                child_out=state["fcout"], rad_in=state["frin"],
                rad_out=state["frout"], centroids=state["fcent"],
                bucket_rows=state["fbuckets"],
                perm=state["fperm"], depth=int(static["flat_depth"]),
                leaf_size=int(static["leaf_size"]),
            )
            # the beam's budget is a static PLAN, not a traced gate: the
            # per-shard base share (budget_t's floor) sizes the knobs, so
            # summed comparisons stay within the global budget
            idx, _, comps = vptree_lib.search_beam(
                flat, Zq, q=static["q"], k=K, X=state["Zf"],
                metric="euclidean",
                max_comparisons=None if plan_budget is None
                else int(plan_budget),
                beam_width=static.get("beam_width"),
                bucket_cap=static.get("bucket_cap"), valid=valid,
            )
        else:
            idx, _, comps = vptree_lib.search_best_first(
                tree, Zq, q=static["q"], k=K, X=state["Z"], metric="euclidean",
                max_comparisons=budget, valid=valid,
            )
        if rerank and K > k:
            idx, dists = _scan_rerank(Q, idx, state["X"], k=k, metric=static["metric"])
            comps = comps + K
        else:
            idx, dists = _scan_rerank(Q, idx[:, :k], state["X"], k=k, metric=static["metric"])
        return idx, dists, comps

    # --------------------------------------------------------------- refresh
    def refresh(self, X: jax.Array, *, Z: Optional[jax.Array] = None) -> "InfinityIndex":
        """New index over a mutated corpus WITHOUT retraining Phi.

        The paper's inductive argument: Phi was fit on the projection subset
        and applies to unseen points, so a changed corpus only needs (a) the
        new rows embedded (``Z=None`` embeds everything here; the live
        subsystem passes embeddings it computed at upsert time) and (b) the
        VP tree rebuilt over the new embedding — no gradient steps.  The
        drift cost is quality, not correctness: Phi was fit against the OLD
        subset's q-metric, which a ``full`` compaction re-projects away.
        """
        X = jnp.asarray(X, jnp.float32)
        Z = embed_lib.apply(self.phi_params, X) if Z is None else jnp.asarray(Z)
        tree = vptree_lib.build_vptree(
            np.asarray(Z), metric="euclidean", seed=self.config.seed
        )
        new = InfinityIndex(
            config=self.config, X=X, Z=Z, phi_params=self.phi_params, tree=tree,
            train_history=self.train_history,
        )
        new.search_defaults = dict(self.search_defaults)
        return new

    # -------------------------------------------------------------- snapshot
    def snapshot_state(self):
        arrays = {
            "X": self.X, "Z": self.Z, "phi": self.phi_params,
            "vantage": self.tree.vantage, "mu": self.tree.mu,
            "left": self.tree.left, "right": self.tree.right,
        }
        cfg = dataclasses.asdict(self.config)  # tuples -> lists in JSON
        statics = {
            "config": cfg,
            "depth": self.tree.depth,
            "search_defaults": self.search_defaults,
        }
        return arrays, statics

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "InfinityIndex":
        cfg = dict(statics["config"])
        cfg["hidden"] = tuple(cfg["hidden"])
        tree = vptree_lib.VPTree(
            vantage=jnp.asarray(arrays["vantage"], jnp.int32),
            mu=jnp.asarray(arrays["mu"], jnp.float32),
            left=jnp.asarray(arrays["left"], jnp.int32),
            right=jnp.asarray(arrays["right"], jnp.int32),
            depth=int(statics["depth"]),
        )
        phi = jax.tree_util.tree_map(jnp.asarray, arrays["phi"])
        inst = cls(
            config=IndexConfig(**cfg),
            X=jnp.asarray(arrays["X"], jnp.float32),
            Z=jnp.asarray(arrays["Z"], jnp.float32),
            phi_params=phi, tree=tree,
            train_history={},  # training curves are build telemetry, not state
        )
        inst.search_defaults = dict(statics.get("search_defaults") or {})
        return inst


def _phi_validation(phi_params, S, Dq, config: IndexConfig) -> dict:
    """Held-out check that Phi reproduces the canonical projection's
    geometry: Pearson correlation between embedding distances and the
    projected q-distances on ``val_pairs`` random finite pairs, plus the
    mean top-10 neighbor overlap (embedding vs projection) over up to 64
    anchor points — the metric the retrain loop optimizes, since search
    quality depends on neighbor ORDER, not absolute stress."""
    ZS = np.asarray(embed_lib.apply(phi_params, S))
    Dq = np.asarray(Dq)
    ns = ZS.shape[0]
    rng = np.random.default_rng(config.seed + 17)
    npairs = max(int(config.val_pairs), 1)
    ii = rng.integers(0, ns, size=npairs)
    jj = rng.integers(0, ns, size=npairs)
    keep = (ii != jj) & np.isfinite(Dq[ii, jj])
    ii, jj = ii[keep], jj[keep]
    corr = 0.0
    if ii.size >= 2:
        e = np.sqrt(np.maximum(((ZS[ii] - ZS[jj]) ** 2).sum(-1), 0.0))
        t = Dq[ii, jj]
        if e.std() > 1e-12 and t.std() > 1e-12:
            corr = float(np.corrcoef(e, t)[0, 1])
    anchors = rng.choice(ns, size=min(64, ns), replace=False)
    kk = min(10, ns - 1)
    overlap = 0.0
    for a in anchors:
        row = Dq[a].copy()
        row[a] = np.inf
        row = np.where(np.isfinite(row), row, np.inf)
        true_nn = np.argpartition(row, kk - 1)[:kk]
        erow = np.sqrt(np.maximum(((ZS - ZS[a]) ** 2).sum(-1), 0.0))
        erow[a] = np.inf
        est_nn = np.argpartition(erow, kk - 1)[:kk]
        overlap += len(set(true_nn.tolist()) & set(est_nn.tolist())) / kk
    overlap /= max(len(anchors), 1)
    return {"pair_corr": corr, "nn_overlap10": float(overlap),
            "val_pairs": int(ii.size)}


def _scan_rerank(Q: jax.Array, idx: jax.Array, X: jax.Array, *, k: int, metric: str):
    """Batch original-metric scoring of candidate id lists via ``core/scan``."""
    return jax.vmap(
        lambda q, cand: scan_lib.topk_candidates(q, cand, X, k=k, metric=metric)
    )(Q, idx)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _quant_prefilter(Q, idx, codes, scales, *, k: int, metric: str):
    """Shrink candidate lists on int8 codes: (B, K) ids -> the (B, k) best
    by code-space distance (the quantized stage of the two-stage rerank)."""
    out, _ = jax.vmap(
        lambda q, cand: scan_lib.quant_candidates(
            q, cand, codes, scales, k=k, metric=metric
        )
    )(Q, idx)
    return out
