"""InfinitySearch — the paper's end-to-end pipeline (Fig. 18).

Offline (build):
  1. sample a projection subset S of the dataset (the paper trains P*_q on a
     fixed 100K subset and applies Phi inductively; we scale this down),
  2. compute the kNN graph of S and the sparse canonical projection D_q
     (Algorithms 6/7),
  3. fit the embedding operator Phi on (S, D_q)  (Eq. 73),
  4. embed the FULL dataset with Phi and build a VP tree over the embedding
     with the Euclidean metric (whose values now approximate q-distances).

Online (search):
  embed the query batch, search the VP tree — single-path descent for q=inf
  (Theorem 1) or budgeted best-first for finite q (Algorithm 2) — and
  optionally rerank the top-K candidates with the ORIGINAL dissimilarity
  (two-stage search, Appendix F.5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding as embed_lib
from repro.core import knn_graph as knn_lib
from repro.core import metrics as metrics_lib
from repro.core import qmetric
from repro.core import scan as scan_lib
from repro.core import vptree as vptree_lib


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    q: float = math.inf
    metric: str = "euclidean"  # original dissimilarity
    # sparse projection
    knn_k: int = 16
    num_hops: int = 6  # doubling schedule: paths up to 2^num_hops edges
    extra_links: int = 2  # random long-range edges per node (connectivity)
    proj_sample: int = 2048
    # embedding operator
    embed_dim: int = 32
    hidden: tuple[int, ...] = (256, 256)
    train_steps: int = 2000
    batch_pairs: int = 1024
    lr: float = 1e-3
    alpha_t: float = 0.0
    dropout: float = 0.0
    local_frac: float = 0.5
    stress_weight: str = "sammon"
    # misc
    seed: int = 0
    impl: str = "jnp"  # 'pallas' routes pairwise/semiring through kernels/


@dataclasses.dataclass
class InfinityIndex:
    config: IndexConfig
    X: jax.Array  # (n, d) original vectors
    Z: jax.Array  # (n, s) embedded vectors
    phi_params: dict
    tree: vptree_lib.VPTree
    train_history: dict

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, X: jax.Array, config: IndexConfig = IndexConfig()) -> "InfinityIndex":
        X = jnp.asarray(X, jnp.float32)
        n = X.shape[0]
        rng = np.random.default_rng(config.seed)

        # 1) projection subset
        if n > config.proj_sample:
            sub = np.sort(rng.choice(n, size=config.proj_sample, replace=False))
            S = X[jnp.asarray(sub)]
        else:
            S = X

        # 2) sparse canonical projection on the subset.  kNN graphs of
        # clustered data can be disconnected — a handful of random long-range
        # edges per node restores connectivity (NSW-style) so the projection
        # assigns finite q-distances to (nearly) all pairs.
        ns = S.shape[0]
        idx, _ = knn_lib.knn_graph(
            S, k=min(config.knn_k, ns - 1), metric=config.metric,
            impl=config.impl,
        )
        mask = knn_lib.knn_mask(idx, ns)
        if config.extra_links > 0:
            links = jnp.asarray(
                rng.integers(0, ns, size=(ns, config.extra_links)), jnp.int32
            )
            mask = mask | knn_lib.knn_mask(links, ns)
        D = metrics_lib.pairwise(S, S, metric=config.metric, impl=config.impl)
        D = jnp.where(jnp.eye(ns, dtype=bool), 0.0, D)
        Dq = qmetric.sparse_canonical_projection(
            D, mask, config.q, num_hops=config.num_hops, impl=config.impl,
            schedule="doubling",
        )

        # 3) fit Phi
        ecfg = embed_lib.EmbedConfig(
            in_dim=X.shape[1],
            out_dim=config.embed_dim,
            hidden=config.hidden,
            dropout=config.dropout,
            q=config.q,
            lr=config.lr,
            steps=config.train_steps,
            batch_pairs=config.batch_pairs,
            alpha_t=config.alpha_t,
            seed=config.seed,
            local_frac=config.local_frac,
            weight=config.stress_weight,
        )
        phi_params, history = embed_lib.train_embedding(
            S, Dq, ecfg, knn_idx=idx, log_every=100
        )

        # 4) embed the full dataset, build the VP tree in embedding space
        Z = embed_lib.apply(phi_params, X)
        tree = vptree_lib.build_vptree(np.asarray(Z), metric="euclidean", seed=config.seed)
        return cls(
            config=config, X=X, Z=Z, phi_params=phi_params, tree=tree,
            train_history=history,
        )

    # ----------------------------------------------------------------- search
    def search(
        self,
        Q: jax.Array,
        k: int = 1,
        *,
        mode: str = "auto",
        max_comparisons: Optional[int] = None,
        rerank: int = 0,
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Returns (indices (B, k), distances (B, k) in the ORIGINAL metric,
        comparisons (B,)).

        mode: 'descend' (Theorem-1 single path, k=1 effective),
              'best_first' (Algorithm 2 with the index's q),
              'auto' = descend for q=inf & k==1 & no rerank, else best_first.
        rerank: two-stage width K (0 = off). Comparisons count tree visits
        plus reranked candidates (each rerank candidate costs one original-
        metric comparison, matching the paper's accounting in F.5).
        """
        Q = jnp.asarray(Q, jnp.float32)
        Zq = embed_lib.apply(self.phi_params, Q)
        K = max(k, rerank)
        use_descend = mode == "descend" or (
            mode == "auto" and math.isinf(self.config.q) and K == 1
        )
        if use_descend:
            bi, bd, comps = vptree_lib.descend_infty(
                self.tree, Zq, X=self.Z, metric="euclidean"
            )
            idx = bi[:, None]
            comps = comps
        else:
            q_eff = self.config.q
            idx, _, comps = vptree_lib.search_best_first(
                self.tree, Zq, q=q_eff, k=K, X=self.Z, metric="euclidean",
                max_comparisons=max_comparisons,
            )
        if rerank and rerank > k:
            idx, dists = self._rerank(Q, idx, k)
            comps = comps + rerank
        else:
            idx = idx[:, :k]
            dists = self._original_dists(Q, idx)
        return idx, dists, comps

    def _original_dists(self, Q: jax.Array, idx: jax.Array) -> jax.Array:
        pair = metrics_lib.pair_fn(self.config.metric)
        cand = self.X[jnp.maximum(idx, 0)]  # (B, k, d)
        d = jax.vmap(lambda q, c: jax.vmap(lambda y: pair(q, y))(c))(Q, cand)
        return jnp.where(idx >= 0, d, jnp.inf)

    def _rerank(self, Q: jax.Array, idx: jax.Array, k: int):
        """Specific search (F.5): original-metric distances to K candidates,
        keep the best k — per-query candidate scoring + selection routed
        through the ``core/scan`` engine (invalid slots masked in the merge)."""
        metric = self.config.metric
        X = self.X
        return jax.vmap(
            lambda q, cand: scan_lib.topk_candidates(q, cand, X, k=k, metric=metric)
        )(Q, idx)
