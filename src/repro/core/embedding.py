"""Embedding operator Phi (paper §4, Appendix F.3).

An MLP ``Phi(.; theta): R^n -> R^s`` trained so Euclidean distances in the
embedding space approximate canonical q-metric distances:

    stress loss (Eq. 14):    l_D(x,y) = [D_q(x,y) - ||Phi x - Phi y||]^2
    triangle penalty (Eq. 72): l_T(x,y,z) =
        [ ||Phi x - Phi y||^q - ||Phi x - Phi z||^q - ||Phi y - Phi z||^q ]_+

minimized as ``alpha_D * sum l_D + alpha_T * sum l_T`` (Eq. 73) with AdamW
over uniformly sampled pairs/triplets (the paper's mMDS protocol).  Pairs
whose projected distance is +inf (disconnected in the sparse projection
graph) are masked out of the loss.

Block = Linear -> GELU -> Dropout, output = Linear (paper Table 2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class EmbedConfig:
    in_dim: int
    out_dim: int = 32
    hidden: tuple[int, ...] = (256, 256)
    dropout: float = 0.05
    # training
    q: float = math.inf
    lr: float = 1e-3
    steps: int = 1500
    batch_pairs: int = 1024
    batch_triplets: int = 256
    alpha_d: float = 1.0
    alpha_t: float = 0.0
    seed: int = 0
    # beyond-paper fit improvements (DESIGN.md §9 / EXPERIMENTS.md §Perf):
    # local_frac draws that fraction of training pairs from the kNN edge set
    # (uniform sampling is dominated by large distances, whose absolute error
    # is irrelevant for NN search); weight='sammon' scales the stress by
    # 1/(d + eps) so small distances are fit in relative terms.
    local_frac: float = 0.5
    weight: str = "sammon"  # 'none' reproduces the paper's Eq. 14 exactly


def init_params(rng: jax.Array, cfg: EmbedConfig) -> dict:
    dims = (cfg.in_dim,) + tuple(cfg.hidden) + (cfg.out_dim,)
    keys = jax.random.split(rng, len(dims) - 1)
    layers = []
    for k, din, dout in zip(keys, dims[:-1], dims[1:]):
        w = jax.random.normal(k, (din, dout), jnp.float32) * (1.0 / math.sqrt(din))
        layers.append({"w": w, "b": jnp.zeros((dout,), jnp.float32)})
    return {"layers": layers}


def apply(
    params: dict,
    x: jax.Array,
    *,
    dropout: float = 0.0,
    rng: Optional[jax.Array] = None,
) -> jax.Array:
    """Phi(x). x: (..., in_dim) -> (..., out_dim).

    If the trainer stored input normalizers they are applied first; embedding
    distances then approximate ``D_q / d_scale``, which preserves neighbor
    ordering exactly (search is scale-invariant).
    """
    if "x_mean" in params:
        x = (x - params["x_mean"]) / params["x_std"]
    h = x
    n_layers = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        h = h @ layer["w"] + layer["b"]
        if i < n_layers - 1:
            h = jax.nn.gelu(h)
            if dropout > 0.0 and rng is not None:
                rng, sub = jax.random.split(rng)
                keep = jax.random.bernoulli(sub, 1.0 - dropout, h.shape)
                h = jnp.where(keep, h / (1.0 - dropout), 0.0)
    return h


def embed_dist(params: dict, x: jax.Array, y: jax.Array) -> jax.Array:
    zx = apply(params, x)
    zy = apply(params, y)
    return jnp.sqrt(jnp.maximum(jnp.sum((zx - zy) ** 2, axis=-1), 1e-12))


def stress_loss(
    params: dict, xi: jax.Array, xj: jax.Array, dij: jax.Array,
    *, dropout: float = 0.0, rng: Optional[jax.Array] = None,
    weight: str = "none",
) -> jax.Array:
    """Mean masked stress (Eq. 14/15); dij = +inf pairs are masked.

    weight='sammon' divides each term by (dij + median(dij)) — relative error
    on small (NN-relevant) distances instead of absolute error everywhere.
    """
    zi = apply(params, xi, dropout=dropout, rng=rng)
    zj = apply(params, xj, dropout=dropout, rng=rng)
    dhat = jnp.sqrt(jnp.maximum(jnp.sum((zi - zj) ** 2, axis=-1), 1e-12))
    mask = jnp.isfinite(dij)
    d = jnp.where(mask, dij, 0.0)
    err = jnp.where(mask, dhat - d, 0.0)
    sq = err**2
    if weight == "sammon":
        scale = jnp.nanmedian(jnp.where(mask, d, jnp.nan))
        sq = sq / (d + jnp.maximum(jnp.nan_to_num(scale), 1e-6))
    return jnp.sum(sq) / jnp.maximum(jnp.sum(mask), 1)


def triangle_loss(
    params: dict, x: jax.Array, y: jax.Array, z: jax.Array, q: float
) -> jax.Array:
    """Mean saturated q-triangle violation (Eq. 72), computed in a
    per-triplet normalized power domain for overflow safety at large q."""
    dxy = embed_dist(params, x, y)
    dxz = embed_dist(params, x, z)
    dyz = embed_dist(params, y, z)
    if math.isinf(q):
        viol = dxy - jnp.maximum(dxz, dyz)
        return jnp.mean(jax.nn.relu(viol))
    s = jax.lax.stop_gradient(
        jnp.maximum(jnp.maximum(dxy, dxz), jnp.maximum(dyz, 1e-12))
    )
    viol = (dxy / s) ** q - (dxz / s) ** q - (dyz / s) ** q
    return jnp.mean(jax.nn.relu(viol))


def train_embedding(
    X: jax.Array,
    Dq: jax.Array,
    cfg: EmbedConfig,
    *,
    knn_idx: Optional[jax.Array] = None,
    log_every: int = 0,
) -> tuple[dict, dict]:
    """Fit theta* = argmin alpha_D * stress + alpha_T * triangle (Eq. 73).

    X: (n, in_dim) training vectors; Dq: (n, n) projected q-distances
    (entries may be +inf for pairs disconnected in the sparse projection).
    ``knn_idx`` (n, k) enables locality-biased pair sampling (cfg.local_frac).
    Returns (params, metrics_history).
    """
    n = X.shape[0]
    rng = jax.random.PRNGKey(cfg.seed)
    rng, init_rng = jax.random.split(rng)
    params = init_params(init_rng, cfg)
    # input standardization + target scale normalization (free for search:
    # neighbor ordering is invariant to a global distance scale).  The
    # normalizers are constants, not trained — they're attached to the
    # returned params and applied by ``apply``.
    x_mean = jnp.mean(X, axis=0)
    x_std = jnp.maximum(jnp.std(X, axis=0), 1e-6)
    finite = jnp.isfinite(Dq) & ~jnp.eye(n, dtype=bool)
    d_scale = jnp.nanmedian(jnp.where(finite, Dq, jnp.nan))
    d_scale = jnp.maximum(jnp.nan_to_num(d_scale, nan=1.0), 1e-9)
    X = (X - x_mean) / x_std  # pre-normalized; 'layers'-only params below
    Dq = Dq / d_scale
    opt = opt_lib.adamw(cfg.lr, weight_decay=1e-5)
    state = opt.init(params)
    use_local = knn_idx is not None and cfg.local_frac > 0.0
    n_local = int(cfg.batch_pairs * cfg.local_frac) if use_local else 0

    def loss_fn(p, ii, jj, kk, drop_rng):
        xi, xj = X[ii], X[jj]
        dij = Dq[ii, jj]
        loss = cfg.alpha_d * stress_loss(
            p, xi, xj, dij, dropout=cfg.dropout, rng=drop_rng, weight=cfg.weight
        )
        if cfg.alpha_t > 0.0:
            loss = loss + cfg.alpha_t * triangle_loss(
                p, X[ii[: cfg.batch_triplets]], X[jj[: cfg.batch_triplets]],
                X[kk[: cfg.batch_triplets]], cfg.q,
            )
        return loss

    @jax.jit
    def step(p, s, key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        ii = jax.random.randint(k1, (cfg.batch_pairs,), 0, n)
        jj = jax.random.randint(k2, (cfg.batch_pairs,), 0, n)
        if n_local:
            # first n_local js are kNN neighbors of their i — local pairs
            col = jax.random.randint(k5, (n_local,), 0, knn_idx.shape[1])
            jj_local = knn_idx[ii[:n_local], col]
            jj = jnp.concatenate([jj_local, jj[n_local:]])
        kk = jax.random.randint(k3, (cfg.batch_pairs,), 0, n)
        loss, grads = jax.value_and_grad(loss_fn)(p, ii, jj, kk, k4)
        p, s = opt.update(grads, s, p)
        return p, s, loss

    history = {"loss": []}
    for t in range(cfg.steps):
        rng, key = jax.random.split(rng)
        params, state, loss = step(params, state, key)
        if log_every and (t % log_every == 0 or t == cfg.steps - 1):
            history["loss"].append((t, float(loss)))
    params = dict(params)
    params["x_mean"] = x_mean
    params["x_std"] = x_std
    params["d_scale"] = d_scale
    return params, history
