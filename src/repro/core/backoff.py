"""Shared backoff / deadline arithmetic (DESIGN.md §14).

One implementation of the retry-and-deadline primitives that two very
different loops need: the training supervisor (``train/fault.py`` — step
deadlines from a trailing median, consecutive-failure trips) and the
serving controller (``launch/serve.py`` — per-request deadlines, capped
exponential retry backoff, deadline→budget degradation).  Keeping the
arithmetic here means a fix to e.g. the trip-counter reset semantics lands
in both state machines at once.

* ``Deadline``        — a per-request countdown: remaining time, expiry,
  and the remaining *fraction* the degradation ladder keys off.
* ``backoff_s``       — capped exponential backoff (attempt -> seconds).
* ``RunCounter``      — consecutive-event counter that trips (and resets)
  at a threshold — the straggler / NaN-run logic of the supervisor.
* ``median_deadline`` — trailing-median × factor straggler threshold.
* ``degraded_budget`` — remaining-deadline fraction -> comparison budget,
  on a power-of-two halving ladder so a shrinking budget stays a bounded
  jit-key dimension (the same pow2 discipline as ``core/scan.pow2ceil``).
* ``CircuitBreaker``  — CLOSED/OPEN/HALF_OPEN state machine over a
  ``RunCounter``: consecutive dispatch failures trip it open, a cooldown
  later one half-open probe decides whether the engine is healthy again
  (DESIGN.md §18 — the overload runtime's fast-fail guard).
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Sequence

import numpy as np


class Deadline:
    """Countdown from ``ms`` milliseconds at construction (monotonic clock).

    ``ms=None`` means "no deadline": ``remaining_ms`` is +inf,
    ``fraction_left`` is 1.0 and ``expired`` is never True — callers can
    thread one object through unconditionally.
    """

    def __init__(self, ms: Optional[float] = None):
        self.ms = None if ms is None else float(ms)
        self._t0 = time.monotonic()

    def elapsed_ms(self) -> float:
        return (time.monotonic() - self._t0) * 1e3

    def remaining_ms(self) -> float:
        if self.ms is None:
            return float("inf")
        return self.ms - self.elapsed_ms()

    def expired(self) -> bool:
        return self.remaining_ms() <= 0.0

    def fraction_left(self) -> float:
        """Remaining budget as a fraction of the original deadline, clamped
        to [0, 1] — what the degradation ladder keys off."""
        if self.ms is None:
            return 1.0
        if self.ms <= 0:
            return 0.0
        return max(0.0, min(1.0, self.remaining_ms() / self.ms))


def backoff_s(
    attempt: int, *, base_s: float = 0.005, cap_s: float = 0.1,
    factor: float = 2.0,
) -> float:
    """Capped exponential backoff: ``base * factor**attempt``, never above
    ``cap_s``.  attempt counts from 0 (first retry sleeps ``base_s``)."""
    try:
        v = base_s * (factor ** max(0, int(attempt)))
    except OverflowError:  # huge attempt counts: the cap is the answer
        return float(cap_s)
    return float(min(cap_s, v))


class RunCounter:
    """Counts consecutive events and trips at a threshold.

    ``observe(True)`` increments the run and returns True exactly when the
    run reaches ``trip`` (the run resets on a trip — the supervisor's
    "after N consecutive flags, restart then start counting afresh").
    ``observe(False)`` resets the run.
    """

    def __init__(self, trip: int):
        self.trip = int(trip)
        self.run = 0

    def observe(self, event: bool) -> bool:
        if not event:
            self.run = 0
            return False
        self.run += 1
        if self.run >= self.trip:
            self.run = 0
            return True
        return False


def median_deadline(
    history: Sequence[float], *, factor: float, min_samples: int = 5,
) -> Optional[float]:
    """Trailing-median straggler threshold: ``factor × median(history)``,
    or None while fewer than ``min_samples`` observations exist (too little
    signal to call anything slow)."""
    if len(history) < min_samples:
        return None
    return float(factor) * float(np.median(np.asarray(history)))


class CircuitBreaker:
    """CLOSED -> OPEN -> HALF_OPEN breaker around a dispatch site
    (DESIGN.md §18).

    Failures feed a ``RunCounter``: ``trip`` *consecutive* failures open
    the breaker (one success resets the run — the supervisor's semantics,
    shared so a fix lands in both machines).  While OPEN, ``allow()`` is
    False and callers fast-fail (shed with an explicit outcome) instead of
    queueing work onto a sick engine.  After ``cooldown_s`` the next
    ``allow()`` admits exactly ONE half-open probe; ``record(True)`` on
    that probe closes the breaker, ``record(False)`` re-opens it with the
    cooldown doubled (capped at ``cooldown_cap_s``) — capped exponential,
    same shape as ``backoff_s``.

    ``clock`` is injectable so tests drive the cooldown without sleeping.
    All transitions run under a lock: ``allow()`` is called from every
    submitting thread, ``record()`` from the dispatch thread.
    """

    CLOSED, HALF_OPEN, OPEN = "CLOSED", "HALF_OPEN", "OPEN"
    #: numeric encoding for the ``breaker_state`` gauge (0 healthy,
    #: 2 tripped — alert thresholds read "higher is worse")
    STATE_CODE = {"CLOSED": 0, "HALF_OPEN": 1, "OPEN": 2}

    def __init__(self, trip: int = 5, cooldown_s: float = 0.5, *,
                 cooldown_cap_s: float = 30.0, factor: float = 2.0,
                 clock=time.monotonic):
        self.counter = RunCounter(trip)
        self.cooldown_s = float(cooldown_s)
        self.cooldown_cap_s = float(cooldown_cap_s)
        self.factor = float(factor)
        self._clock = clock
        self._lock = threading.Lock()
        self.state = self.CLOSED
        self.trips = 0  # lifetime open transitions
        self._opened_at: Optional[float] = None
        self._open_round = 0  # consecutive re-opens (cooldown exponent)
        self._probe_inflight = False

    def _cooldown(self) -> float:
        return min(self.cooldown_cap_s,
                   self.cooldown_s * self.factor ** self._open_round)

    def allow(self) -> bool:
        """May a dispatch proceed right now?  OPEN past its cooldown
        transitions to HALF_OPEN and admits exactly one probe."""
        with self._lock:
            if self.state == self.CLOSED:
                return True
            if self.state == self.OPEN:
                if self._clock() - self._opened_at < self._cooldown():
                    return False
                self.state = self.HALF_OPEN
                self._probe_inflight = True
                return True
            # HALF_OPEN: one probe at a time
            if self._probe_inflight:
                return False
            self._probe_inflight = True
            return True

    def record(self, ok: bool) -> bool:
        """Feed one dispatch outcome; returns True when this call tripped
        the breaker open (callers count ``breaker_trips_total`` off it)."""
        with self._lock:
            if ok:
                if self.state != self.CLOSED:
                    self.state = self.CLOSED
                    self._open_round = 0
                self._probe_inflight = False
                self.counter.observe(False)
                return False
            if self.state == self.HALF_OPEN:
                # the probe failed: straight back to OPEN, cooldown doubled
                self._probe_inflight = False
                self._open_round += 1
                self._open(self._clock())
                return True
            if self.state == self.OPEN:
                return False  # late failures while already open: no-op
            if self.counter.observe(True):
                self._open(self._clock())
                return True
            return False

    def _open(self, now: float) -> None:
        self.state = self.OPEN
        self._opened_at = now
        self.trips += 1
        self.counter.run = 0

    def retry_after_s(self) -> float:
        """Client backoff hint: remaining cooldown when OPEN, else 0."""
        with self._lock:
            if self.state != self.OPEN:
                return 0.0
            return max(0.0, self._cooldown()
                       - (self._clock() - self._opened_at))

    def state_code(self) -> int:
        return self.STATE_CODE[self.state]


def degraded_budget(
    budget: Optional[int], frac: float, *, floor: int = 8,
) -> Optional[int]:
    """Map the remaining-deadline fraction to a comparison budget.

    Full budget while more than half the deadline remains; every further
    halving of the remaining fraction halves the budget, floored at
    ``floor``.  The ladder is powers of two of the base budget, so a
    deadline-pressured engine whose budget is a static jit knob compiles at
    most O(log budget) distinct programs — the same bounded-recompilation
    discipline as ``core/scan.pow2ceil`` (DESIGN.md §14: this is the
    anytime knob — the paper's comparison bound traded against recall
    along the measured curve).
    """
    if budget is None:
        return None
    b, f = int(budget), float(frac)
    while f < 0.5 and b > floor:
        b = max(int(floor), b // 2)
        f *= 2.0
    return max(int(floor), b)
