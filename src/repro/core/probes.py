"""Online recall probes: sampled ground-truth shadowing of live traffic
(DESIGN.md §17).

The serving stack measures latency everywhere but was blind on the axis
the paper actually trades it against: recall.  A ``RecallProbe`` shadows a
configurable fraction (default 1%) of ``SearchServer.query`` traffic
through the exact fused brute-force path (``core/scan.topk_scan``, the
same oracle the benchmarks use) and maintains a sliding-window recall@k
estimate with a Wilson score confidence interval.

Design points:

* **Deterministic sampling.**  Whether query ordinal ``i`` is probed is a
  pure function of ``(seed, i)`` — a blake2b draw, the ``core/chaos``
  idiom — so the same seed over the same traffic stream reproduces the
  same probe set across restarts (tested).  The ordinal counter advances
  per served query whether or not it samples.
* **Observe-only.**  Probing never touches the served answer: the server
  records its latency first, then hands the (already returned-shape)
  result rows to the probe.  Sampled queries are buffered and ground
  truth runs in fixed-size pow2 flushes, so the shadow path compiles
  O(log) programs and amortizes to ~``rate`` of serving compute.
* **Right sub-corpus.**  Ground truth is filter- and tombstone-aware:
  filtered queries are judged against the predicate-passing rows only,
  live answers against the alive logical corpus (served slot ids mapped
  through ``slot_to_logical``), sharded answers against the full held
  corpus — the same id space each engine answers in.
* **SLO floor.**  With ``slo_floor`` set, a *sustained* breach — the
  Wilson upper bound falling below the floor with at least
  ``slo_min_samples`` probed queries in the window — reports ``"breach"``
  so the server can walk its health machine to DEGRADED and count
  ``quality_degraded_total``; recovery reports when the estimate climbs
  back over the floor.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Optional

import numpy as np

#: 95% two-sided normal quantile — the default Wilson interval width.
Z_95 = 1.959963984540054


@dataclasses.dataclass(frozen=True)
class ProbeConfig:
    """Knobs for ``RecallProbe`` (``SearchServer(probe=...)`` sugar:
    a float is ``rate``, a dict is keyword arguments)."""

    rate: float = 0.01          # fraction of served queries shadowed
    k: int = 10                 # recall@k depth (capped by the request's k)
    window: int = 2048          # probed queries in the sliding window
    seed: int = 0               # sampling stream seed
    flush_at: int = 32          # buffered queries per ground-truth flush
                                # (small flushes pay jax dispatch overhead
                                # out of proportion to their compute)
    slo_floor: Optional[float] = None   # sustained-recall floor (None = off)
    slo_min_samples: int = 64   # window occupancy before the floor arms
    z: float = Z_95             # confidence-interval quantile

    def __post_init__(self):
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"probe rate {self.rate} not in [0, 1]")
        if self.slo_floor is not None and not (0.0 < self.slo_floor <= 1.0):
            raise ValueError(f"slo_floor {self.slo_floor} not in (0, 1]")

    @classmethod
    def from_cfg(cls, cfg) -> "ProbeConfig":
        if isinstance(cfg, cls):
            return cfg
        if isinstance(cfg, (int, float)) and not isinstance(cfg, bool):
            return cls(rate=float(cfg))
        if isinstance(cfg, dict):
            return cls(**cfg)
        raise TypeError(f"probe config: float rate, dict or ProbeConfig, "
                        f"got {type(cfg).__name__}")


#: ordinals per blake2b call — one 64-byte digest yields 8 eight-byte
#: draws, so sampling a serving batch costs B/8 hashes, not B (the
#: sampler runs on every recorded batch; measured at ~25us/64 queries)
_BLOCK = 8


def _block_draws(seed: int, block: int) -> np.ndarray:
    key = f"probe:{seed}:{block}".encode()
    d = hashlib.blake2b(key, digest_size=8 * _BLOCK).digest()
    return np.frombuffer(d, dtype=">u8").astype(np.float64) / 2.0 ** 64


def sample_draw(seed: int, ordinal: int) -> float:
    """Uniform [0, 1) from a stable hash of (seed, query ordinal) — the
    deterministic coin flip (the ``core/chaos`` idiom).  Pure: the same
    (seed, ordinal) draws the same number in any process, ever."""
    return float(_block_draws(seed, ordinal // _BLOCK)[ordinal % _BLOCK])


def draws_range(seed: int, start: int, count: int) -> np.ndarray:
    """(count,) float64 draws for ordinals [start, start+count): the
    vectorized form of ``sample_draw`` — one joined digest buffer and a
    single frombuffer, so bulk draws cost ~B/8 hashes plus one numpy op
    (the per-ordinal loop form cost ~10x this)."""
    if count <= 0:
        return np.zeros((0,), np.float64)
    b0 = start // _BLOCK
    b1 = (start + count - 1) // _BLOCK
    buf = b"".join(
        hashlib.blake2b(f"probe:{seed}:{b}".encode(),
                        digest_size=8 * _BLOCK).digest()
        for b in range(b0, b1 + 1)
    )
    draws = np.frombuffer(buf, dtype=">u8").astype(np.float64) / 2.0 ** 64
    off = start - b0 * _BLOCK
    return draws[off:off + count]


def sampled_mask(seed: int, rate: float, start: int, count: int) -> np.ndarray:
    """(count,) bool — which of query ordinals [start, start+count) sample."""
    return draws_range(seed, start, count) < rate


def wilson_interval(successes: float, trials: float,
                    z: float = Z_95) -> tuple[float, float, float]:
    """(estimate, lo, hi): the Wilson score interval for a binomial
    proportion — well-behaved at p near 0/1 and small n, which is exactly
    where a freshly armed probe lives."""
    if trials <= 0:
        return 0.0, 0.0, 1.0
    p = successes / trials
    z2 = z * z
    denom = 1.0 + z2 / trials
    center = (p + z2 / (2.0 * trials)) / denom
    hw = (z / denom) * math.sqrt(
        p * (1.0 - p) / trials + z2 / (4.0 * trials * trials)
    )
    return p, max(0.0, center - hw), min(1.0, center + hw)


def count_hits(served_idx: np.ndarray, true_idx: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
    """Per-query (hits, trials) for recall@k (Eq. 71 numerators).

    ``trials`` is the number of *valid* ground-truth ids in the row (< k
    when the filtered/alive sub-corpus is smaller than k), so a fully
    correct answer over a tiny sub-corpus scores 1.0, not |sub|/k."""
    m = len(served_idx)
    hits = np.zeros((m,), np.int64)
    trials = np.zeros((m,), np.int64)
    for i in range(m):
        t = {int(x) for x in true_idx[i] if int(x) >= 0}
        if not t:
            continue
        a = {int(x) for x in served_idx[i] if int(x) >= 0}
        trials[i] = len(t)
        hits[i] = len(a & t)
    return hits, trials


def view_key(filter) -> Optional[str]:
    """Stable identity of a probe's ground-truth view: queries buffered
    under different filters (or a mutated live corpus — the caller mixes
    in its generation) must not share one flush's ``valid`` mask."""
    if filter is None:
        return None
    if isinstance(filter, dict):
        return json.dumps(filter, sort_keys=True, default=str)
    arr = np.asarray(filter)
    return "mask:" + hashlib.blake2b(
        arr.tobytes() + str(arr.shape).encode(), digest_size=8
    ).hexdigest()


class RecallProbe:
    """Sampler + sliding-window recall estimator (see module docstring).

    The probe holds no engine state: the server samples with
    ``sample()``, computes ground-truth hit counts for the sampled
    queries, and feeds them back through ``observe()``; ``estimate()`` /
    ``stats()`` read the window."""

    def __init__(self, cfg=None, **kw):
        if cfg is None:
            cfg = ProbeConfig(**kw)
        else:
            cfg = ProbeConfig.from_cfg(cfg)
        self.cfg = cfg
        self.reset()

    #: ordinals of hash draws prefetched per refill — sampling runs on
    #: every recorded serving batch, so the steady-state cost must be a
    #: numpy slice compare (~5us), not a hashing pass (~30us/batch)
    _PREFETCH = 4096

    # ------------------------------------------------------------ sampling
    def _prefetch(self, start: int, count: int) -> None:
        """Refill the draw cache to cover ordinals [start, start+count):
        one hashing pass per ~``_PREFETCH`` ordinals, plus the precomputed
        sampled-ordinal positions the index fast path reads."""
        base = (start // _BLOCK) * _BLOCK
        self._draws = draws_range(
            self.cfg.seed, base, max(self._PREFETCH, count + _BLOCK))
        self._draws_start = base
        self._hit_ordinals = base + np.nonzero(self._draws < self.cfg.rate)[0]

    def sample(self, count: int) -> np.ndarray:
        """(count,) bool mask over the next ``count`` query ordinals;
        advances the ordinal counter whether or not anything samples.
        Bit-identical to ``sampled_mask`` (the prefetch is a cache of the
        same pure draws, so restart determinism is untouched)."""
        s = self.seen
        lo = s - self._draws_start
        if self._draws is None or lo < 0 or lo + count > len(self._draws):
            self._prefetch(s, count)
            lo = s - self._draws_start
        mask = self._draws[lo:lo + count] < self.cfg.rate
        self.seen += count
        return mask

    def sample_indices(self, count: int) -> np.ndarray:
        """Positions within the next ``count`` ordinals that sample —
        ``np.nonzero(sample(count))[0]`` without allocating the mask: the
        per-serving-batch fast path (a couple of binary searches over the
        prefetched hit list, ~2us on the usual nothing-sampled batch)."""
        s = self.seen
        lo = s - self._draws_start
        if self._draws is None or lo < 0 or lo + count > len(self._draws):
            self._prefetch(s, count)
        hits = self._hit_ordinals
        a, b = np.searchsorted(hits, (s, s + count))
        self.seen += count
        return hits[a:b] - s

    # ------------------------------------------------------------ estimator
    def observe(self, hits, trials) -> None:
        """Append per-query (hits, trials) outcomes to the window."""
        hits = np.atleast_1d(np.asarray(hits, np.int64))
        trials = np.atleast_1d(np.asarray(trials, np.int64))
        for h, t in zip(hits, trials):
            if t <= 0:
                continue  # empty sub-corpus: nothing to judge
            self._hits[self._pos] = h
            self._trials[self._pos] = t
            self._pos = (self._pos + 1) % self.cfg.window
            self._len = min(self._len + 1, self.cfg.window)
            self.probed += 1

    def estimate(self) -> dict:
        """Windowed recall@k with its Wilson interval."""
        h = float(self._hits[: self._len].sum())
        t = float(self._trials[: self._len].sum())
        p, lo, hi = wilson_interval(h, t, self.cfg.z)
        return {
            "recall": p, "lo": lo, "hi": hi,
            "window_probed": int(self._len), "trials": int(t),
        }

    # ------------------------------------------------------------ SLO floor
    def update_slo(self) -> Optional[str]:
        """Re-evaluate the floor; returns "breach" on the SERVING->breach
        edge, "recover" on the way back, None otherwise.  A breach needs
        the *upper* Wilson bound under the floor (confidently bad, not
        noisily bad) over at least ``slo_min_samples`` probed queries."""
        floor = self.cfg.slo_floor
        if floor is None or self._len < self.cfg.slo_min_samples:
            return None
        est = self.estimate()
        if not self.breached and est["hi"] < floor:
            self.breached = True
            self.breaches += 1
            return "breach"
        if self.breached and est["recall"] >= floor:
            self.breached = False
            return "recover"
        return None

    # ------------------------------------------------------------- plumbing
    def reset(self) -> None:
        """Fresh stream: ordinal counter, window and SLO state all rewind
        (what a server ``swap()`` calls so estimates never mix engines)."""
        self.seen = 0        # query ordinals consumed (sampled or not)
        self._draws = None   # prefetched hash draws (see _prefetch())
        self._draws_start = 0
        self._hit_ordinals = None
        self.probed = 0      # lifetime probed-query count
        self.breaches = 0
        self.breached = False
        self._hits = np.zeros((self.cfg.window,), np.int64)
        self._trials = np.zeros((self.cfg.window,), np.int64)
        self._pos = 0
        self._len = 0

    def stats(self) -> dict:
        """The ``stats()["quality"]`` block."""
        est = self.estimate()
        out = {
            "rate": self.cfg.rate,
            "k": self.cfg.k,
            "seed": self.cfg.seed,
            "window": self.cfg.window,
            "seen": int(self.seen),
            "probed": int(self.probed),
            "window_probed": est["window_probed"],
            "recall_estimate": round(est["recall"], 4),
            "ci_low": round(est["lo"], 4),
            "ci_high": round(est["hi"], 4),
        }
        if self.cfg.slo_floor is not None:
            out.update(slo_floor=self.cfg.slo_floor,
                       breached=self.breached, breaches=self.breaches)
        return out
