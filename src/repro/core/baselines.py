"""ANN baselines the paper compares against (§5.1, App. F.7) — in JAX.

* ``brute_force`` / ``BruteIndex`` — exact blocked top-k (the ground-truth
                      oracle).
* ``IVFFlat``       — k-means coarse quantizer + probed exact scoring
                      (FAISS IVF-Flat semantics).
* ``IVFPQ``         — IVF + product quantization with ADC lookup tables
                      (Jégou et al. 2011).
* ``NSWGraph``      — greedy beam search over a kNN graph (the navigable-
                      small-world core of HNSW, single layer).

All searches are jit-compiled with static shapes (clusters padded to the max
list length; beam frontiers fixed-width) — the TPU-idiomatic formulation of
the same algorithms.

Every searcher implements the ``core/index`` protocol: it registers under a
string key, builds from one config mapping, returns a ``SearchResult`` whose
``comparisons`` field counts original-space distance evaluations (the
paper's implementation-agnostic cost metric), reports ``memory_bytes()``,
and exposes ``shard_state``/``shard_search`` so ``ShardedIndex`` can run it
data-parallel over corpus shards.  The pre-registry entry points (keyword
arguments like ``nprobe=4``) keep working unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core import knn_graph as knn_lib
from repro.core import metrics as metrics_lib
from repro.core import quant as quant_lib
from repro.core import scan as scan_lib
from repro.core.index import SearchResult


# ---------------------------------------------------------------------------
# brute force
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "metric", "block", "impl"))
def brute_force(
    X: jax.Array, Q: jax.Array, *, k: int = 1, metric: str = "euclidean",
    block: int = 0, impl: str = "jnp", valid: Optional[jax.Array] = None,
) -> SearchResult:
    """Exact search. Returns SearchResult (idx (B,k), dist (B,k), comps (B,)).

    Streams over X through ``core/scan`` — the (B, n) score matrix is never
    materialized, so ground truth stays computable when n no longer fits.
    ``valid`` (n,) bool restricts candidates (filtered search): the scan
    masks non-passing rows to +inf, so the answer is bit-identical to a
    brute scan over the pre-filtered sub-corpus (same per-pair distance
    arithmetic, same ascending-index tie order), and comparisons count the
    passing rows actually scored."""
    dists, idx = scan_lib.topk_scan(
        Q, X, k=k, metric=metric, impl=impl,
        block=block or scan_lib.DEFAULT_BLOCK, valid=valid,
    )
    if valid is None:
        comps = jnp.full((Q.shape[0],), X.shape[0], jnp.int32)
    else:
        comps = jnp.broadcast_to(
            jnp.sum(valid).astype(jnp.int32), (Q.shape[0],)
        )
    return SearchResult(idx, dists, comps)


@functools.partial(
    jax.jit, static_argnames=("k", "K", "metric", "block", "impl")
)
def _brute_quant_search(
    Q, codes, scales, sqnorms, X, *, k, K, metric, block, impl, valid=None,
) -> SearchResult:
    """Quantized two-stage brute scan: first pass over int8 codes keeps the
    ``K = quant.shortlist_width(k, n)`` best, the shortlist is re-scored
    exactly in f32 (``topk_candidates``) and the best k survive.  The full
    corpus is read at 1 byte/dim; f32 rows are touched only for the K
    shortlisted candidates.  Comparisons count both stages: n code scores
    (sum of the mask under a filter) + K exact re-scores."""
    qd, qpos = scan_lib.topk_scan_quant(
        Q, codes, scales, k=K, metric=metric, impl=impl,
        block=block or scan_lib.DEFAULT_BLOCK, valid=valid, sqnorms=sqnorms,
    )
    idx, dists = jax.vmap(
        lambda q, c: scan_lib.topk_candidates(q, c, X, k=k, metric=metric)
    )(Q, qpos)
    if valid is None:
        scanned = jnp.int32(codes.shape[0])
    else:
        scanned = jnp.sum(valid).astype(jnp.int32)
    comps = jnp.broadcast_to(scanned + K, (Q.shape[0],))
    return SearchResult(idx.astype(jnp.int32), dists, comps)


@index_lib.register_index("brute")
@dataclasses.dataclass
class BruteIndex:
    """The exact oracle behind the uniform contract (budget is ignored —
    a brute scan always pays n comparisons per query).  With a ``quant``
    store attached (the registry's ``quant`` cfg key) the scan becomes the
    quantized two-stage: int8 first pass, exact f32 rerank of the pow2
    shortlist — recall >= 0.99 at a quarter of the scanned bytes."""

    X: jax.Array
    metric: str = "euclidean"
    impl: str = "jnp"
    block: int = 0
    search_defaults: dict = dataclasses.field(default_factory=dict)
    quant: Optional[quant_lib.QuantStore] = None

    #: ShardedIndex may hand this engine per-shard code slices
    shard_supports_quant = True

    @classmethod
    def build(
        cls, X: jax.Array, *, metric: str = "euclidean", impl: str = "jnp",
        block: int = 0,
    ) -> "BruteIndex":
        return cls(X=jnp.asarray(X, jnp.float32), metric=metric, impl=impl, block=block)

    def search(self, Q: jax.Array, k: int = 1, *, budget: Optional[int] = None,
               filter=None) -> SearchResult:
        from repro.core import filter as filter_lib

        filter = index_lib.resolve(filter, self.search_defaults, "filter")
        mask = filter_lib.resolve_mask(
            filter, getattr(self, "attrs", None), self.X.shape[0]
        )
        Q = jnp.asarray(Q, jnp.float32)
        k = int(k)
        if self.quant is not None:
            codes, scales, sqnorms = self.quant.device_view()
            return _brute_quant_search(
                Q, codes, scales, sqnorms, self.X, k=k,
                K=quant_lib.shortlist_width(k, self.X.shape[0]),
                metric=self.metric, block=self.block, impl=self.impl,
                valid=mask,
            )
        return brute_force(
            self.X, Q, k=k, metric=self.metric,
            block=self.block, impl=self.impl, valid=mask,
        )

    def memory_bytes(self) -> int:
        return index_lib.pytree_nbytes(self.X) + index_lib.side_store_bytes(self)

    # -------------------------------------------------------------- snapshot
    def snapshot_state(self):
        return {"X": self.X}, {
            "metric": self.metric, "impl": self.impl, "block": self.block,
            "search_defaults": self.search_defaults,
        }

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "BruteIndex":
        return cls(
            X=jnp.asarray(arrays["X"], jnp.float32), metric=statics["metric"],
            impl=statics["impl"], block=int(statics["block"]),
            search_defaults=dict(statics.get("search_defaults") or {}),
        )

    # -------------------------------------------------------------- sharding
    def shard_state(self):
        return {"X": self.X}, {"metric": self.metric, "impl": self.impl, "block": self.block}

    @classmethod
    def shard_search(cls, state, Q, *, k, budget, static, valid=None,
                     quant=None):
        if quant is not None:
            codes, scales, sqnorms = quant
            res = _brute_quant_search(
                Q, codes, scales, sqnorms, state["X"], k=k,
                K=quant_lib.shortlist_width(k, state["X"].shape[0]),
                metric=static["metric"], block=static["block"],
                impl=static["impl"], valid=valid,
            )
        else:
            res = brute_force(
                state["X"], Q, k=k, metric=static["metric"],
                block=static["block"], impl=static["impl"], valid=valid,
            )
        return res.idx, res.dist, res.comparisons


# ---------------------------------------------------------------------------
# k-means (shared by IVF variants)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_clusters", "iters", "metric"))
def kmeans(
    X: jax.Array, *, num_clusters: int, iters: int = 10, metric: str = "sqeuclidean",
    seed: int = 0,
):
    """Lloyd's algorithm; returns (centroids (C, d), assignment (n,))."""
    n = X.shape[0]
    key = jax.random.PRNGKey(seed)
    init_idx = jax.random.choice(key, n, (num_clusters,), replace=False)
    cents = X[init_idx]

    def body(_, cents):
        D = metrics_lib.pairwise(X, cents, metric=metric)
        assign = jnp.argmin(D, axis=1)
        one_hot = jax.nn.one_hot(assign, num_clusters, dtype=X.dtype)
        sums = one_hot.T @ X
        counts = jnp.sum(one_hot, axis=0)[:, None]
        new = sums / jnp.maximum(counts, 1.0)
        return jnp.where(counts > 0, new, cents)

    cents = jax.lax.fori_loop(0, iters, body, cents)
    assign = jnp.argmin(metrics_lib.pairwise(X, cents, metric=metric), axis=1)
    return cents, assign


def _build_lists(assign: np.ndarray, num_clusters: int) -> tuple[np.ndarray, np.ndarray]:
    """Padded inverted lists: (C, Lmax) member indices (-1 pad) + lengths."""
    lists = [np.where(assign == c)[0] for c in range(num_clusters)]
    lmax = max(1, max(len(l) for l in lists))
    padded = np.full((num_clusters, lmax), -1, np.int32)
    lens = np.zeros((num_clusters,), np.int32)
    for c, l in enumerate(lists):
        padded[c, : len(l)] = l
        lens[c] = len(l)
    return padded, lens


def _resolve_nprobe(
    nprobe: Optional[int], budget: Optional[int], *, n: int, num_clusters: int,
    default: int = 4,
) -> int:
    """The one IVF probe policy (instance AND shard paths, Flat AND PQ):
    explicit nprobe wins; else a comparison budget converts via "probing one
    list costs ~n/C scored candidates" -> nprobe = clamp(budget·C/n, 1, C);
    else ``default``.  Always clamped to [1, C]."""
    if nprobe is None and budget is not None:
        per_list = max(1, -(-n // num_clusters))
        nprobe = int(budget) // per_list
    if nprobe is None:
        nprobe = default
    return max(1, min(num_clusters, int(nprobe)))


# ---------------------------------------------------------------------------
# IVF-Flat
# ---------------------------------------------------------------------------

@index_lib.register_index("ivf_flat")
@dataclasses.dataclass
class IVFFlat:
    """k-means coarse quantizer + probed exact scoring (FAISS IVF-Flat
    semantics); nprobe trades recall for comparisons.  With a ``quant``
    store attached, probed members are first scored on int8 codes and only
    the pow2 shortlist is re-scored in f32 (IVFFlat -> IVF-SQ8, roughly)."""

    X: jax.Array
    centroids: jax.Array
    lists: jax.Array  # (C, Lmax) int32, -1 padded
    list_lens: jax.Array
    metric: str
    search_defaults: dict = dataclasses.field(default_factory=dict)
    quant: Optional[quant_lib.QuantStore] = None

    #: ShardedIndex may hand this engine per-shard code slices
    shard_supports_quant = True

    @classmethod
    def build(
        cls, X: jax.Array, *, num_clusters: int = 64, iters: int = 10,
        metric: str = "euclidean", seed: int = 0,
    ) -> "IVFFlat":
        X = jnp.asarray(X, jnp.float32)
        cents, assign = kmeans(X, num_clusters=num_clusters, iters=iters, seed=seed)
        lists, lens = _build_lists(np.asarray(assign), num_clusters)
        return cls(X=X, centroids=cents, lists=jnp.asarray(lists),
                   list_lens=jnp.asarray(lens), metric=metric)

    def search(
        self, Q: jax.Array, k: int = 1, *, nprobe: Optional[int] = None,
        budget: Optional[int] = None, filter=None,
    ) -> SearchResult:
        from repro.core import filter as filter_lib

        nprobe = _resolve_nprobe(
            index_lib.resolve(nprobe, self.search_defaults, "nprobe"),
            index_lib.resolve(budget, self.search_defaults, "budget"),
            n=self.X.shape[0], num_clusters=self.centroids.shape[0],
        )
        filter = index_lib.resolve(filter, self.search_defaults, "filter")
        mask = filter_lib.resolve_mask(
            filter, getattr(self, "attrs", None), self.X.shape[0]
        )
        idx, dist, comps = _ivf_flat_search(
            self.X, self.centroids, self.lists, self.list_lens,
            jnp.asarray(Q, jnp.float32), k=int(k), nprobe=nprobe,
            metric=self.metric, valid=mask, quant=self._quant_view(),
        )
        return SearchResult(idx, dist, comps)

    def _quant_view(self):
        if self.quant is None:
            return None
        codes, scales, _ = self.quant.device_view()
        return codes, scales

    def memory_bytes(self) -> int:
        return index_lib.pytree_nbytes(
            (self.X, self.centroids, self.lists, self.list_lens)
        ) + index_lib.side_store_bytes(self)

    # -------------------------------------------------------------- snapshot
    def snapshot_state(self):
        return (
            {"X": self.X, "centroids": self.centroids, "lists": self.lists,
             "list_lens": self.list_lens},
            {"metric": self.metric, "search_defaults": self.search_defaults},
        )

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "IVFFlat":
        return cls(
            X=jnp.asarray(arrays["X"], jnp.float32),
            centroids=jnp.asarray(arrays["centroids"], jnp.float32),
            lists=jnp.asarray(arrays["lists"], jnp.int32),
            list_lens=jnp.asarray(arrays["list_lens"], jnp.int32),
            metric=statics["metric"],
            search_defaults=dict(statics.get("search_defaults") or {}),
        )

    # -------------------------------------------------------------- sharding
    def shard_state(self):
        sd = self.search_defaults or {}
        static = {"metric": self.metric, "nprobe": sd.get("nprobe"),
                  "budget": sd.get("budget")}
        return (
            {"X": self.X, "centroids": self.centroids, "lists": self.lists,
             "list_lens": self.list_lens},
            static,
        )

    @classmethod
    def shard_search(cls, state, Q, *, k, budget, static, valid=None,
                     quant=None):
        nprobe = _resolve_nprobe(
            static.get("nprobe"), budget if budget is not None else static.get("budget"),
            n=state["X"].shape[0], num_clusters=state["centroids"].shape[0],
        )
        return _ivf_flat_search(
            state["X"], state["centroids"], state["lists"], state["list_lens"],
            Q, k=k, nprobe=nprobe, metric=static["metric"], valid=valid,
            quant=None if quant is None else quant[:2],
        )


@functools.partial(jax.jit, static_argnames=("k", "nprobe", "metric"))
def _ivf_flat_search(X, cents, lists, lens, Q, *, k, nprobe, metric, valid=None,
                     quant=None):
    B = Q.shape[0]
    Dc = metrics_lib.pairwise(Q, cents, metric=metric)
    _, probe = jax.lax.top_k(-Dc, nprobe)  # (B, nprobe)
    cand = lists[probe].reshape(B, -1)  # (B, nprobe * Lmax)
    if valid is not None:
        # filtered search: non-passing members become -1 padding BEFORE the
        # scan, so mask composition is filter ∧ list-validity and the
        # comparison count below only pays for rows actually scored
        cand = jnp.where(valid[jnp.maximum(cand, 0)] & (cand >= 0), cand, -1)
    ok = cand >= 0
    # quantized probing: gathered members score on int8 codes first, then
    # only the pow2 shortlist touches f32 rows (the rerank-width rule);
    # both stages land in the comparison count.  When the width already
    # covers every gathered candidate the code pass could not shrink
    # anything — skip it (same guard as the infinity rerank prefilter).
    K = 0
    if quant is not None:
        w = quant_lib.shortlist_width(k, X.shape[0])
        if w < int(cand.shape[1]):
            K = w

    def per_query(q, c, v):
        nv = jnp.sum(v).astype(jnp.int32)
        if K:
            codes, scales = quant
            c, _ = scan_lib.quant_candidates(
                q, c, codes, scales, k=K, metric=metric
            )
            nv = nv + K
        # probed-list scoring routes through the scan engine; the padded
        # slots are masked inside the merge
        idx, d = scan_lib.topk_candidates(q, c, X, k=k, metric=metric)
        return idx, d, nv

    idx, dist, comps = jax.vmap(per_query)(Q, cand, ok)
    return idx.astype(jnp.int32), dist, comps


# ---------------------------------------------------------------------------
# IVF-PQ (ADC)
# ---------------------------------------------------------------------------

@index_lib.register_index("ivf_pq")
@dataclasses.dataclass
class IVFPQ:
    """IVF + product quantization with ADC lookup tables (Jégou et al.
    2011); optional exact rerank of the ADC shortlist."""

    X: jax.Array
    centroids: jax.Array  # coarse (C, d)
    codebooks: jax.Array  # (M, 256sub, dsub)
    codes: jax.Array  # (n, M) uint8-as-int32 PQ codes of residuals
    lists: jax.Array
    list_lens: jax.Array
    metric: str
    search_defaults: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def build(
        cls, X: jax.Array, *, num_clusters: int = 64, M: int = 8, ksub: int = 32,
        iters: int = 10, metric: str = "euclidean", seed: int = 0,
    ) -> "IVFPQ":
        """PQ on residuals (x - coarse centroid), M subspaces, ksub centroids
        per subspace (<= 256)."""
        X = jnp.asarray(X, jnp.float32)
        n, d = X.shape
        assert d % M == 0, (d, M)
        dsub = d // M
        cents, assign = kmeans(X, num_clusters=num_clusters, iters=iters, seed=seed)
        resid = X - cents[assign]
        sub = resid.reshape(n, M, dsub)
        books, codes = [], []
        for m in range(M):
            cb, cd = kmeans(sub[:, m], num_clusters=ksub, iters=iters, seed=seed + m + 1)
            books.append(cb)
            codes.append(cd)
        lists, lens = _build_lists(np.asarray(assign), num_clusters)
        return cls(
            X=X, centroids=cents, codebooks=jnp.stack(books),
            codes=jnp.stack(codes, axis=1).astype(jnp.int32),
            lists=jnp.asarray(lists), list_lens=jnp.asarray(lens), metric=metric,
        )

    def search(
        self, Q: jax.Array, k: int = 1, *, nprobe: Optional[int] = None,
        rerank: Optional[int] = None, budget: Optional[int] = None,
        filter=None,
    ) -> SearchResult:
        from repro.core import filter as filter_lib

        nprobe = _resolve_nprobe(
            index_lib.resolve(nprobe, self.search_defaults, "nprobe"),
            index_lib.resolve(budget, self.search_defaults, "budget"),
            n=self.X.shape[0], num_clusters=self.centroids.shape[0],
        )
        rerank = int(index_lib.resolve(rerank, self.search_defaults, "rerank", 0))
        filter = index_lib.resolve(filter, self.search_defaults, "filter")
        mask = filter_lib.resolve_mask(
            filter, getattr(self, "attrs", None), self.X.shape[0]
        )
        idx, dist, comps = _ivf_pq_search(
            self.X, self.centroids, self.codebooks, self.codes, self.lists,
            jnp.asarray(Q, jnp.float32), k=int(k), nprobe=nprobe, rerank=rerank,
            metric=self.metric, valid=mask,
        )
        return SearchResult(idx, dist, comps)

    def memory_bytes(self) -> int:
        return index_lib.pytree_nbytes(
            (self.X, self.centroids, self.codebooks, self.codes, self.lists, self.list_lens)
        ) + index_lib.side_store_bytes(self)

    # -------------------------------------------------------------- snapshot
    def snapshot_state(self):
        return (
            {"X": self.X, "centroids": self.centroids, "codebooks": self.codebooks,
             "codes": self.codes, "lists": self.lists, "list_lens": self.list_lens},
            {"metric": self.metric, "search_defaults": self.search_defaults},
        )

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "IVFPQ":
        return cls(
            X=jnp.asarray(arrays["X"], jnp.float32),
            centroids=jnp.asarray(arrays["centroids"], jnp.float32),
            codebooks=jnp.asarray(arrays["codebooks"], jnp.float32),
            codes=jnp.asarray(arrays["codes"], jnp.int32),
            lists=jnp.asarray(arrays["lists"], jnp.int32),
            list_lens=jnp.asarray(arrays["list_lens"], jnp.int32),
            metric=statics["metric"],
            search_defaults=dict(statics.get("search_defaults") or {}),
        )

    # -------------------------------------------------------------- sharding
    def shard_state(self):
        sd = self.search_defaults or {}
        static = {"metric": self.metric, "nprobe": sd.get("nprobe"),
                  "rerank": int(sd.get("rerank") or 0), "budget": sd.get("budget")}
        return (
            {"X": self.X, "centroids": self.centroids, "codebooks": self.codebooks,
             "codes": self.codes, "lists": self.lists, "list_lens": self.list_lens},
            static,
        )

    @classmethod
    def shard_search(cls, state, Q, *, k, budget, static, valid=None):
        nprobe = _resolve_nprobe(
            static.get("nprobe"), budget if budget is not None else static.get("budget"),
            n=state["X"].shape[0], num_clusters=state["centroids"].shape[0],
        )
        return _ivf_pq_search(
            state["X"], state["centroids"], state["codebooks"], state["codes"],
            state["lists"], Q, k=k, nprobe=nprobe,
            rerank=int(static.get("rerank") or 0), metric=static["metric"],
            valid=valid,
        )


@functools.partial(jax.jit, static_argnames=("k", "nprobe", "rerank", "metric"))
def _ivf_pq_search(X, cents, books, codes, lists, Q, *, k, nprobe, rerank, metric,
                   valid=None):
    """ADC: per (query, probed cluster) LUT of query-residual -> subspace
    centroid sq-distances; candidate distance = sum of LUT entries."""
    B, d = Q.shape
    M, ksub, dsub = books.shape
    if valid is not None:
        # filtered search: drop non-passing members to -1 padding at the
        # source, so ADC scoring, the comparison count and the rerank
        # shortlist all see only passing rows
        lists = jnp.where(valid[jnp.maximum(lists, 0)] & (lists >= 0), lists, -1)
    Dc = metrics_lib.pairwise(Q, cents, metric="sqeuclidean")
    _, probe = jax.lax.top_k(-Dc, nprobe)  # (B, nprobe)

    def per_query(q, probes):
        def per_cluster(c):
            r = (q - cents[c]).reshape(M, dsub)  # query residual
            # LUT (M, ksub): ||r_m - codebook[m, j]||^2
            lut = jnp.sum((r[:, None, :] - books) ** 2, axis=-1)
            members = lists[c]  # (Lmax,)
            mcodes = codes[jnp.maximum(members, 0)]  # (Lmax, M)
            adc = jnp.sum(lut[jnp.arange(M)[None, :], mcodes], axis=-1)
            adc = jnp.where(members >= 0, adc, jnp.inf)
            return members, adc

        mem, adc = jax.vmap(per_cluster)(probes)  # (nprobe, Lmax)
        mem = mem.reshape(-1)
        adc = adc.reshape(-1)
        kk = max(k, rerank)
        neg, pos = jax.lax.top_k(-adc, kk)
        cand = mem[pos]
        comps = jnp.sum(jnp.isfinite(adc)).astype(jnp.int32)
        if rerank:
            # exact re-scoring of the ADC shortlist via the scan engine
            idx2, dex = scan_lib.topk_candidates(q, cand, X, k=k, metric=metric)
            return idx2, dex, comps
        return cand[:k], -neg[:k], comps

    idx, dist, comps = jax.vmap(per_query)(Q, probe)
    return idx.astype(jnp.int32), dist, comps


# ---------------------------------------------------------------------------
# NSW graph beam search
# ---------------------------------------------------------------------------

@index_lib.register_index("nsw")
@dataclasses.dataclass
class NSWGraph:
    """Greedy beam search over a kNN graph with random long-range links
    (the navigable-small-world core of HNSW, single layer)."""

    X: jax.Array
    neighbors: jax.Array  # (n, deg) int32
    metric: str
    entry: int
    search_defaults: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def build(
        cls, X: jax.Array, *, degree: int = 16, random_links: int = 4,
        metric: str = "euclidean", seed: int = 0,
    ) -> "NSWGraph":
        """kNN edges + a few random long-range links per node — the
        small-world shortcut that lets greedy search hop between clusters
        (HNSW gets this from its upper layers)."""
        X = jnp.asarray(X, jnp.float32)
        idx, _ = knn_lib.knn_graph(X, k=degree, metric=metric)
        rng = np.random.default_rng(seed)
        if random_links > 0:
            extra = rng.integers(0, X.shape[0], size=(X.shape[0], random_links))
            idx = jnp.concatenate([idx, jnp.asarray(extra, jnp.int32)], axis=1)
        return cls(X=X, neighbors=idx, metric=metric, entry=int(rng.integers(X.shape[0])))

    def search(
        self, Q: jax.Array, k: int = 1, *, ef: Optional[int] = None,
        max_steps: Optional[int] = None, budget: Optional[int] = None,
        filter=None,
    ) -> SearchResult:
        from repro.core import filter as filter_lib

        ef, max_steps = self._resolve_beam(
            int(k),
            index_lib.resolve(ef, self.search_defaults, "ef"),
            index_lib.resolve(max_steps, self.search_defaults, "max_steps"),
            index_lib.resolve(budget, self.search_defaults, "budget"),
            deg=self.neighbors.shape[1],
        )
        filter = index_lib.resolve(filter, self.search_defaults, "filter")
        mask = filter_lib.resolve_mask(
            filter, getattr(self, "attrs", None), self.X.shape[0]
        )
        idx, dist, comps = _nsw_search(
            self.X, self.neighbors, jnp.asarray(Q, jnp.float32),
            jnp.int32(self.entry), k=int(k), ef=ef, max_steps=max_steps,
            metric=self.metric, valid=mask,
        )
        return SearchResult(idx, dist, comps)

    @staticmethod
    def _resolve_beam(k, ef, max_steps, budget, *, deg) -> tuple[int, int]:
        """The one beam policy (instance AND shard paths): explicit knobs
        win; else a budget converts via "each expansion scores <= deg fresh
        neighbors" -> max_steps = budget/deg."""
        ef = 32 if ef is None else int(ef)
        if max_steps is None and budget is not None:
            max_steps = max(1, int(budget) // max(1, deg))
        return max(ef, int(k)), int(max_steps if max_steps is not None else 64)

    def memory_bytes(self) -> int:
        return index_lib.pytree_nbytes(
            (self.X, self.neighbors)
        ) + index_lib.side_store_bytes(self)

    # -------------------------------------------------------------- snapshot
    def snapshot_state(self):
        return (
            {"X": self.X, "neighbors": self.neighbors},
            {"metric": self.metric, "entry": int(self.entry),
             "search_defaults": self.search_defaults},
        )

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "NSWGraph":
        return cls(
            X=jnp.asarray(arrays["X"], jnp.float32),
            neighbors=jnp.asarray(arrays["neighbors"], jnp.int32),
            metric=statics["metric"], entry=int(statics["entry"]),
            search_defaults=dict(statics.get("search_defaults") or {}),
        )

    # -------------------------------------------------------------- sharding
    def shard_state(self):
        sd = self.search_defaults or {}
        static = {"metric": self.metric, "ef": sd.get("ef"),
                  "max_steps": sd.get("max_steps"), "budget": sd.get("budget")}
        return (
            {"X": self.X, "neighbors": self.neighbors,
             "entry": jnp.int32(self.entry)},
            static,
        )

    @classmethod
    def shard_search(cls, state, Q, *, k, budget, static, valid=None):
        ef, max_steps = cls._resolve_beam(
            k, static.get("ef"), static.get("max_steps"),
            budget if budget is not None else static.get("budget"),
            deg=state["neighbors"].shape[1],
        )
        return _nsw_search(
            state["X"], state["neighbors"], Q, state["entry"], k=k,
            ef=ef, max_steps=max_steps, metric=static["metric"], valid=valid,
        )


@functools.partial(jax.jit, static_argnames=("k", "ef", "max_steps", "metric"))
def _nsw_search(X, neighbors, Q, entry, *, k, ef, max_steps, metric, valid=None):
    """Greedy best-first beam (HNSW layer-0 semantics, fixed iteration count).

    Frontier = ef best visited nodes; each step expands the best unexpanded
    node's neighbor list.  Visited set is a dense (n,) bool row per query —
    fine at benchmark scale, and fully vectorized on TPU.  ``entry`` is a
    traced int32 scalar so per-shard entry points ride along as data.

    ``valid`` (n,) bool gives filtered-graph-search semantics: the beam
    NAVIGATES over every node — restricting the graph itself to passing
    nodes would disconnect it under narrow filters — while a separate
    result buffer collects the best passing nodes seen.  Each node's
    distance is evaluated exactly once (the visited set), so a node enters
    the result buffer at most once and comps counts every evaluation
    regardless of whether the node passes.
    """
    n, deg = neighbors.shape
    pair = metrics_lib.pair_fn(metric)
    entry = entry.astype(jnp.int32)

    def per_query(q):
        d0 = pair(q, X[entry])
        cand_i = jnp.full((ef,), -1, jnp.int32).at[0].set(entry)
        cand_d = jnp.full((ef,), jnp.inf, jnp.float32).at[0].set(d0)
        expanded = jnp.zeros((ef,), bool)
        visited = jnp.zeros((n,), bool).at[entry].set(True)
        comps = jnp.int32(1)
        if valid is None:
            res_i = res_d = None
        else:  # passing-node result buffer, seeded with the entry if it passes
            res_i = jnp.where(valid[entry], cand_i, -1)
            res_d = jnp.where(valid[entry], cand_d, jnp.inf)

        def cond(st):
            cand_i, cand_d, expanded, visited, comps, t, *_ = st
            has_unexpanded = jnp.any((cand_i >= 0) & ~expanded)
            return has_unexpanded & (t < max_steps)

        def body(st):
            cand_i, cand_d, expanded, visited, comps, t, *res = st
            d_mask = jnp.where((cand_i >= 0) & ~expanded, cand_d, jnp.inf)
            b = jnp.argmin(d_mask)
            node = cand_i[b]
            expanded = expanded.at[b].set(True)
            nbrs = neighbors[jnp.maximum(node, 0)]  # (deg,)
            fresh = ~visited[nbrs]
            # a neighbor row can list the same node twice (a random long
            # link duplicating a kNN edge): only the FIRST occurrence is
            # fresh, else the duplicate enters the frontier/result twice,
            # double-counts comps, and can evict a true neighbor.  deg is
            # small, so the O(deg^2) first-occurrence mask is free.
            pos = jnp.arange(deg)
            earlier_dup = jnp.any(
                (nbrs[None, :] == nbrs[:, None]) & (pos[None, :] < pos[:, None]),
                axis=1,
            )
            fresh = fresh & ~earlier_dup
            visited = visited.at[nbrs].set(True)
            nd = jax.vmap(lambda j: pair(q, X[j]))(nbrs)
            nd = jnp.where(fresh, nd, jnp.inf)
            comps = comps + jnp.sum(fresh).astype(jnp.int32)
            if valid is not None:
                # fresh AND passing neighbors join the result buffer (their
                # one-and-only distance evaluation happened just above)
                res_i, res_d = res
                rd = jnp.concatenate(
                    [res_d, jnp.where(valid[nbrs], nd, jnp.inf)]
                )
                ri = jnp.concatenate([res_i, nbrs])
                keep = jnp.argsort(rd)[:ef]
                res = (ri[keep], rd[keep])
            # merge into frontier: keep ef best, preserving expansion flags
            all_i = jnp.concatenate([cand_i, nbrs])
            all_d = jnp.concatenate([cand_d, nd])
            all_e = jnp.concatenate([expanded, jnp.zeros((deg,), bool)])
            order = jnp.argsort(all_d)[:ef]
            return (all_i[order], all_d[order], all_e[order], visited, comps,
                    t + 1, *res)

        init = (cand_i, cand_d, expanded, visited, comps, jnp.int32(0))
        if valid is not None:
            init = init + (res_i, res_d)
        out = jax.lax.while_loop(cond, body, init)
        if valid is None:
            cand_i, cand_d = out[0], out[1]
        else:  # answers come from the passing-node buffer, not the frontier
            cand_i, cand_d = out[6], out[7]
            cand_i = jnp.where(jnp.isinf(cand_d), -1, cand_i)
        return cand_i[:k], cand_d[:k], out[4]

    return jax.vmap(per_query)(Q)
