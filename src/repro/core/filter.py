"""Predicate AST -> per-query candidate masks (DESIGN.md §12).

Filtered search treats a predicate as a *subset of the domain* (Pestov's
framing of similarity search; metric bounds stay valid on arbitrary
subsets — Connor et al.), so the whole subsystem reduces to one object: a
``(n,)`` bool mask that every engine ANDs into its existing candidate
validity (``core/scan``'s ``valid``, the live tombstone bitmap, IVF list
padding).  This module owns the path from user predicate to that mask:

* **AST** — ``Filter`` is an AND of ``Clause``s; three clause ops only:
  ``range`` (inclusive lo <= v <= hi, either side open), ``eq`` and
  ``isin``.  ``Filter.from_spec`` accepts the ergonomic dict form used by
  ``SearchServer.query`` (``{"shop": {"isin": ["a", "b"]}, "price":
  {"range": [0, 10]}}``, a bare scalar meaning ``eq``, a bare list meaning
  ``isin``) and normalizes everything to hashable tuples so compiled masks
  cache per filter.
* **compile_mask** — clause-by-clause jnp evaluation against an
  ``AttributeStore``'s device columns, AND-reduced.  Missing values (NaN /
  code -1) compare false under every op, and categorical clause values are
  encoded through the vocabulary on host (an unknown label matches
  nothing), so the traced program is pure float/int compares — it shards
  transparently when the columns were ``place()``d on a mesh.
* **resolve_mask** — the one entry point engines call: predicate or raw
  bool mask in, ``Optional[(n,) bool]`` device array out, with the
  store's per-filter cache in the middle.
* **selectivity** — estimated passing fraction.  Exact (one mean) at the
  corpus sizes this repo runs; the infinity engine scales its two-stage
  rerank width by it so recall holds on narrow filters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core import attrs as attrs_lib

OPS = ("range", "eq", "isin")


@dataclasses.dataclass(frozen=True)
class Clause:
    """One column constraint.  ``value``: range -> (lo, hi) with None =
    open side; eq -> scalar; isin -> tuple of scalars/labels."""

    col: str
    op: str
    value: Any

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown filter op {self.op!r}; have {OPS}")
        if self.op == "range":
            lo, hi = self.value  # malformed ranges fail here, not at compile
            if lo is None and hi is None:
                raise ValueError(f"range on {self.col!r}: both sides open")


@dataclasses.dataclass(frozen=True)
class Filter:
    """AND of clauses — hashable, so stores can cache compiled masks."""

    clauses: tuple[Clause, ...]

    @classmethod
    def from_spec(cls, spec) -> "Filter":
        """Normalize any accepted predicate form:

        * a ``Filter`` (returned as-is),
        * ``{"col": scalar}``              -> eq
        * ``{"col": [v1, v2]}``            -> isin
        * ``{"col": {"range": [lo, hi]}}`` / ``{"eq": v}`` / ``{"isin": [..]}``
        * a list/tuple of ``Clause``s.
        """
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, Clause):
            return cls((spec,))
        if isinstance(spec, (list, tuple)) and all(
            isinstance(c, Clause) for c in spec
        ):
            if not spec:  # vacuous all(): an empty list must not slip by
                raise ValueError("empty filter spec: pass filter=None to disable")
            return cls(tuple(spec))
        if not isinstance(spec, Mapping):
            raise TypeError(
                f"filter spec must be a Filter, Clause list, or dict: {spec!r}"
            )
        clauses = []
        for col, cond in spec.items():
            if isinstance(cond, Mapping):
                if len(cond) != 1:
                    raise ValueError(
                        f"filter[{col!r}]: one op per clause, got {sorted(cond)}"
                    )
                (op, val), = cond.items()
                if op == "range":
                    lo, hi = val
                    val = (_scalar(lo), _scalar(hi))
                elif op == "isin":
                    val = tuple(_scalar(v) for v in val)
                elif op == "eq":
                    val = _scalar(val)
                else:
                    raise ValueError(f"filter[{col!r}]: unknown op {op!r}; have {OPS}")
                clauses.append(Clause(col, op, val))
            elif isinstance(cond, (list, tuple, set, frozenset, np.ndarray)):
                clauses.append(
                    Clause(col, "isin", tuple(_scalar(v) for v in cond))
                )
            else:
                clauses.append(Clause(col, "eq", _scalar(cond)))
        if not clauses:
            raise ValueError("empty filter spec: pass filter=None to disable")
        return cls(tuple(clauses))


def _scalar(v):
    """Hashable host scalar (np scalars -> python) — None passes through."""
    if v is None or isinstance(v, str):
        return v
    if isinstance(v, (np.generic,)):
        return v.item()
    return v


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def compile_mask(filt: Filter, store: attrs_lib.AttributeStore) -> jnp.ndarray:
    """Evaluate the AND-of-clauses against the store's device columns.

    Returns a ``(n,)`` bool device array (n = the store's row capacity; the
    live subsystem ANDs its alive bitmap on top).  NaN numeric values and
    -1 categorical codes fail every clause by construction."""
    mask = None
    for cl in filt.clauses:
        kind = store.kind(cl.col)  # unknown columns raise here
        col = store.device_columns()[cl.col]
        if kind == "numeric":
            m = _numeric_clause(cl, col)
        else:
            m = _categorical_clause(cl, col, store)
        mask = m if mask is None else (mask & m)
    return mask


def _numeric_clause(cl: Clause, col: jnp.ndarray) -> jnp.ndarray:
    if cl.op == "range":
        lo, hi = cl.value
        m = jnp.ones(col.shape, bool)
        if lo is not None:
            m = m & (col >= jnp.float32(lo))
        if hi is not None:
            m = m & (col <= jnp.float32(hi))
        # NaN >= lo is already False, but an open side must not let NaN through
        return m & ~jnp.isnan(col)
    if cl.op == "eq":
        if cl.value is None:  # None is the missing sentinel: matches nothing
            return jnp.zeros(col.shape, bool)
        return col == jnp.float32(cl.value)
    # isin: small OR-reduction — clause value lists are operator-sized
    m = jnp.zeros(col.shape, bool)
    for v in cl.value:
        if v is None:
            continue
        m = m | (col == jnp.float32(v))
    return m


def _categorical_clause(
    cl: Clause, codes: jnp.ndarray, store: attrs_lib.AttributeStore
) -> jnp.ndarray:
    if cl.op == "range":
        raise TypeError(f"range clause on categorical column {cl.col!r}")
    values = (cl.value,) if cl.op == "eq" else tuple(cl.value)
    # host-side vocabulary encode: unknown labels -> -1, dropped below, so
    # the compiled program only ever compares against real codes (missing
    # rows are code -1 and can never match)
    enc = [store.encode(cl.col, v) for v in values]
    enc = [c for c in enc if c >= 0]
    if not enc:
        return jnp.zeros(codes.shape, bool)
    m = jnp.zeros(codes.shape, bool)
    for c in enc:
        m = m | (codes == jnp.int32(c))
    return m


# ---------------------------------------------------------------------------
# the engine entry point
# ---------------------------------------------------------------------------

MaskOrSpec = Union[None, Filter, Clause, Mapping, list, tuple, np.ndarray,
                   jnp.ndarray]


def resolve_mask(
    filt: MaskOrSpec, store: Optional[attrs_lib.AttributeStore], n: int
) -> Optional[jnp.ndarray]:
    """Engine-side resolution: predicate spec or raw bool mask -> device
    mask (or None = unfiltered).

    Raw ``(n,)`` bool arrays pass straight through (the composition path:
    live hands its frozen engine a pre-sliced mask, sharded hands each
    shard a row slice).  Predicates need the engine to hold an
    ``AttributeStore`` (the ``attrs`` cfg key at build) and are compiled
    once per distinct filter — the store caches by the hashable AST."""
    if filt is None:
        return None
    if isinstance(filt, (np.ndarray, jnp.ndarray)):
        if filt.ndim != 1 or filt.shape[0] != n:
            raise ValueError(
                f"filter mask shape {filt.shape} != corpus rows ({n},)"
            )
        return jnp.asarray(filt, bool)
    if store is None:
        raise TypeError(
            "this index has no attribute store: build it with an 'attrs' "
            "cfg mapping (or pass a precomputed (n,) bool mask)"
        )
    f = Filter.from_spec(filt)
    cached = store.mask_cache.get(f)
    if cached is None:
        cached = store.mask_cache[f] = compile_mask(f, store)
    if cached.shape[0] < n:
        raise ValueError(
            f"attribute store covers {cached.shape[0]} rows < corpus {n}"
        )
    return cached[:n] if cached.shape[0] > n else cached


def selectivity(mask) -> float:
    """Estimated passing fraction of a mask (exact at current scales —
    one device mean; the hook where a sampled estimator would slot in)."""
    return float(jnp.mean(jnp.asarray(mask, jnp.float32)))


def cached_selectivity(
    filt: MaskOrSpec, store: Optional[attrs_lib.AttributeStore], mask
) -> float:
    """``selectivity(mask)`` with the device->host sync amortized: when the
    filter is a predicate resolved through ``store``, the fraction caches
    next to the compiled mask (``sel_cache``, cleared on mutation), so a
    serving loop re-issuing the same filter pays the sync once.  Raw masks
    still pay per call — they carry no cacheable identity."""
    if store is None or filt is None or isinstance(filt, (np.ndarray, jnp.ndarray)):
        return selectivity(mask)
    f = Filter.from_spec(filt)
    sel = store.sel_cache.get(f)
    if sel is None:
        sel = store.sel_cache[f] = selectivity(mask)
    return sel


def bucket_selectivity(sel: float, floor: float = 1e-4) -> float:
    """Selectivity rounded DOWN to a power of two in [floor, 1].

    Static knobs derived from selectivity (the infinity rerank width) key
    jit caches; bucketing bounds the distinct compiled programs to
    O(log 1/floor) while only ever widening the derived knob (rounding the
    selectivity down scales the width up — conservative for recall)."""
    import math

    if sel >= 1.0:
        return 1.0
    return 2.0 ** math.floor(math.log2(max(sel, floor)))


def scaled_width(K: int, sel: float, n: int) -> int:
    """Selectivity-scaled two-stage rerank width (infinity engine).

    A filter of selectivity s leaves the true k-th passing neighbor ~1/s
    deeper in the *unfiltered* embedding-space ranking, so the candidate
    stage must surface ~K/s passing candidates' worth of tree frontier to
    keep recall flat.  Rounded to the next power of two (``scan.pow2ceil``
    — bounds recompilation to O(log n) widths, the ``core/live``
    oversampling discipline) and clamped to [K, n]."""
    from repro.core.scan import pow2ceil

    if sel <= 0.0:
        return min(n, max(K, 1))
    want = int(np.ceil(K / max(sel, 1.0 / max(n, 1))))
    return max(K, min(n, pow2ceil(want)))
