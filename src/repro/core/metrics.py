"""Dissimilarity functions (paper §2, Table 1).

Every metric has two entry points:
  * ``<name>(x, y)``            — single-pair dissimilarity, jnp scalars in/out.
  * ``<name>_matrix(X, Y)``     — blocked (m, n) pairwise matrix.

All are pure jnp and jit/vmap friendly. ``pairwise`` dispatches by name and is
the single integration point used by the projection, VP tree, baselines and
benchmarks. The Pallas ``kernels/pdist`` path is selected by
``pairwise(..., impl="pallas")`` where the metric is supported.

Naming note: this module is the DISSIMILARITY registry.  Operational
metrics — counters, latency histograms, Prometheus exposition — live in
``repro.core.telemetry`` (DESIGN.md §16), which is never re-exported under
the name ``metrics``; keep the two namespaces apart (``__all__`` below is
the explicit public surface of this one).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "EPS", "METRICS",
    "euclidean", "sqeuclidean", "manhattan", "chebyshev", "cosine",
    "correlation", "jaccard", "dot",
    "euclidean_matrix", "sqeuclidean_matrix", "manhattan_matrix",
    "chebyshev_matrix", "cosine_matrix", "correlation_matrix",
    "jaccard_matrix", "dot_matrix",
    "pair_fn", "matrix_fn", "pairwise",
]

EPS = 1e-12

# ---------------------------------------------------------------------------
# Single-pair forms
# ---------------------------------------------------------------------------


def euclidean(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.maximum(jnp.sum((x - y) ** 2), 0.0))


def sqeuclidean(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.sum((x - y) ** 2)


def manhattan(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.sum(jnp.abs(x - y))


def chebyshev(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(x - y))


def cosine(x: jax.Array, y: jax.Array) -> jax.Array:
    nx = jnp.sqrt(jnp.sum(x * x))
    ny = jnp.sqrt(jnp.sum(y * y))
    return 1.0 - jnp.dot(x, y) / jnp.maximum(nx * ny, EPS)


def correlation(x: jax.Array, y: jax.Array) -> jax.Array:
    xc = x - jnp.mean(x)
    yc = y - jnp.mean(y)
    return cosine(xc, yc)


def jaccard(x: jax.Array, y: jax.Array) -> jax.Array:
    """Jaccard dissimilarity for binary (0/1) vectors."""
    xb = x > 0
    yb = y > 0
    inter = jnp.sum(jnp.logical_and(xb, yb))
    union = jnp.sum(jnp.logical_or(xb, yb))
    return 1.0 - inter / jnp.maximum(union, 1)


def dot(x: jax.Array, y: jax.Array) -> jax.Array:
    """Negative inner product (maximum-inner-product search as dissimilarity)."""
    return -jnp.dot(x, y)


# ---------------------------------------------------------------------------
# Matrix forms — MXU-friendly formulations where possible
# ---------------------------------------------------------------------------


def sqeuclidean_matrix(X: jax.Array, Y: jax.Array) -> jax.Array:
    """(m, n) squared distances via ``|x|^2 + |y|^2 - 2 x.yT`` (one matmul)."""
    x2 = jnp.sum(X * X, axis=-1)[:, None]
    y2 = jnp.sum(Y * Y, axis=-1)[None, :]
    d2 = x2 + y2 - 2.0 * (X @ Y.T)
    return jnp.maximum(d2, 0.0)


def euclidean_matrix(X: jax.Array, Y: jax.Array) -> jax.Array:
    return jnp.sqrt(sqeuclidean_matrix(X, Y))


def manhattan_matrix(X: jax.Array, Y: jax.Array) -> jax.Array:
    # O(m n d) with broadcast; blocked by the caller for large m,n.
    return jnp.sum(jnp.abs(X[:, None, :] - Y[None, :, :]), axis=-1)


def chebyshev_matrix(X: jax.Array, Y: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(X[:, None, :] - Y[None, :, :]), axis=-1)


def cosine_matrix(X: jax.Array, Y: jax.Array) -> jax.Array:
    Xn = X / jnp.maximum(jnp.linalg.norm(X, axis=-1, keepdims=True), EPS)
    Yn = Y / jnp.maximum(jnp.linalg.norm(Y, axis=-1, keepdims=True), EPS)
    return 1.0 - Xn @ Yn.T


def correlation_matrix(X: jax.Array, Y: jax.Array) -> jax.Array:
    Xc = X - jnp.mean(X, axis=-1, keepdims=True)
    Yc = Y - jnp.mean(Y, axis=-1, keepdims=True)
    return cosine_matrix(Xc, Yc)


def jaccard_matrix(X: jax.Array, Y: jax.Array) -> jax.Array:
    Xb = (X > 0).astype(jnp.float32)
    Yb = (Y > 0).astype(jnp.float32)
    inter = Xb @ Yb.T  # MXU-friendly
    sx = jnp.sum(Xb, axis=-1)[:, None]
    sy = jnp.sum(Yb, axis=-1)[None, :]
    union = sx + sy - inter
    return 1.0 - inter / jnp.maximum(union, 1.0)


def dot_matrix(X: jax.Array, Y: jax.Array) -> jax.Array:
    return -(X @ Y.T)


_PAIR: dict[str, Callable] = {
    "euclidean": euclidean,
    "sqeuclidean": sqeuclidean,
    "manhattan": manhattan,
    "chebyshev": chebyshev,
    "cosine": cosine,
    "correlation": correlation,
    "jaccard": jaccard,
    "dot": dot,
}

_MATRIX: dict[str, Callable] = {
    "euclidean": euclidean_matrix,
    "sqeuclidean": sqeuclidean_matrix,
    "manhattan": manhattan_matrix,
    "chebyshev": chebyshev_matrix,
    "cosine": cosine_matrix,
    "correlation": correlation_matrix,
    "jaccard": jaccard_matrix,
    "dot": dot_matrix,
}

METRICS = tuple(sorted(_PAIR))


def pair_fn(metric: str) -> Callable:
    if metric not in _PAIR:
        raise KeyError(f"unknown metric {metric!r}; available: {METRICS}")
    return _PAIR[metric]


def matrix_fn(metric: str) -> Callable:
    if metric not in _MATRIX:
        raise KeyError(f"unknown metric {metric!r}; available: {METRICS}")
    return _MATRIX[metric]


@functools.partial(jax.jit, static_argnames=("metric", "block", "impl"))
def pairwise(
    X: jax.Array,
    Y: jax.Array,
    *,
    metric: str = "euclidean",
    block: int = 0,
    impl: str = "jnp",
) -> jax.Array:
    """Pairwise dissimilarity matrix.

    ``block > 0`` evaluates the matrix in row blocks of that size via
    ``lax.map`` to bound peak memory for the O(mnd) metrics (manhattan /
    chebyshev); the matmul-based metrics don't need it.
    ``impl='pallas'`` routes supported metrics through ``kernels/pdist``.
    """
    if impl == "pallas":
        from repro.kernels.pdist import ops as pdist_ops

        if metric in pdist_ops.SUPPORTED:
            return pdist_ops.pdist(X, Y, metric=metric)
        # kernel-unsupported metrics (jaccard, correlation) fall back to jnp
    fn = _MATRIX[metric]
    if block and X.shape[0] > block:
        m = X.shape[0]
        pad = (-m) % block
        Xp = jnp.pad(X, ((0, pad), (0, 0)))
        blocks = Xp.reshape(-1, block, X.shape[1])
        out = jax.lax.map(lambda xb: fn(xb, Y), blocks)
        return out.reshape(-1, Y.shape[0])[:m]
    return fn(X, Y)
