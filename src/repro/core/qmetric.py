"""Canonical q-metric projection  P*_q  (paper §3, Appendix E, Algs. 4-7).

The canonical projection maps an arbitrary symmetric dissimilarity matrix
``D`` onto the unique q-metric that satisfies the Axioms of Projection and
Transformation (Theorem 2): all-pairs shortest paths under the q-norm path
cost,

    d_q(x, y) = min_{paths c: x->y} || [d(c_0,c_1), ..., d(c_{l-1},c_l)] ||_q .

TPU adaptation (DESIGN.md §3.1)
-------------------------------
The paper's Algorithms 4/5 are pivot-sequential Floyd-Warshall sweeps: an
O(n)-long dependency chain of rank-1 relaxations that is latency-bound on a
systolic machine.  We reformulate the projection as **path doubling over the
(min, +) semiring in the q-power domain**:

    M_{t+1} = min(M_t, M_t (*) M_t),      (A (*) B)[ij] = min_k A[ik] + B[kj]

After ceil(log2(n-1)) sweeps M has converged to the all-pairs q-shortest
paths; each sweep is a dense blocked semiring matmul executed either in pure
jnp (row-blocked) or by the Pallas kernel ``kernels/qpath``.

Numerics
--------
Finite q works in the **log-power domain** ``L = q * log d``: the powered path
sum ``a^q + b^q`` becomes ``logaddexp(La, Lb)`` which is overflow/underflow
safe for any q (q=32, q=64 included).  Distances are recovered as
``exp(L / q)``.  q = inf uses the minimax semiring directly on distances.
Masked (non-neighbor) entries are +inf and propagate correctly through both
semirings.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

INF = jnp.inf

__all__ = [
    "semiring_matmul",
    "canonical_projection",
    "sparse_canonical_projection",
    "project_with_queries",
    "floyd_warshall_reference",
    "is_q_metric",
    "q_violation",
    "to_log_domain",
    "from_log_domain",
]


# ---------------------------------------------------------------------------
# domain transforms
# ---------------------------------------------------------------------------

def to_log_domain(D: jax.Array, q: float) -> jax.Array:
    """``L = q * log D`` with D=0 -> -inf and D=inf -> +inf (exact in f32)."""
    return q * jnp.log(D)


def from_log_domain(L: jax.Array, q: float) -> jax.Array:
    return jnp.exp(L / q)


# ---------------------------------------------------------------------------
# semiring matmul: the single hot spot (Pallas kernel mirrors this)
# ---------------------------------------------------------------------------

def _combine(a: jax.Array, b: jax.Array, mode: str) -> jax.Array:
    """Edge-combine along a path: powered-sum (log domain) or max (q=inf)."""
    if mode == "logminplus":
        return jnp.logaddexp(a, b)
    if mode == "minplus":
        return a + b
    if mode == "minmax":
        return jnp.maximum(a, b)
    raise ValueError(f"unknown semiring mode {mode!r}")


@functools.partial(jax.jit, static_argnames=("mode", "row_block", "impl"))
def semiring_matmul(
    A: jax.Array,
    B: jax.Array,
    *,
    mode: str = "minmax",
    row_block: int = 32,
    impl: str = "jnp",
) -> jax.Array:
    """``C[i,j] = min_k combine(A[i,k], B[k,j])`` over the chosen semiring.

    mode = 'logminplus' : combine = logaddexp  (finite q, log-power domain)
    mode = 'minplus'    : combine = +          (finite q, power domain)
    mode = 'minmax'     : combine = max        (q = inf, distance domain)

    The jnp implementation evaluates in row blocks of ``row_block`` to keep
    the (bs, n, n) broadcast intermediate bounded.  ``impl='pallas'`` calls
    the blocked VMEM-tiled kernel.
    """
    if impl == "pallas":
        from repro.kernels.qpath import ops as qpath_ops

        return qpath_ops.qpath_matmul(A, B, mode=mode)

    n, k = A.shape
    k2, m = B.shape
    assert k == k2, (A.shape, B.shape)

    def one_block(Ab: jax.Array) -> jax.Array:
        # (bs, k, 1) combine (1, k, m) -> (bs, k, m) -> min over k
        c = _combine(Ab[:, :, None], B[None, :, :], mode)
        return jnp.min(c, axis=1)

    bs = max(1, min(row_block, n))
    pad = (-n) % bs
    Ap = jnp.pad(A, ((0, pad), (0, 0)), constant_values=INF)
    out = jax.lax.map(one_block, Ap.reshape(-1, bs, k))
    return out.reshape(-1, m)[:n]


# ---------------------------------------------------------------------------
# canonical projection (dense, Algorithms 4/5 re-scheduled as path doubling)
# ---------------------------------------------------------------------------

def _num_sweeps(n: int) -> int:
    """Path doubling: after t sweeps, optimal over paths of <= 2^t edges."""
    return max(1, math.ceil(math.log2(max(n - 1, 2))))


@functools.partial(
    jax.jit, static_argnames=("q", "num_sweeps", "row_block", "impl")
)
def canonical_projection(
    D: jax.Array,
    q: float,
    *,
    num_sweeps: Optional[int] = None,
    row_block: int = 32,
    impl: str = "jnp",
) -> jax.Array:
    """Dense canonical projection ``P*_q(D)`` (Algorithms 4 & 5).

    ``q`` may be any float >= 1 or ``math.inf``.  Returns distances in the
    original scale.  Fixed point of itself (Axiom A1) and q-triangle feasible
    (Lemma 1) — both property-tested.
    """
    n = D.shape[0]
    sweeps = _num_sweeps(n) if num_sweeps is None else num_sweeps

    if math.isinf(q):
        M = D

        def body(_, M):
            return jnp.minimum(
                M, semiring_matmul(M, M, mode="minmax", row_block=row_block, impl=impl)
            )

        M = jax.lax.fori_loop(0, sweeps, body, M)
        return M

    L = to_log_domain(D, q)

    def body(_, L):
        return jnp.minimum(
            L, semiring_matmul(L, L, mode="logminplus", row_block=row_block, impl=impl)
        )

    L = jax.lax.fori_loop(0, sweeps, body, L)
    return from_log_domain(L, q)


# ---------------------------------------------------------------------------
# sparse canonical projection (Algorithms 6/7: kNN-masked, l-hop truncated)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("q", "num_hops", "row_block", "impl", "schedule")
)
def sparse_canonical_projection(
    D: jax.Array,
    mask: jax.Array,
    q: float,
    *,
    num_hops: int = 8,
    row_block: int = 32,
    impl: str = "jnp",
    schedule: str = "bellman",
) -> jax.Array:
    """Sparse projection restricted to a neighborhood graph (Algs. 6/7).

    ``mask`` is a boolean (n, n) adjacency (symmetrized kNN graph).  Paths may
    only traverse masked edges; ``num_hops`` bounds the path length l exactly
    as the paper's early-stopped pivot loop does.  Unreachable pairs remain
    +inf (callers mask them out; the Phi trainer samples finite pairs only).

    schedule='bellman':  M_{t+1} = min(M_t, M_t (*) E) — paths of <= t+1
        edges after t sweeps, the paper's literal l semantics.
    schedule='doubling': M_{t+1} = min(M_t, M_t (*) M_t) — paths of <= 2^t
        edges after t sweeps; still confined to masked edges (a composition
        of allowed paths is an allowed path).  This is the TPU-preferred
        schedule (DESIGN.md §3.1) and the InfinityIndex default.
    """
    n = D.shape[0]
    eye = jnp.eye(n, dtype=bool)
    allowed = jnp.logical_or(mask, mask.T) | eye
    doubling = schedule == "doubling"

    if math.isinf(q):
        E = jnp.where(allowed, D, INF)
        M = E

        def body(_, M):
            rhs = M if doubling else E
            return jnp.minimum(
                M, semiring_matmul(M, rhs, mode="minmax", row_block=row_block, impl=impl)
            )

        return jax.lax.fori_loop(0, num_hops, body, M)

    E = jnp.where(allowed, to_log_domain(D, q), INF)
    M = E

    def body(_, M):
        rhs = M if doubling else E
        return jnp.minimum(
            M, semiring_matmul(M, rhs, mode="logminplus", row_block=row_block, impl=impl)
        )

    M = jax.lax.fori_loop(0, num_hops, body, M)
    return from_log_domain(M, q)


# ---------------------------------------------------------------------------
# query extension (Prop. 1 experiments): project H = (X u {x_o}, E)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("q", "row_block", "impl"))
def project_with_queries(
    D: jax.Array,
    dq_rows: jax.Array,
    q: float,
    *,
    row_block: int = 32,
    impl: str = "jnp",
) -> jax.Array:
    """Projected query->dataset distances ``E_q(x_o, x)`` for a batch of queries.

    ``D`` is the (n, n) dataset dissimilarity matrix, ``dq_rows`` the (B, n)
    query-to-dataset dissimilarities.  Rather than projecting B separate
    (n+1)x(n+1) graphs, we use the fact that a q-shortest path from x_o to x
    decomposes as (x_o -> z) edge + (z -> x) q-shortest *within X*, or the
    direct edge:

        E_q(x_o, x) = min( d(x_o,x),  min_z combine(d(x_o,z), D_q(z,x)) )

    which is exact because x_o has degree n and appears at most once on any
    simple shortest path (all edge weights positive).  One projection of D +
    one semiring matvec per query batch.
    """
    Dq = canonical_projection(D, q, row_block=row_block, impl=impl)
    if math.isinf(q):
        via = semiring_matmul(dq_rows, Dq, mode="minmax", row_block=row_block, impl=impl)
        return jnp.minimum(dq_rows, via)
    Lrows = to_log_domain(dq_rows, q)
    LD = to_log_domain(Dq, q)
    via = semiring_matmul(Lrows, LD, mode="logminplus", row_block=row_block, impl=impl)
    return from_log_domain(jnp.minimum(Lrows, via), q)


# ---------------------------------------------------------------------------
# reference implementation — the paper's literal pivot loop (oracle in tests)
# ---------------------------------------------------------------------------

def floyd_warshall_reference(D: jax.Array, q: float) -> jax.Array:
    """Literal Algorithm 4/5: sequential pivots (used as the test oracle)."""
    n = D.shape[0]
    if math.isinf(q):
        M = D

        def body(i, M):
            cand = jnp.maximum(M[:, i][:, None], M[i, :][None, :])
            return jnp.minimum(M, cand)

        return jax.lax.fori_loop(0, n, body, M)

    L = to_log_domain(D, q)

    def body(i, L):
        cand = jnp.logaddexp(L[:, i][:, None], L[i, :][None, :])
        return jnp.minimum(L, cand)

    L = jax.lax.fori_loop(0, n, body, L)
    return from_log_domain(L, q)


# ---------------------------------------------------------------------------
# q-triangle inequality diagnostics
# ---------------------------------------------------------------------------

def q_violation(D: jax.Array, q: float) -> jax.Array:
    """Max violation of the q-triangle inequality over all triples.

    0.0 (up to fp slack) iff D is a q-metric.  Works in the normalized power
    domain for finite q to stay in range.
    """
    if math.isinf(q):
        # d(x,y) <= max(d(x,z), d(z,y))
        bound = jnp.min(
            jnp.maximum(D[:, :, None], D[None, :, :].transpose(1, 0, 2)), axis=1
        )
        # bound[i,j] = min_z max(D[z,i], D[z,j]) ; exclude z in {i,j} is not
        # needed: z=i gives max(0, D[i,j]) = D[i,j] so bound <= D always.
        return jnp.max(D - bound)
    scale = jnp.max(jnp.where(jnp.isfinite(D), D, 0.0))
    Dn = D / jnp.maximum(scale, 1e-30)
    P = Dn**q
    bound = jnp.min(P[:, :, None] + P[None, :, :].transpose(1, 0, 2), axis=1)
    viol = jnp.max(P - bound)  # in normalized power domain
    return viol


def is_q_metric(D: jax.Array, q: float, *, tol: float = 1e-5) -> bool:
    return bool(q_violation(D, q) <= tol)
