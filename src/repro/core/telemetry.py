"""Search telemetry subsystem: counters, histograms, spans, trace export
(DESIGN.md §16).

One process-wide, dependency-free registry answering the question the flat
``stats()`` dict cannot: *which stage* of a query spent the comparisons and
the milliseconds.  In metric-space search the budget currency is distance
evaluations (the paper's App. F.1 accounting), so the registry is built
around labeled counters — ``comparisons_total{engine=...,stage=...,q=...}``
— next to log-spaced latency histograms and a bounded in-memory trace ring.

Three primitives:

* ``Counter`` / ``Gauge`` / ``Histogram`` — labeled metrics held in the
  module ``REGISTRY``.  Histograms use fixed log-spaced latency buckets
  (``LATENCY_BUCKETS_S``) so two runs' distributions are always mergeable.
  Use through the convenience entry points ``count`` / ``set_gauge`` /
  ``observe``, which are no-ops (one branch) while telemetry is disabled.
* ``span(name, **labels)`` — a context manager that times a stage, records
  the duration into the ``stage_seconds`` histogram (labeled
  ``stage=name``) and appends a Chrome ``trace_event`` to the trace ring.
  The span closes — histogram observed, trace event emitted, flagged
  ``error=True`` — even when the body raises, so exception paths never
  leak an open span.  ``emit_span`` records a stage whose duration was
  measured (or apportioned) by the caller — how the in-kernel beam stages,
  whose comparison counters exit the jitted program as extra scalar
  outputs, get flamegraph rows without host callbacks.
* the trace ring — a fixed-capacity ring of ``trace_event`` dicts,
  exported by ``dump_trace(path)`` as Chrome/Perfetto-loadable JSON.
  Overflow overwrites the oldest events (``dropped`` is reported), so
  sustained traffic holds memory flat.

Global switch: ``enable()`` / ``disable()`` (or env ``REPRO_TELEMETRY=1``).
Disabled, every entry point returns after a single flag branch — no locks,
no allocation — and instrumented code paths are behavior-identical
(bit-exact search ids) to an uninstrumented build: recording only observes
values the search already computed.

Exposition: ``metrics_text()`` renders the registry in Prometheus text
exposition format (``search_latency_bucket{le=...}``,
``comparisons_total{stage=...}``, ...); ``snapshot()`` returns the same
data as a nested dict (what ``SearchServer.stats()['telemetry']`` and the
``BENCH_*.json`` stamps embed).

Naming note: this module is ``repro.core.telemetry`` and nothing else —
``repro.core.metrics`` is the *dissimilarity* registry (euclidean, cosine,
...), an unrelated namespace.  Do not re-export either under the other's
name.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

__all__ = [
    "LATENCY_BUCKETS_S", "Counter", "Gauge", "Histogram", "Registry",
    "REGISTRY", "enabled", "enable", "disable", "reset",
    "count", "set_gauge", "observe", "span", "emit_span",
    "counter_series", "histogram_series", "counter_total",
    "snapshot", "summary", "metrics_text", "dump_trace",
    "trace_events", "set_trace_cap", "now_us", "q_label",
]

#: fixed log-spaced latency buckets (seconds): 100us .. 10s in a
#: 1-2.5-5 decade ladder, +Inf implied.  Fixed — never derived from data —
#: so histograms from any two runs/processes merge bucket-by-bucket.
LATENCY_BUCKETS_S = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)

_ENABLED = os.environ.get("REPRO_TELEMETRY", "") not in ("", "0", "false")
_LOCK = threading.RLock()
_T0 = time.perf_counter()  # trace timestamps are microseconds since import


def enabled() -> bool:
    return _ENABLED


def enable(on: bool = True) -> None:
    """Flip the global switch.  Enabling mid-run is safe: metrics simply
    start accumulating from here; nothing retroactive is synthesized."""
    global _ENABLED
    _ENABLED = bool(on)


def disable() -> None:
    enable(False)


def _label_key(labels: dict) -> tuple:
    """Canonical hashable identity of a label set (values stringified)."""
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


class Counter:
    """Monotonic labeled counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._vals: dict[tuple, float] = {}

    def inc(self, value: float = 1, **labels) -> None:
        if not _ENABLED:
            return
        key = _label_key(labels)
        with _LOCK:
            self._vals[key] = self._vals.get(key, 0) + value

    def series(self) -> list[tuple[dict, float]]:
        with _LOCK:
            return [(dict(k), v) for k, v in sorted(self._vals.items())]

    def total(self, **match) -> float:
        """Sum over every label set containing all of ``match``."""
        m = {k: str(v) for k, v in match.items()}
        with _LOCK:
            return sum(
                v for k, v in self._vals.items()
                if all(dict(k).get(mk) == mv for mk, mv in m.items())
            )

    def _reset(self) -> None:
        self._vals.clear()


class Gauge(Counter):
    """Labeled last-value gauge (same storage, set instead of add)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not _ENABLED:
            return
        with _LOCK:
            self._vals[_label_key(labels)] = value


class Histogram:
    """Labeled histogram over fixed bucket upper bounds (+Inf implied)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple = LATENCY_BUCKETS_S):
        self.name, self.help = name, help
        self.buckets = tuple(buckets)
        # per label set: [bucket counts ... , +Inf count], sum, count
        self._vals: dict[tuple, list] = {}

    def observe(self, value: float, **labels) -> None:
        if not _ENABLED:
            return
        key = _label_key(labels)
        with _LOCK:
            rec = self._vals.get(key)
            if rec is None:
                rec = self._vals[key] = [[0] * (len(self.buckets) + 1), 0.0, 0]
            counts, _, _ = rec
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            rec[1] += value
            rec[2] += 1

    def series(self) -> list[tuple[dict, dict]]:
        with _LOCK:
            return [
                (dict(k), {"buckets": list(rec[0]), "sum": rec[1],
                           "count": rec[2]})
                for k, rec in sorted(self._vals.items())
            ]

    def _reset(self) -> None:
        self._vals.clear()


class Registry:
    """Name -> metric, with get-or-create accessors (kind-checked)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        # lock-free fast path: dict reads are atomic in CPython, and a hit
        # of the right kind needs no mutation — this runs per count()/
        # observe() on the serving hot path
        m = self._metrics.get(name)
        if type(m) is cls:
            return m
        with _LOCK:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple = LATENCY_BUCKETS_S) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def metrics(self) -> dict:
        with _LOCK:
            return dict(self._metrics)

    def reset(self) -> None:
        # drop metrics entirely (not just their series): a reset registry
        # must be indistinguishable from a fresh one — names re-register on
        # the next write, and no call site caches metric objects
        with _LOCK:
            self._metrics.clear()


REGISTRY = Registry()


# ---------------------------------------------------------------------------
# trace ring (Chrome trace_event format, Perfetto-loadable)
# ---------------------------------------------------------------------------

class _TraceRing:
    def __init__(self, cap: int = 8192):
        self.cap = int(cap)
        self._buf: list[dict] = []
        self._pos = 0
        self.dropped = 0

    def append(self, ev: dict) -> None:
        with _LOCK:
            if len(self._buf) < self.cap:
                self._buf.append(ev)
            else:  # overwrite the oldest: memory stays flat under load
                self._buf[self._pos] = ev
                self._pos = (self._pos + 1) % self.cap
                self.dropped += 1

    def events(self) -> list[dict]:
        with _LOCK:
            return self._buf[self._pos:] + self._buf[: self._pos]

    def clear(self) -> None:
        with _LOCK:
            self._buf.clear()
            self._pos = 0
            self.dropped = 0


_TRACE = _TraceRing()


def set_trace_cap(cap: int) -> None:
    """Resize the trace ring (drops buffered events)."""
    global _TRACE
    with _LOCK:
        _TRACE = _TraceRing(cap)


def trace_events() -> list[dict]:
    return _TRACE.events()


def _now_us() -> float:
    return (time.perf_counter() - _T0) * 1e6


def now_us() -> float:
    """Current trace-clock timestamp (µs since import) — pass as
    ``emit_span(..., ts_us=...)`` to lay synthesized stages end to end."""
    return _now_us()


def _trace_event(name: str, ts_us: float, dur_us: float, args: dict) -> None:
    _TRACE.append({
        "name": name, "ph": "X", "ts": ts_us, "dur": dur_us,
        "pid": os.getpid(), "tid": threading.get_ident(),
        "args": args,
    })


# ---------------------------------------------------------------------------
# instrument entry points (all no-ops behind one branch while disabled)
# ---------------------------------------------------------------------------

def count(name: str, value: float = 1, help: str = "", **labels) -> None:
    if not _ENABLED:
        return
    REGISTRY.counter(name, help).inc(value, **labels)


def set_gauge(name: str, value: float, help: str = "", **labels) -> None:
    if not _ENABLED:
        return
    REGISTRY.gauge(name, help).set(value, **labels)


def observe(name: str, value: float, help: str = "", **labels) -> None:
    if not _ENABLED:
        return
    REGISTRY.histogram(name, help).observe(value, **labels)


class _NullSpan:
    """The disabled path: one shared object, no per-call allocation."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Plain-class context manager (no generator machinery: this sits on
    the per-query serving path, where the <5% overhead budget lives)."""

    __slots__ = ("name", "labels", "t0", "ts")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels

    def __enter__(self):
        self.t0 = time.perf_counter()
        self.ts = (self.t0 - _T0) * 1e6
        return self

    def __exit__(self, etype, exc, tb):
        # __exit__ IS the close-on-exception guarantee: the histogram
        # observation and the trace event land either way
        dur = time.perf_counter() - self.t0
        args = dict(self.labels)
        if etype is not None:
            args["error"] = True
        observe("stage_seconds", dur, stage=self.name, **self.labels)
        _trace_event(self.name, self.ts, dur * 1e6, args)
        return False


def span(name: str, **labels):
    """Time a stage: ``with telemetry.span("dispatch", engine="nsw"): ...``.

    Records the wall time into ``stage_seconds{stage=name, **labels}`` and
    appends one complete ('X') trace event; on exception the span still
    closes, with ``error: true`` in the event args."""
    if not _ENABLED:
        return _NULL_SPAN
    return _LiveSpan(name, labels)


def emit_span(name: str, dur_s: float, *, ts_us: Optional[float] = None,
              args: Optional[dict] = None, **labels) -> None:
    """Record an externally-timed stage (same sinks as ``span``).

    The jitted traversal stages are one fused dispatch — their comparison
    counters exit as extra scalar outputs, and the caller apportions the
    dispatch wall time across them (flagged ``estimated`` in the event
    args by the caller); this is how those stages get flamegraph rows
    without host callbacks inside compiled code."""
    if not _ENABLED:
        return
    observe("stage_seconds", dur_s, stage=name, **labels)
    ev_args = dict(labels)
    if args:
        ev_args.update(args)
    ts = ts_us if ts_us is not None else _now_us() - dur_s * 1e6
    _trace_event(name, ts, dur_s * 1e6, ev_args)


# ---------------------------------------------------------------------------
# read-side: series access, snapshot tree, Prometheus text, trace dump
# ---------------------------------------------------------------------------

def counter_series(name: str) -> list[tuple[dict, float]]:
    m = REGISTRY.metrics().get(name)
    return m.series() if isinstance(m, Counter) else []


def histogram_series(name: str) -> list[tuple[dict, dict]]:
    m = REGISTRY.metrics().get(name)
    return m.series() if isinstance(m, Histogram) else []


def counter_total(name: str, **match) -> float:
    m = REGISTRY.metrics().get(name)
    return m.total(**match) if isinstance(m, Counter) else 0.0


def snapshot() -> dict:
    """The registry as a nested dict tree (stats()/BENCH embedding)."""
    out: dict = {"enabled": _ENABLED, "counters": {}, "gauges": {},
                 "histograms": {}}
    for name, m in sorted(REGISTRY.metrics().items()):
        if isinstance(m, Histogram):
            out["histograms"][name] = {
                _label_str(_label_key(lbl)): rec for lbl, rec in m.series()
            }
        elif isinstance(m, Gauge):
            out["gauges"][name] = {
                _label_str(_label_key(lbl)): v for lbl, v in m.series()
            }
        elif isinstance(m, Counter):
            out["counters"][name] = {
                _label_str(_label_key(lbl)): v for lbl, v in m.series()
            }
    out["trace"] = {"events": len(_TRACE.events()),
                    "dropped": _TRACE.dropped, "cap": _TRACE.cap}
    return out


def summary() -> dict:
    """Compact snapshot for benchmark stamps: histogram bucket arrays are
    collapsed to count/sum/mean — the breakdown, not the full distribution."""
    snap = snapshot()
    hists = {}
    for name, series in snap["histograms"].items():
        hists[name] = {
            lbl: {"count": rec["count"], "sum": round(rec["sum"], 6),
                  "mean": round(rec["sum"] / rec["count"], 6)
                  if rec["count"] else 0.0}
            for lbl, rec in series.items()
        }
    return {"counters": snap["counters"], "gauges": snap["gauges"],
            "histograms": hists, "trace": snap["trace"]}


def _esc(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _fmt_labels(lbl: dict, extra: Optional[dict] = None) -> str:
    items = {**lbl, **(extra or {})}
    if not items:
        return ""
    inner = ",".join(f'{k}="{_esc(str(v))}"' for k, v in sorted(items.items()))
    return "{" + inner + "}"


def _fmt_val(v: float) -> str:
    return repr(int(v)) if float(v) == int(v) else repr(float(v))


def metrics_text() -> str:
    """Prometheus text exposition format (version 0.0.4) of the registry.

    Histograms expand to cumulative ``<name>_bucket{le=...}`` series plus
    ``<name>_sum`` / ``<name>_count``; counters/gauges render one line per
    label set.  Served by ``examples/serve_search.py --metrics-port``."""
    lines: list[str] = []
    for name, m in sorted(REGISTRY.metrics().items()):
        if m.help:
            lines.append(f"# HELP {name} {m.help}")
        lines.append(f"# TYPE {name} {m.kind}")
        if isinstance(m, Histogram):
            for lbl, rec in m.series():
                cum = 0
                for ub, c in zip(m.buckets, rec["buckets"]):
                    cum += c
                    lines.append(
                        f"{name}_bucket{_fmt_labels(lbl, {'le': repr(float(ub))})} {cum}"
                    )
                cum += rec["buckets"][-1]
                lines.append(
                    f"{name}_bucket{_fmt_labels(lbl, {'le': '+Inf'})} {cum}"
                )
                lines.append(f"{name}_sum{_fmt_labels(lbl)} {repr(rec['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(lbl)} {rec['count']}")
        else:
            for lbl, v in m.series():
                lines.append(f"{name}{_fmt_labels(lbl)} {_fmt_val(v)}")
    return "\n".join(lines) + "\n"


def dump_trace(path: str) -> str:
    """Write the trace ring as Chrome ``trace_event`` JSON — open it in
    Perfetto (ui.perfetto.dev) or chrome://tracing for the flamegraph."""
    payload = {
        "traceEvents": _TRACE.events(),
        "displayTimeUnit": "ms",
        "metadata": {"dropped_events": _TRACE.dropped,
                     "ring_capacity": _TRACE.cap},
    }
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def reset() -> None:
    """Zero every metric and clear the trace ring (tests / bench cells)."""
    REGISTRY.reset()
    _TRACE.clear()


def q_label(q) -> str:
    """Canonical string form of the q knob for labels ('inf', '2.0', ...)."""
    try:
        import math as _math

        return "inf" if _math.isinf(float(q)) else str(float(q))
    except (TypeError, ValueError):
        return str(q)
