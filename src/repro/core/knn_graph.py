"""Exact k-nearest-neighbor graphs (substrate for the sparse projection,
the NSW baseline and the GNN neighbor sampler).

The sparse canonical projection (Algs. 6/7) restricts q-shortest paths to a
kNN graph with k ~ log n (Groisman et al. 2022 guarantee for Euclidean data).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import scan as scan_lib


@functools.partial(jax.jit, static_argnames=("k", "metric", "block", "impl"))
def knn_graph(
    X: jax.Array,
    *,
    k: int,
    metric: str = "euclidean",
    block: int = 0,
    impl: str = "jnp",
) -> tuple[jax.Array, jax.Array]:
    """Exact kNN of every row of X within X (self excluded).

    Returns (indices (n, k) int32, distances (n, k) f32), ascending.
    Runs through the streaming ``core/scan`` engine: self-exclusion is an
    index mask inside the top-k merge, so neither the (n, n) matrix nor an
    (n, n) eye mask is ever materialized.
    """
    dists, idx = scan_lib.topk_scan(
        X, X, k=k, metric=metric, impl=impl, exclude_self=True,
        block=block or scan_lib.DEFAULT_BLOCK,
    )
    return idx, dists


def knn_mask(idx: jax.Array, n: int) -> jax.Array:
    """Boolean (n, n) adjacency from kNN indices, symmetrized by the caller
    inside ``sparse_canonical_projection`` (mask | mask.T)."""
    rows = jnp.arange(n)[:, None]
    mask = jnp.zeros((n, n), dtype=bool)
    return mask.at[rows, idx].set(True)
