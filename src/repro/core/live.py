"""Live index: mutation on top of any frozen engine (DESIGN.md §11).

The paper's pipeline is build-once (sample, project, fit Phi, freeze a VP
tree — Fig. 18); production corpora mutate.  ``LiveIndex`` makes every
registered engine mutable with the classic segment architecture:

* **frozen segment** — an immutable inner engine (any registry key) built
  over the generation's corpus.  Never touched by upserts.
* **delta buffer** — a fixed-capacity ``(cap, d)`` row buffer holding
  vectors inserted since the last compaction, searched by an exact
  ``core/scan.topk_scan`` over the occupied-and-alive slots (the ``valid``
  mask).  Exact original-metric scoring over a small buffer means inserts
  are visible to the very next query at full recall.
* **tombstone bitmap** — one alive/dead bit per addressable slot (frozen
  rows then delta slots).  Deletes flip a bit; nothing is rebuilt.

``search`` oversamples the frozen engine (k' >= k + frozen tombstones, so
deleted rows can never evict a live answer), re-scores the surviving frozen
candidates in the original metric, scans the delta, and merges the two
lists through ``core/scan.merge_topk`` — frozen slot ids are always lower
than delta slot ids and the frozen list is merged first, so the global
tie-to-lowest-index guarantee of the scan contract is preserved.

**Generation-swap compaction**: when the delta fills or the deleted
fraction crosses a threshold, a new frozen engine is built on host over the
compacted corpus (alive frozen rows, then alive delta rows, in insertion
order) and published atomically — searches in flight keep reading the old
generation object; the swap is a single reference assignment.  For the
``infinity`` engine two modes exist: ``full`` re-projects everything (a
from-scratch build — bit-identical to rebuilding on the compacted corpus),
``refresh`` reuses the frozen Phi, carrying the inductively-embedded delta
rows into the new VP tree without retraining (the paper's own
inductive-application argument: Phi extends to unseen points).

Addressing: slot ids are positional within a generation — frozen rows are
``0..n_frozen-1``, delta slot ``j`` is ``n_frozen + j``.  Only compaction
renumbers, and compaction happens only inside ``upsert`` (delta full, or
the deleted fraction past the threshold) or an explicit ``compact()`` —
``delete`` just flips tombstone bits, so held ids survive it.  ``compact()``
returns the old-slot -> new-slot remap (-1 = deleted), ``upsert`` remaps
the ids it returns through any swap it triggered, ``stats()['generation']``
tells a caller whether its ids are still current, and ``slot_to_logical()``
gives the live view's positions at any time (what recall harnesses compare
against).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter as filter_lib
from repro.core import index as index_lib
from repro.core import quant as quant_lib
from repro.core import scan as scan_lib
from repro.core import telemetry as telem
from repro.core.index import SearchResult


_pow2ceil = scan_lib.pow2ceil  # the shared width-bucketing discipline


@dataclasses.dataclass
class _Generation:
    """Everything one search touches, swapped as a unit at compaction.

    ``delta_X`` / ``tomb`` / ``fill`` mutate in place between compactions
    (writes land before the fill bump, so a concurrent reader never sees a
    half-written row); compaction builds a complete replacement and
    publishes it with one reference assignment.
    """

    frozen: Any  # inner Index over the generation corpus
    frozen_X: jax.Array  # (n_frozen, d) original vectors of the frozen rows
    delta_X: np.ndarray  # (cap, d) f32 host buffer, rows [0, fill) occupied
    delta_Z: Optional[np.ndarray]  # (cap, s) inductive Phi embeddings (infinity)
    tomb: np.ndarray  # (n_frozen + cap,) bool — the tombstone bitmap
    fill: int = 0
    gen_id: int = 0
    dead_count: int = 0  # running tombstone count: dead_total() is O(1)
    # device mirrors of the mutable state, rebuilt lazily after a mutation
    # so the hot query path never re-uploads an unchanged delta/bitmap
    _dev: Optional[tuple] = dataclasses.field(default=None, repr=False)

    @property
    def n_frozen(self) -> int:
        return int(self.frozen_X.shape[0])

    @property
    def n_slots(self) -> int:
        return self.n_frozen + self.fill

    def dead_frozen(self) -> int:
        return int(self.tomb[: self.n_frozen].sum())

    def dead_total(self) -> int:
        # the counter, not a bitmap scan: search() checks this per query
        return self.dead_count

    def invalidate(self) -> None:
        self._dev = None

    def device_view(self):
        """(delta_X_dev, tomb_frozen_dev, alive_delta_dev, dead_frozen,
        n_alive_delta), uploaded once per mutation instead of per query."""
        if self._dev is None:
            cap = self.delta_X.shape[0]
            alive_d = (np.arange(cap) < self.fill) & ~self.tomb[
                self.n_frozen : self.n_frozen + cap
            ]
            self._dev = (
                jnp.asarray(self.delta_X),
                jnp.asarray(self.tomb[: self.n_frozen]),
                jnp.asarray(alive_d),
                self.dead_frozen(),
                int(alive_d.sum()),
            )
        return self._dev


@functools.partial(jax.jit, static_argnames=("k", "kd", "kq", "metric"))
def _merge_frozen_delta(
    Q, fidx, frozen_X, tomb_f, delta_X, delta_valid, quant=None,
    *, k, kd, kq=0, metric
):
    """Mask + re-score frozen candidates, scan the delta, merge to top-k.

    ``fidx`` is the frozen engine's oversampled candidate list (its raw
    distances are NOT used).  Candidates whose tombstone bit is set become
    -1 and are re-scored away; the survivors are re-scored in the ORIGINAL
    metric via ``topk_candidates`` so the two lists are comparable for
    every engine (ivf_pq without rerank returns ADC scores; infinity
    returns reranked original-metric scores — re-scoring makes the merge
    metric uniform; like the two-stage rerank in F.5, this reporting
    re-score is not counted as search work).

    ``quant`` — (delta codes (cap, d) int8, scales) from the slot-aligned
    quant store — switches the delta scan to the quantized two-stage: int8
    first pass keeps ``kq`` slots, the exact f32 rerank over ``delta_X``
    keeps ``kd``; the merged answer stays in the original metric.
    """
    n_frozen = frozen_X.shape[0]
    alive = (fidx >= 0) & ~tomb_f[jnp.maximum(fidx, 0)]
    cand = jnp.where(alive, fidx, -1)
    fi, fd = jax.vmap(
        lambda q, c: scan_lib.topk_candidates(q, c, frozen_X, k=k, metric=metric)
    )(Q, cand)

    if quant is None:
        dd, dpos = scan_lib.topk_scan(
            Q, delta_X, k=kd, metric=metric, valid=delta_valid
        )
    else:
        dcodes, scales = quant
        _, dpos1 = scan_lib.topk_scan_quant(
            Q, dcodes, scales, k=kq, metric=metric, valid=delta_valid
        )
        dpos, dd = jax.vmap(
            lambda q, c: scan_lib.topk_candidates(
                q, c, delta_X, k=kd, metric=metric
            )
        )(Q, dpos1)
    di = jnp.where(dpos >= 0, n_frozen + dpos, -1).astype(jnp.int32)
    if kd < k:  # pad the delta list to the frozen list's width
        pad = k - kd
        dd = jnp.pad(dd, ((0, 0), (0, pad)), constant_values=jnp.inf)
        di = jnp.pad(di, ((0, 0), (0, pad)), constant_values=-1)

    # frozen first (lower slot ids) -> merge keeps ties at the lowest id
    mdist, midx = scan_lib.merge_topk(
        jnp.stack([fd, dd], axis=1), jnp.stack([fi, di], axis=1), k=k
    )
    return midx, mdist


@index_lib.register_index("live")
class LiveIndex:
    """Mutable wrapper over any frozen engine: upsert / delete / compact.

    cfg keys (``registry_build``): ``engine`` (inner registry key),
    ``engine_cfg`` (its one-mapping config, reused verbatim at every
    compaction so a compacted index equals a from-scratch build),
    ``delta_cap``, ``compact_deleted_frac``, ``auto_compact``,
    ``compact_mode`` ('full' | 'refresh'), plus ``budget`` as a search
    default.  The original dissimilarity for delta scans / re-scoring is
    read from ``engine_cfg['metric']`` (default 'euclidean') — the metric
    every inner engine scores in.
    """

    registry_name = "live"

    def __init__(
        self, gen: _Generation, *, engine: str, engine_cfg: dict, metric: str,
        delta_cap: int, compact_deleted_frac: float, auto_compact: bool,
        compact_mode: str, search_defaults: Optional[dict] = None,
    ):
        self._gen = gen
        self.engine = engine
        self.engine_cfg = dict(engine_cfg)
        self.metric = metric
        self.delta_cap = int(delta_cap)
        self.compact_deleted_frac = float(compact_deleted_frac)
        self.auto_compact = bool(auto_compact)
        self.compact_mode = compact_mode
        self.compactions = 0
        self.search_defaults = dict(search_defaults or {})
        self.attrs = None  # slot-aligned core/attrs store (attach_attrs)
        self.quant = None  # slot-aligned core/quant store (attach_quant)
        self.chaos = None  # core/chaos.FaultPlan (attach_chaos)

    # ------------------------------------------------------------------ attrs
    def attach_attrs(self, store) -> None:
        """Attach a ``core/attrs`` store, slot-aligned: frozen rows then the
        delta buffer's capacity.  Accepts a corpus-length store (registry
        build: extended with missing-sentinel delta slots) or a full
        slot-capacity store (snapshot restore)."""
        gen = self._gen
        cap = gen.n_frozen + self.delta_cap
        if store.n == gen.n_frozen:
            store = store.take(np.arange(gen.n_frozen), capacity=cap)
        elif store.n != cap:
            raise ValueError(
                f"attrs cover {store.n} rows; need the corpus ({gen.n_frozen}) "
                f"or full slot capacity ({cap})"
            )
        self.attrs = store
        self._attach_frozen_view(gen, store)

    def attach_quant(self, store) -> None:
        """Attach a ``core/quant`` store, slot-aligned like the attribute
        store: frozen rows then the delta buffer's capacity.  Accepts a
        corpus-length store (registry build: zero-padded to slot capacity,
        any already-present delta rows quantized in) or a full slot-capacity
        store (snapshot restore — delta codes already in place).  Upserted
        rows are quantized with the FROZEN generation's scales (the same
        inductive-application argument as Phi; compaction recomputes scales
        from the compacted corpus)."""
        gen = self._gen
        cap = gen.n_frozen + self.delta_cap
        if store.rows == gen.n_frozen:
            store = store.take(np.arange(gen.n_frozen), capacity=cap)
            if gen.fill:
                store.set_rows(gen.n_frozen, gen.delta_X[: gen.fill], gen.fill)
        elif store.rows != cap:
            raise ValueError(
                f"quant codes cover {store.rows} rows; need the corpus "
                f"({gen.n_frozen}) or full slot capacity ({cap})"
            )
        self.quant = store
        self._attach_frozen_quant(gen, store)

    def attach_chaos(self, plan) -> None:
        """Hold the fault plan; the live fault sites are ``search`` (entry),
        ``delta`` (upsert — injected overflow) and ``compact`` (fired just
        before the atomic publish: all rebuild work done, crash before the
        swap — the old generation must keep serving untouched)."""
        self.chaos = plan

    @staticmethod
    def _attach_frozen_quant(gen, store) -> None:
        """Give the frozen engine its own frozen-rows code view, so its
        internal scans run the quantized two-stage (engines without a
        quantized scan path — nsw, ivf_pq — hold the view unused)."""
        index_lib.attach_quant_store(
            gen.frozen, store.take(np.arange(gen.n_frozen))
        )

    @staticmethod
    def _attach_frozen_view(gen, store) -> None:
        """Give the frozen engine its own frozen-rows store view, so
        ``search`` can hand it the PREDICATE instead of a raw mask slice —
        the frozen engine then caches the compiled mask and its selectivity
        itself (no per-query device sync on the hot path).  The view's
        vocabulary snapshot stays correct across delta mutations: a label
        first seen in an upsert exists only in delta slots, so the frozen
        view encoding it to "matches nothing" is exactly right; compaction
        re-attaches a fresh view anyway."""
        index_lib.attach_store(
            gen.frozen, store.take(np.arange(gen.n_frozen))
        )

    # ------------------------------------------------------------------ build
    @classmethod
    def registry_build(cls, X, cfg: Optional[Mapping[str, Any]] = None) -> "LiveIndex":
        cfg = dict(cfg or {})
        engine = cfg.pop("engine", "brute")
        if engine == "live":
            raise TypeError("live: cannot wrap a live index in a live index")
        engine_cfg = cfg.pop("engine_cfg", None)
        kw = {
            k: cfg.pop(k)
            for k in ("delta_cap", "compact_deleted_frac", "auto_compact",
                      "compact_mode")
            if k in cfg
        }
        sdef = {k: cfg.pop(k) for k in ("budget",) if k in cfg}
        if engine_cfg is None:
            engine_cfg = cfg  # remaining keys configure the inner engine
        elif cfg:
            raise TypeError(
                f"live: pass inner-engine keys via engine_cfg OR inline, "
                f"not both: {sorted(cfg)}"
            )
        idx = cls.build(X, engine=engine, engine_cfg=engine_cfg, **kw)
        idx.search_defaults = sdef
        return idx

    @classmethod
    def build(
        cls, X, *, engine: str = "brute",
        engine_cfg: Optional[Mapping[str, Any]] = None, delta_cap: int = 1024,
        compact_deleted_frac: float = 0.25, auto_compact: bool = True,
        compact_mode: str = "full",
    ) -> "LiveIndex":
        if compact_mode not in ("full", "refresh"):
            raise ValueError(f"compact_mode must be 'full' or 'refresh': {compact_mode!r}")
        X = jnp.asarray(X, jnp.float32)
        if X.ndim != 2 or X.shape[0] < 1:
            raise ValueError(f"live: need a non-empty (n, d) corpus, got {X.shape}")
        engine_cfg = dict(engine_cfg or {})
        delta_cap = int(delta_cap)
        if delta_cap < 1:
            raise ValueError(f"delta_cap must be >= 1: {delta_cap}")
        # the original dissimilarity every inner engine scores in — for a
        # sharded wrapper it lives on the inner engine's cfg, one level down
        metric_cfg = engine_cfg
        if engine == "sharded":
            inner = engine_cfg.get("engine_cfg")
            if inner is None:  # sharded's inline form: leftover keys = inner cfg
                inner = {k: v for k, v in engine_cfg.items()
                         if k not in ("engine", "shards", "mesh")}
            metric_cfg = inner
            if delta_cap < int(engine_cfg.get("shards", 2)):
                raise ValueError(
                    "live over sharded: delta_cap must be >= the shard count "
                    "(compaction carries up to shards-1 remainder rows)"
                )
        frozen = index_lib.build(engine, X, engine_cfg)
        gen = _Generation(
            frozen=frozen,
            frozen_X=X,
            delta_X=np.zeros((delta_cap, X.shape[1]), np.float32),
            delta_Z=cls._fresh_delta_Z(frozen, delta_cap),
            tomb=np.zeros((X.shape[0] + delta_cap,), bool),
        )
        return cls(
            gen, engine=engine, engine_cfg=engine_cfg,
            metric=metric_cfg.get("metric", "euclidean"), delta_cap=delta_cap,
            compact_deleted_frac=compact_deleted_frac, auto_compact=auto_compact,
            compact_mode=compact_mode,
        )

    @staticmethod
    def _fresh_delta_Z(frozen, cap: int) -> Optional[np.ndarray]:
        """Infinity engines get a parallel buffer of inductive embeddings:
        Phi applies to unseen points (the paper's inductive argument), so new
        rows are embedded at upsert and carried into refresh compactions."""
        Z = getattr(frozen, "Z", None)
        if Z is None:
            return None
        return np.zeros((cap, Z.shape[1]), np.float32)

    # ---------------------------------------------------------------- mutate
    def upsert(self, X_new, ids=None, attrs=None) -> np.ndarray:
        """Insert rows (optionally replacing existing slots); returns the
        assigned slot ids.

        ``ids`` (same length as ``X_new``): existing slot ids to replace —
        each is tombstoned and its new vector appended (segment-architecture
        update = delete + insert; -1 entries mean plain insert).  When the
        delta cannot hold the batch, compaction runs mid-batch; already-
        assigned ids are remapped through the compaction remap, so the
        returned array is valid in the FINAL generation as a whole.

        ``attrs`` — ``{column: per-row values}`` for the inserted rows,
        written into the slot-aligned attribute store.  Columns left out
        (or the whole mapping, when a store exists) get the missing
        sentinel, so unattributed rows never match a filter.
        """
        X_new = np.asarray(X_new, np.float32)
        if X_new.ndim == 1:
            X_new = X_new[None]
        d = self._gen.delta_X.shape[1]
        if X_new.shape[1] != d:
            raise ValueError(f"upsert dim {X_new.shape[1]} != corpus dim {d}")
        if attrs and self.attrs is None:
            raise TypeError(
                "upsert got attrs but this index has no attribute store: "
                "build with an 'attrs' cfg mapping"
            )
        if self.chaos is not None:
            # injected buffer exhaustion: the whole upsert is rejected
            # BEFORE any tombstone or delta write, so a caller's retry
            # starts from unchanged state
            self.chaos.on_delta()
        if self.attrs is not None:
            # validate BEFORE the destructive steps below: a malformed
            # attrs mapping must not tombstone the replaced ids and must
            # not partially publish a chunked batch
            self.attrs.validate_rows(attrs, X_new.shape[0])
        if ids is not None:
            ids = np.asarray(ids, np.int64)
            if ids.shape[0] != X_new.shape[0]:
                raise ValueError("upsert: ids and X_new length mismatch")
            self.delete(ids[ids >= 0])
        out = np.empty((X_new.shape[0],), np.int64)
        done = 0
        while done < X_new.shape[0]:
            gen = self._gen
            room = self.delta_cap - gen.fill
            if room == 0:
                remap = self.compact()
                # rows inserted before the swap live on under new slot ids
                # (they were just written, hence alive: remap is >= 0)
                out[:done] = remap[out[:done]]
                continue
            take = min(room, X_new.shape[0] - done)
            rows = X_new[done : done + take]
            gen.delta_X[gen.fill : gen.fill + take] = rows
            if gen.delta_Z is not None:
                from repro.core import embedding as embed_lib

                gen.delta_Z[gen.fill : gen.fill + take] = np.asarray(
                    embed_lib.apply(gen.frozen.phi_params, jnp.asarray(rows))
                )
            if self.attrs is not None:
                chunk = None if attrs is None else {
                    c: np.asarray(v)[done : done + take]
                    for c, v in dict(attrs).items()
                }
                self.attrs.set_rows(gen.n_frozen + gen.fill, chunk, take)
            if self.quant is not None:
                # quantize under the frozen scales — visible to the very
                # next query's delta code scan
                self.quant.set_rows(gen.n_frozen + gen.fill, rows, take)
            out[done : done + take] = gen.n_frozen + gen.fill + np.arange(take)
            gen.fill += take  # publish the rows only after they are written
            gen.invalidate()
            done += take
        remap = self._maybe_autocompact()
        if remap is not None:
            out = remap[out]
        return out

    def delete(self, ids) -> int:
        """Tombstone slot ids; returns how many were newly marked dead.
        Unknown / out-of-range ids raise — a delete that silently misses
        would leave phantom rows in the next compaction.

        Deletes NEVER renumber: they only flip tombstone bits, so slot ids
        a caller holds stay valid across any number of deletes.  A deleted
        fraction past the threshold is compacted at the next ``upsert`` (or
        explicit ``compact``) — the operations that already hand back
        remapped ids."""
        gen = self._gen
        ids = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
        if ids.size and ((ids < 0) | (ids >= gen.n_slots)).any():
            bad = ids[(ids < 0) | (ids >= gen.n_slots)]
            raise KeyError(f"delete: slot ids out of range: {bad[:8].tolist()}")
        newly = int((~gen.tomb[ids]).sum())
        gen.tomb[ids] = True
        gen.dead_count += newly
        gen.invalidate()
        return newly

    def _maybe_autocompact(self) -> Optional[np.ndarray]:
        """Compacts when the deleted fraction crosses the threshold;
        returns the remap when a swap happened (callers holding slot ids
        mid-operation translate them through it)."""
        gen = self._gen
        if not self.auto_compact:
            return None
        dead = gen.dead_total()
        # dead == n_slots: nothing alive to freeze — compaction would raise,
        # but the deletes themselves succeeded; wait for the next insert
        if gen.n_slots and dead < gen.n_slots and dead / gen.n_slots >= self.compact_deleted_frac:
            return self.compact()
        return None

    # --------------------------------------------------------------- compact
    def compact(self, mode: Optional[str] = None) -> np.ndarray:
        """Generation swap: rebuild the frozen engine over the compacted
        corpus and publish it atomically.  Returns the old-slot -> new-slot
        remap (-1 = deleted).

        ``full`` rebuilds through the registry with the original
        ``engine_cfg`` — byte-for-byte the engine a from-scratch build on
        the compacted corpus would produce (seeds live in the cfg).
        ``refresh`` (infinity only; falls back to full elsewhere) keeps the
        frozen Phi: alive frozen embeddings and the inductively-embedded
        delta rows are concatenated and only the VP tree is rebuilt — no
        retraining, the paper's inductive application.

        A ``sharded`` inner engine needs its corpus divisible by the shard
        count: the trailing ``n % shards`` rows are carried into the new
        generation's delta buffer instead of the frozen segment (their slot
        ids are unchanged by the carry — delta slots start at the new
        ``n_frozen``), so compaction never pads with phantom rows and never
        fails on an uneven count.
        """
        with telem.span("compaction", engine=self.engine,
                        mode=mode or self.compact_mode):
            return self._compact_impl(mode)

    def _compact_impl(self, mode: Optional[str]) -> np.ndarray:
        gen = self._gen
        mode = mode or self.compact_mode
        fill = gen.fill  # snapshot: rows appended during the rebuild would
        # belong to the NEXT generation; bounding the copy here keeps the
        # remap consistent with what this compaction actually absorbed
        alive_f = ~gen.tomb[: gen.n_frozen]
        alive_d = ~gen.tomb[gen.n_frozen : gen.n_frozen + fill]
        Xf = np.asarray(gen.frozen_X)
        corpus = np.concatenate([Xf[alive_f], gen.delta_X[:fill][alive_d]], axis=0)
        if corpus.shape[0] < 1:
            raise ValueError("compact: every row is tombstoned; nothing to build on")
        carry = 0
        if self.engine == "sharded":
            shards = int(self.engine_cfg.get("shards", 2))
            carry = corpus.shape[0] % shards
            if corpus.shape[0] - carry < shards:
                raise ValueError(
                    f"compact: {corpus.shape[0]} alive rows cannot fill "
                    f"{shards} shards"
                )
        frozen_part = corpus[: corpus.shape[0] - carry]

        if mode == "refresh" and gen.delta_Z is not None:
            frozen = self._refresh_frozen(gen, alive_f, alive_d, frozen_part, fill)
        else:
            frozen = index_lib.build(
                self.engine, jnp.asarray(frozen_part), self.engine_cfg
            )

        remap = np.full((gen.n_slots,), -1, np.int64)
        alive = np.concatenate([alive_f, alive_d])
        remap[alive] = np.arange(int(alive.sum()))

        # realign the side stores into LOCALS: nothing on self mutates until
        # the single publish below, so a compaction that dies at any point —
        # including an injected ``compact``-site fault — leaves the serving
        # generation AND its slot-aligned stores untouched (DESIGN.md §14)
        new_attrs = new_quant = None
        if self.attrs is not None:
            # alive order == compacted corpus order == new slot order (the
            # carry rows land in delta slots whose ids equal their corpus
            # positions), so one gather realigns the store
            new_attrs = self.attrs.take(
                np.where(alive)[0],
                capacity=frozen_part.shape[0] + self.delta_cap,
            )
            index_lib.attach_store(
                frozen, new_attrs.take(np.arange(frozen_part.shape[0]))
            )
        if self.quant is not None:
            # re-quantize from the compacted corpus (fresh scales — what a
            # from-scratch quantized build would compute), padded back out
            # to the new generation's slot capacity; carry rows sit in
            # delta slots whose positions equal their corpus order
            new_quant = quant_lib.QuantStore.build(corpus).take(
                np.arange(corpus.shape[0]),
                capacity=frozen_part.shape[0] + self.delta_cap,
            )
            index_lib.attach_quant_store(
                frozen, new_quant.take(np.arange(frozen_part.shape[0]))
            )

        new_gen = _Generation(
            frozen=frozen,
            frozen_X=jnp.asarray(frozen_part),
            delta_X=np.zeros((self.delta_cap, corpus.shape[1]), np.float32),
            delta_Z=self._fresh_delta_Z(frozen, self.delta_cap),
            tomb=np.zeros((frozen_part.shape[0] + self.delta_cap,), bool),
            gen_id=gen.gen_id + 1,
        )
        if carry:  # carried rows land in delta slots 0..carry-1, whose slot
            # ids equal their corpus positions — the remap stays positional
            new_gen.delta_X[:carry] = corpus[corpus.shape[0] - carry :]
            new_gen.fill = carry
        if self.chaos is not None:
            # the worst-case crash point: every rebuild cost paid, nothing
            # published — searches in flight and after must keep answering
            # from the old generation bit-identically, and no remap escapes
            self.chaos.on_compact()
        # the atomic publish: generation and realigned stores swap together
        self._gen = new_gen
        if new_attrs is not None:
            self.attrs = new_attrs
        if new_quant is not None:
            self.quant = new_quant
        self.compactions += 1
        telem.count("compactions_total", engine=self.engine)
        return remap

    def _refresh_frozen(self, gen, alive_f, alive_d, corpus, fill):
        """Infinity refresh: carry embeddings instead of retraining Phi."""
        old = gen.frozen
        Z = np.concatenate(
            [np.asarray(old.Z)[alive_f], gen.delta_Z[:fill][alive_d]], axis=0
        )
        return old.refresh(jnp.asarray(corpus), Z=jnp.asarray(Z))

    # ---------------------------------------------------------------- search
    def search(self, Q, k: int = 1, *, budget: Optional[int] = None,
               filter=None) -> SearchResult:
        gen = self._gen  # one read: searches never straddle a generation swap
        if self.chaos is not None:
            self.chaos.on_search()
        budget = index_lib.resolve(budget, self.search_defaults, "budget")
        filter = index_lib.resolve(filter, self.search_defaults, "filter")
        Q = jnp.asarray(Q, jnp.float32)
        k = int(k)
        # slot-aligned mask over the full capacity; composition order is
        # filter ∧ tombstone (∧ the inner engine's own validity) — the
        # tombstone/alive AND happens below, per segment (DESIGN.md §12)
        cap = gen.n_frozen + self.delta_cap
        if isinstance(filter, (np.ndarray, jnp.ndarray)) and \
                filter.shape[0] == gen.n_slots and gen.n_slots < cap:
            # raw masks naturally come slot-count sized; pad the unoccupied
            # delta slots False (they hold no row to pass)
            filter = jnp.concatenate(
                [jnp.asarray(filter, bool),
                 jnp.zeros((cap - gen.n_slots,), bool)]
            )
        mask = filter_lib.resolve_mask(filter, self.attrs, cap)
        # frozen-segment filter: hand PREDICATES down as-is (the frozen
        # engine resolves them against its own store view — compiled mask
        # and selectivity cache there, no per-query slicing or sync); raw
        # masks slice positionally
        if mask is None:
            f_filter = None
        elif not isinstance(filter, (np.ndarray, jnp.ndarray)) and \
                getattr(gen.frozen, "attrs", None) is not None:
            f_filter = filter
        else:
            f_filter = mask[: gen.n_frozen]
        if gen.fill == 0 and gen.dead_total() == 0:
            # clean generation: the live wrapper is transparent, so a
            # compacted index answers bit-identically to its frozen engine
            telem.count("live_scan_total", engine=self.engine,
                        segment="frozen")
            with telem.span("frozen_scan", engine=self.engine, clean=True):
                return gen.frozen.search(Q, k=k, budget=budget,
                                         filter=f_filter)

        delta_X, tomb_f, alive_d, dead_frozen, n_alive_d = gen.device_view()
        # oversample: every frozen tombstone can evict at most one live
        # answer, so k' >= k + dead_frozen keeps exhaustive engines exact.
        # Rounding k' up to a power of two bounds recompilation to
        # O(log n_frozen) distinct widths as deletes accumulate.
        kf = min(gen.n_frozen, _pow2ceil(k + dead_frozen))
        telem.count("live_scan_total", engine=self.engine, segment="frozen")
        with telem.span("frozen_scan", engine=self.engine, oversample=kf):
            fres = gen.frozen.search(Q, k=kf, budget=budget, filter=f_filter)
            if telem.enabled():
                jax.block_until_ready(fres.comparisons)

        kd = min(k, self.delta_cap)
        delta_valid = alive_d if mask is None else (
            alive_d & mask[gen.n_frozen :]
        )
        quant = kq = None
        if self.quant is not None:
            # the delta region of the slot-aligned code buffer: int8 first
            # pass keeps kq slots, the exact f32 rerank keeps kd
            codes, scales, _ = self.quant.device_view()
            quant = (codes[gen.n_frozen :], scales)
            kq = min(self.delta_cap, quant_lib.shortlist_width(kd, self.delta_cap))
        telem.count("live_scan_total", engine=self.engine, segment="delta")
        with telem.span("delta_scan", engine=self.engine, fill=gen.fill):
            midx, mdist = _merge_frozen_delta(
                Q, fres.idx, gen.frozen_X, tomb_f, delta_X, delta_valid, quant,
                k=k, kd=kd, kq=kq or 0, metric=self.metric,
            )
            if telem.enabled():
                jax.block_until_ready(midx)
        # frozen work as counted by the engine + one comparison per alive
        # (and passing, under a filter) delta row — the scan really scores
        # each of them (on codes when quantized, plus the kq exact rescores)
        if mask is None:
            comps = fres.comparisons + jnp.int32(n_alive_d)
        else:
            comps = fres.comparisons + jnp.sum(delta_valid).astype(jnp.int32)
        if kq:
            comps = comps + jnp.int32(kq)
        return SearchResult(midx, mdist, comps)

    # ------------------------------------------------------------ inspection
    def corpus(self) -> np.ndarray:
        """The live logical corpus: alive frozen rows then alive delta rows,
        in slot order — exactly what the next compaction will freeze."""
        gen = self._gen
        alive_f = ~gen.tomb[: gen.n_frozen]
        alive_d = ~gen.tomb[gen.n_frozen : gen.n_frozen + gen.fill]
        return np.concatenate(
            [np.asarray(gen.frozen_X)[alive_f], gen.delta_X[: gen.fill][alive_d]],
            axis=0,
        )

    def slot_to_logical(self) -> np.ndarray:
        """Slot id -> position in ``corpus()`` (-1 = tombstoned) — the map
        recall harnesses use to compare live answers against a rebuild."""
        gen = self._gen
        alive = ~gen.tomb[: gen.n_slots]
        out = np.full((gen.n_slots,), -1, np.int64)
        out[alive] = np.arange(int(alive.sum()))
        return out

    def stats(self) -> dict:
        """Segment composition — the operator's compaction-pressure gauge."""
        gen = self._gen
        return {
            "engine": self.engine,
            "generation": gen.gen_id,
            "frozen_size": gen.n_frozen,
            "delta_fill": gen.fill,
            "delta_cap": self.delta_cap,
            "tombstones": gen.dead_total(),
            "deleted_frac": gen.dead_total() / max(1, gen.n_slots),
            "n_alive": gen.n_slots - gen.dead_total(),
            "compactions": self.compactions,
            "attr_columns": list(self.attrs.columns()) if self.attrs else [],
            "quant_bytes": self.quant.memory_bytes() if self.quant else 0,
        }

    def memory_bytes(self) -> int:
        gen = self._gen
        # frozen_X is its own resident copy (post-compaction it is a
        # separate device array from whatever the engine holds; at initial
        # build it may alias — reported capacity, not aliasing)
        extra = index_lib.pytree_nbytes(gen.frozen_X)
        extra += gen.delta_X.nbytes + gen.tomb.nbytes
        if gen.delta_Z is not None:
            extra += gen.delta_Z.nbytes
        return gen.frozen.memory_bytes() + int(extra) + \
            index_lib.side_store_bytes(self)

    # --------------------------------------------------------------- snapshot
    def snapshot_state(self):
        from repro.core import store as store_lib

        gen = self._gen
        fa, fs = store_lib.engine_snapshot_state(gen.frozen)
        arrays = {
            "frozen": fa,
            "frozen_X": np.asarray(gen.frozen_X),
            "delta_X": gen.delta_X[: gen.fill],
            # the bitmap snapshots as actual bits (np.packbits)
            "tomb_bits": np.packbits(gen.tomb),
        }
        if gen.delta_Z is not None:
            arrays["delta_Z"] = gen.delta_Z[: gen.fill]
        statics = {
            "engine": self.engine,
            "engine_cfg": self.engine_cfg,
            "metric": self.metric,
            "delta_cap": self.delta_cap,
            "compact_deleted_frac": self.compact_deleted_frac,
            "auto_compact": self.auto_compact,
            "compact_mode": self.compact_mode,
            "compactions": self.compactions,
            "fill": gen.fill,
            "gen_id": gen.gen_id,
            "tomb_len": int(gen.tomb.shape[0]),
            "frozen_statics": fs,
            "search_defaults": self.search_defaults,
        }
        return arrays, statics

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "LiveIndex":
        from repro.core import store as store_lib

        engine = statics["engine"]
        frozen = store_lib.engine_from_snapshot(
            engine, arrays["frozen"], statics["frozen_statics"]
        )
        frozen_X = jnp.asarray(arrays["frozen_X"], jnp.float32)
        cap = int(statics["delta_cap"])
        fill = int(statics["fill"])
        delta_X = np.zeros((cap, frozen_X.shape[1]), np.float32)
        delta_X[:fill] = np.asarray(arrays["delta_X"], np.float32)
        delta_Z = cls._fresh_delta_Z(frozen, cap)
        if delta_Z is not None and "delta_Z" in arrays:
            delta_Z[:fill] = np.asarray(arrays["delta_Z"], np.float32)
        tomb = np.unpackbits(
            np.asarray(arrays["tomb_bits"], np.uint8), count=statics["tomb_len"]
        ).astype(bool)
        gen = _Generation(
            frozen=frozen, frozen_X=frozen_X, delta_X=delta_X, delta_Z=delta_Z,
            tomb=tomb, fill=fill, gen_id=int(statics["gen_id"]),
            dead_count=int(tomb.sum()),
        )
        idx = cls(
            gen, engine=engine, engine_cfg=dict(statics["engine_cfg"]),
            metric=statics["metric"], delta_cap=cap,
            compact_deleted_frac=statics["compact_deleted_frac"],
            auto_compact=statics["auto_compact"],
            compact_mode=statics["compact_mode"],
            search_defaults=dict(statics.get("search_defaults") or {}),
        )
        idx.compactions = int(statics.get("compactions", 0))
        return idx
