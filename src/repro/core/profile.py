"""Compiled-program roofline profiles (DESIGN.md §17).

Every hot search path in this repo is one jitted XLA program (the
static-shape discipline: server batch buckets, ``ShardedIndex`` shard
programs, the flattened beam traversal, the quantized scans).  This module
captures the *optimized* HLO of those programs — ``fn.lower(args)
.compile().as_text()`` — and runs it through the loop-aware
``dist/roofline`` accounting, so "N× faster" claims come with a flops /
HBM-bytes / arithmetic-intensity / %-of-roofline number instead of a wall
clock alone.

Two capture surfaces:

* ``capture_jit(name, fn, *args)`` — profile a jitted function directly
  (``core/scan.topk_scan``, a ``ShardedIndex._jitted`` entry, ...).
* ``capture_search(index, Q, ...)`` — wrap any registry engine's whole
  batched ``search`` in one ``jax.jit`` and profile that: the compiled
  program *is* the engine's serving dispatch for that (bucket, k) — the
  beam traversal, centroid ranking, int8 first pass and f32 rerank all
  inlined.  Telemetry is suspended during tracing (engine bodies sync
  comparison counts to host, which tracers cannot).

Predicted time is the per-chip three-term roofline (``max`` of compute /
HBM / collective, ``dist/roofline`` constants — a TPU v5p-class hardware
model; on the CPU CI backend the %-of-peak is honest about being tiny).
Measured time is the median post-warmup dispatch.  ``pct_of_peak`` =
predicted / measured: the fraction of the modeled hardware ceiling the
program actually achieves.

Captured profiles land in a process-wide registry (``profiles()``), as
telemetry gauges (``roofline_*{program=...}``) when telemetry is on, and
as the ``roofline`` block on BENCH_topk / BENCH_serving / BENCH_infinity
rows via ``as_row()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry as telem
from repro.dist import roofline


@dataclasses.dataclass
class ProgramProfile:
    """One compiled program's roofline accounting."""

    name: str
    labels: dict
    flops: float            # loop-aware dot flops (dist/roofline.hlo_stats)
    hbm_bytes: float        # loop-aware instruction-output bytes
    intensity: float        # flops / byte
    dot_count: int
    t_compute_s: float      # flops / PEAK_FLOPS
    t_memory_s: float       # bytes / HBM_BW
    t_collective_s: float   # collective bytes / ICI_BW
    t_predicted_s: float    # max of the three terms
    dominant: str           # which term bounds the program
    t_measured_s: Optional[float] = None
    pct_of_peak: Optional[float] = None  # predicted / measured

    def as_row(self) -> dict:
        """The JSON block bench rows carry."""
        out = {
            "program": self.name,
            "flops": float(self.flops),
            "hbm_bytes": float(self.hbm_bytes),
            "intensity": round(float(self.intensity), 4),
            "dot_count": int(self.dot_count),
            "t_predicted_s": float(self.t_predicted_s),
            "dominant": self.dominant,
        }
        if self.t_measured_s is not None:
            out["t_measured_s"] = float(self.t_measured_s)
            out["pct_of_peak"] = float(self.pct_of_peak)
        return out


#: process-wide capture registry: (name, sorted label items) -> profile
_PROGRAMS: dict = {}


def _key(name: str, labels: Optional[dict]):
    return (name, tuple(sorted((labels or {}).items())))


def reset() -> None:
    _PROGRAMS.clear()


def profiles(name: Optional[str] = None) -> list[ProgramProfile]:
    """Captured profiles, optionally filtered by program name."""
    return [p for p in _PROGRAMS.values() if name is None or p.name == name]


def export_gauges(prof: ProgramProfile) -> None:
    """Publish one profile as telemetry gauges (no-op when telemetry is
    off) — ``roofline_pct_of_peak`` is what the Prometheus exposition and
    the CI observability smoke assert on."""
    if not telem.enabled():
        return
    labels = {"program": prof.name, **{k: v for k, v in prof.labels.items()}}
    telem.set_gauge("roofline_flops", prof.flops, **labels)
    telem.set_gauge("roofline_hbm_bytes", prof.hbm_bytes, **labels)
    telem.set_gauge("roofline_intensity", prof.intensity, **labels)
    telem.set_gauge("roofline_predicted_s", prof.t_predicted_s, **labels)
    if prof.t_measured_s is not None:
        telem.set_gauge("roofline_measured_s", prof.t_measured_s, **labels)
        telem.set_gauge("roofline_pct_of_peak", prof.pct_of_peak, **labels)


def _measure(fn, args, kwargs, iters: int = 3) -> float:
    """Median post-warmup dispatch seconds (block_until_ready)."""
    jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def capture_jit(name: str, fn, *args, labels: Optional[dict] = None,
                measure: bool = True, measured_s: Optional[float] = None,
                force: bool = False, export: bool = True,
                **kwargs) -> ProgramProfile:
    """Profile one jitted function at these (static + array) arguments.

    Lowers and compiles via the AOT path, feeds the optimized HLO text to
    the loop-aware ``dist/roofline`` parsers, and (by default) times the
    live dispatch for the predicted-vs-measured pair.  Re-captures of the
    same (name, labels) return the cached profile unless ``force`` or a
    fresh ``measured_s`` is supplied."""
    key = _key(name, labels)
    cached = _PROGRAMS.get(key)
    if cached is not None and not force and measured_s is None:
        return cached
    was_on = telem.enabled()
    telem.disable()  # traced bodies must not sync counters to host
    try:
        lowered = fn.lower(*args, **kwargs)
        compiled = lowered.compile()
    finally:
        if was_on:
            telem.enable()
    hlo = compiled.as_text()
    stats = roofline.hlo_stats(hlo)
    coll = roofline.parse_collectives(hlo)
    t_compute = stats.flops / roofline.PEAK_FLOPS
    t_memory = stats.bytes / roofline.HBM_BW
    t_coll = coll.total_bytes / roofline.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_pred = max(terms.values())
    if measured_s is None and measure:
        measured_s = _measure(fn, args, kwargs)
    prof = ProgramProfile(
        name=name, labels=dict(labels or {}),
        flops=stats.flops, hbm_bytes=stats.bytes,
        intensity=stats.flops / max(stats.bytes, 1.0),
        dot_count=stats.dot_count,
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        t_predicted_s=t_pred, dominant=dominant,
        t_measured_s=measured_s,
        pct_of_peak=(t_pred / measured_s) if measured_s else None,
    )
    _PROGRAMS[key] = prof
    if export:
        export_gauges(prof)
    return prof


def capture_search(index, Q, *, k: int = 10, budget: Optional[int] = None,
                   filter=None, engine: Optional[str] = None,
                   labels: Optional[dict] = None, measure: bool = True,
                   force: bool = False, **search_kw) -> ProgramProfile:
    """Profile a registry engine's whole batched search as ONE program.

    ``jax.jit`` around ``index.search`` traces the engine's entire
    dispatch — for a sharded index that includes the shard_map programs,
    for infinity the beam traversal + rerank, for quantized engines the
    int8 scan — so the profile covers exactly what a serving bucket pays.
    Telemetry is suspended while tracing (engines sync comparison counts
    to host inside ``search``; a tracer cannot be synced) and the gauges
    are exported afterwards."""
    eng = engine or getattr(index, "registry_name", type(index).__name__)
    Qj = jnp.asarray(Q, jnp.float32)
    lbl = {"engine": eng, "batch": int(Qj.shape[0]), "k": int(k),
           **(labels or {})}
    key = _key(f"search:{eng}", lbl)
    cached = _PROGRAMS.get(key)
    if cached is not None and not force:
        return cached

    def run(Qb):
        r = index.search(Qb, k=k, budget=budget, filter=filter, **search_kw)
        return r[0], r[1], r[2]

    fn = jax.jit(run)
    was_on = telem.enabled()
    telem.disable()
    try:
        prof = capture_jit(
            f"search:{eng}", fn, Qj, labels=lbl, measure=measure,
            force=force, export=False,
        )
    finally:
        if was_on:
            telem.enable()
    export_gauges(prof)
    return prof
