"""Vantage-point trees for q-metric / infinity-metric search (paper App. C/D).

Build (host, numpy)
-------------------
``build_vptree`` follows Algorithm 1 literally: random (or max-spread)
vantage, radius = median of distances, ties assigned to the OUTSIDE set
(paper (5)/(16)).  The tree is stored as flat arrays — ``vantage[i]`` is the
dataset index of node i's vantage point, ``mu[i]`` its radius, ``left/right``
child node ids (-1 = none) — so the search phase is pure gather arithmetic.

Search (device, JAX) — DESIGN.md §3.2
-------------------------------------
* ``descend_infty``: the Theorem-1 path.  In an infinity-metric space the
  prune conditions (inf-CI)/(inf-CO) are complementary, so each query visits
  exactly one node per level; the whole batch advances in lockstep with one
  gather + one batched distance per level (fori_loop over depth).  Total
  comparisons per query = root-to-leaf path length <= tree depth.
* ``search_best_first``: Algorithm 2 (finite q) with its backtracking
  semantics — a while_loop with an explicit fixed-capacity DFS stack, a
  top-k result buffer and a ``max_comparisons`` budget.  Budget >= n
  reproduces the exact search; smaller budgets give the approximate
  speed/recall trade-off swept in the benchmarks.
* ``search_beam``: level-synchronous beam traversal over a FLATTENED tree
  (``flatten_vptree``: level-order internal nodes + contiguous leaf buckets
  of ``leaf_size`` points, corpus rows re-laid-out bucket-major).  Per
  level the whole (B queries x W beam) frontier of vantage distances is one
  batched distance computation, the q-CI/q-CO prune rules run vectorized
  as child lower bounds against the running tau, and the top-W children
  per query survive; reached leaf buckets accumulate into a fixed-capacity
  buffer and are scanned with the ``core/scan`` running-merge discipline —
  the whole search is ONE jitted dispatch per query batch (DESIGN.md §15).

Both searches accept either raw vectors (distances evaluated on the fly with
any registered metric) or precomputed query->dataset distance rows (used for
the canonical-projection experiments where d_q(x_o, x) comes from
``project_with_queries``).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as metrics_lib

INF = jnp.inf


class VPTree(NamedTuple):
    """Flat array representation of a VP tree (device-friendly)."""

    vantage: jax.Array  # (num_nodes,) int32 — dataset index of vantage point
    mu: jax.Array  # (num_nodes,) float32 — node radius
    left: jax.Array  # (num_nodes,) int32 — inside child node id or -1
    right: jax.Array  # (num_nodes,) int32 — outside child node id or -1
    depth: int  # static python int

    @property
    def num_nodes(self) -> int:
        return int(self.vantage.shape[0])


# ---------------------------------------------------------------------------
# host-side numpy distance rows (build-time only)
# ---------------------------------------------------------------------------

def _np_dist_rows(X: np.ndarray, i: int, idxs: np.ndarray, metric: str) -> np.ndarray:
    x = X[i]
    Y = X[idxs]
    if metric == "euclidean":
        return np.sqrt(np.maximum(((Y - x) ** 2).sum(-1), 0.0))
    if metric == "sqeuclidean":
        return ((Y - x) ** 2).sum(-1)
    if metric == "manhattan":
        return np.abs(Y - x).sum(-1)
    if metric == "chebyshev":
        return np.abs(Y - x).max(-1)
    if metric == "cosine":
        nx = max(float(np.linalg.norm(x)), 1e-12)
        ny = np.maximum(np.linalg.norm(Y, axis=-1), 1e-12)
        return 1.0 - (Y @ x) / (ny * nx)
    if metric == "correlation":
        xc = x - x.mean()
        Yc = Y - Y.mean(-1, keepdims=True)
        nx = max(float(np.linalg.norm(xc)), 1e-12)
        ny = np.maximum(np.linalg.norm(Yc, axis=-1), 1e-12)
        return 1.0 - (Yc @ xc) / (ny * nx)
    if metric == "jaccard":
        xb = x > 0
        Yb = Y > 0
        inter = (Yb & xb).sum(-1)
        union = (Yb | xb).sum(-1)
        return 1.0 - inter / np.maximum(union, 1)
    if metric == "dot":
        return -(Y @ x)
    raise KeyError(metric)


# ---------------------------------------------------------------------------
# build (Algorithm 1)
# ---------------------------------------------------------------------------

def build_vptree(
    X: Optional[np.ndarray] = None,
    *,
    D: Optional[np.ndarray] = None,
    metric: str = "euclidean",
    seed: int = 0,
    select: str = "random",
) -> VPTree:
    """Recursive median-split construction (Algorithm 1).

    Either ``X`` (vectors + metric) or ``D`` (precomputed (n, n) dissimilarity
    matrix, e.g. a canonical projection) must be given.  ``select='spread'``
    uses the Yianilos variance heuristic over a distance sample (Remark 2).
    """
    if (X is None) == (D is None):
        raise ValueError("exactly one of X / D must be provided")
    n = (X.shape[0] if X is not None else D.shape[0])
    if n == 0:
        raise ValueError("empty dataset")
    rng = np.random.default_rng(seed)

    def dist_rows(i: int, idxs: np.ndarray) -> np.ndarray:
        if D is not None:
            return np.asarray(D)[i, idxs]
        return _np_dist_rows(np.asarray(X), i, idxs, metric)

    vantage: list[int] = []
    mu: list[float] = []
    left: list[int] = []
    right: list[int] = []

    def new_node() -> int:
        vantage.append(-1)
        mu.append(0.0)
        left.append(-1)
        right.append(-1)
        return len(vantage) - 1

    max_depth = 0

    # Iterative DFS to avoid Python recursion limits on unbalanced trees.
    root = new_node()
    stack: list[tuple[int, np.ndarray, int]] = [(root, np.arange(n), 0)]
    while stack:
        node, idxs, d_level = stack.pop()
        max_depth = max(max_depth, d_level)
        if select == "spread" and len(idxs) > 2:
            cand = idxs[rng.choice(len(idxs), size=min(8, len(idxs)), replace=False)]
            probe = idxs[rng.choice(len(idxs), size=min(32, len(idxs)), replace=False)]
            spreads = [float(np.var(dist_rows(int(c), probe))) for c in cand]
            v = int(cand[int(np.argmax(spreads))])
        else:
            v = int(idxs[rng.integers(len(idxs))])
        rest = idxs[idxs != v]
        vantage[node] = v
        if rest.size == 0:
            continue
        dists = dist_rows(v, rest)
        m = float(np.median(dists))
        mu[node] = m
        inside = rest[dists < m]
        outside = rest[dists >= m]  # ties -> outside (paper (5))
        if inside.size:
            c = new_node()
            left[node] = c
            stack.append((c, inside, d_level + 1))
        if outside.size:
            c = new_node()
            right[node] = c
            stack.append((c, outside, d_level + 1))

    return VPTree(
        vantage=jnp.asarray(vantage, jnp.int32),
        mu=jnp.asarray(mu, jnp.float32),
        left=jnp.asarray(left, jnp.int32),
        right=jnp.asarray(right, jnp.int32),
        depth=max_depth + 1,
    )


# ---------------------------------------------------------------------------
# distance evaluation during search
# ---------------------------------------------------------------------------

def _make_dist(X: Optional[jax.Array], metric: str):
    """Returns f(q_repr, j) -> distance.

    If ``X`` is given, ``q_repr`` is a query vector; otherwise ``q_repr`` is a
    precomputed (n,) row of query->dataset dissimilarities and the evaluation
    is a single gather (canonical-projection search mode).
    """
    if X is None:
        def f(q_row: jax.Array, j: jax.Array) -> jax.Array:
            return q_row[j]
        return f
    pair = metrics_lib.pair_fn(metric)

    def f(q_vec: jax.Array, j: jax.Array) -> jax.Array:
        return pair(q_vec, X[j])

    return f


# ---------------------------------------------------------------------------
# infinity-metric descent (Theorem 1)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "depth"))
def _descend_impl(tree_arrays, X, queries, metric: str, depth: int):
    vantage, mu, left, right = tree_arrays
    dist = _make_dist(X, metric)

    def per_query(qr):
        def body(_, st):
            node, best_d, best_i, comps = st
            valid = node >= 0
            j = vantage[jnp.maximum(node, 0)]
            d = dist(qr, j)
            better = valid & (d < best_d)
            best_d = jnp.where(better, d, best_d)
            best_i = jnp.where(better, j, best_i)
            comps = comps + valid.astype(jnp.int32)
            go_left = d < mu[jnp.maximum(node, 0)]
            nxt = jnp.where(go_left, left[jnp.maximum(node, 0)], right[jnp.maximum(node, 0)])
            node = jnp.where(valid, nxt, node)
            return node, best_d, best_i, comps

        init = (jnp.int32(0), jnp.float32(INF), jnp.int32(-1), jnp.int32(0))
        _, bd, bi, c = jax.lax.fori_loop(0, depth, body, init)
        return bi, bd, c

    return jax.vmap(per_query)(queries)


def descend_infty(
    tree: VPTree,
    queries: jax.Array,
    *,
    X: Optional[jax.Array] = None,
    metric: str = "euclidean",
):
    """Single-path descent (Algorithm 3 / Theorem 1).

    ``queries`` is (B, d) vectors when ``X`` is given, else (B, n) precomputed
    distance rows.  Returns (best_idx (B,), best_dist (B,), comparisons (B,)).
    Comparisons <= tree depth by construction.
    """
    return _descend_impl(
        (tree.vantage, tree.mu, tree.left, tree.right), X, queries, metric, tree.depth
    )


# ---------------------------------------------------------------------------
# finite-q best-first search (Algorithm 2) with comparison budget
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("metric", "q", "k", "stack_cap")
)
def _best_first_impl(
    tree_arrays, X, queries, max_comparisons, metric: str, q: float, k: int,
    stack_cap: int, valid=None,
):
    # ``max_comparisons`` is a TRACED int32 scalar: it only gates the
    # while_loop condition, so different budgets (notably the per-shard
    # remainder split in core/index) share one compiled program.
    # ``valid`` (n,) bool masks ACCEPTANCE only (filtered search): every
    # vantage distance is still evaluated — navigation and pruning need it
    # — and still counts against the budget, but non-passing points never
    # enter the top-k buffer.  tau then upper-bounds the k-th best PASSING
    # distance, which is >= the unfiltered tau, so pruning only weakens:
    # conservative, never wrong (the subset argument of DESIGN.md §12).
    vantage, mu, left, right = tree_arrays
    dist = _make_dist(X, metric)
    q_inf = math.isinf(q)

    def per_query(qr):
        def cond(st):
            stack, sp, kd, ki, comps, trunc = st
            return (sp > 0) & (comps < max_comparisons)

        def body(st):
            stack, sp, kd, ki, comps, trunc = st
            node = stack[sp - 1]
            sp = sp - 1
            j = vantage[node]
            d = dist(qr, j)
            comps = comps + 1
            # top-k insert (k is small; argsort of k+1 elements); filtered-
            # out vantages insert as (+inf, -1) — a no-op slot
            if valid is None:
                ins_d, ins_i = d, j
            else:
                ok = valid[j]
                ins_d = jnp.where(ok, d, INF)
                ins_i = jnp.where(ok, j, -1)
            cd = jnp.concatenate([kd, ins_d[None]])
            ci = jnp.concatenate([ki, ins_i[None]])
            order = jnp.argsort(cd)
            kd = cd[order][:k]
            ki = ci[order][:k]
            tau = kd[k - 1]

            m = mu[node]
            lc, rc = left[node], right[node]
            if q_inf:
                # (inf-CI)/(inf-CO): complementary once tau <= d holds.
                prune_out = jnp.maximum(d, tau) < m
                prune_in = jnp.maximum(m, tau) <= d
            else:
                # powered conditions in a normalized domain: overflow-safe and
                # conservative (underflow can only disable pruning, never
                # prune a branch that may hold the NN).
                s = jnp.maximum(jnp.maximum(d, m), jnp.where(jnp.isfinite(tau), tau, 0.0))
                s = jnp.maximum(s, 1e-30)
                dq = (d / s) ** q
                mq = (m / s) ** q
                tq = jnp.where(jnp.isfinite(tau), (tau / s) ** q, INF)
                prune_out = dq + tq < mq  # (q-CI): only inside can hold NN
                prune_in = mq + tq <= dq  # (q-CO): only outside can hold NN

            # DFS order: push the deferred far child first, near child last.
            push_left = (lc >= 0) & ~prune_in
            push_right = (rc >= 0) & ~prune_out
            near_left = d < m  # visit the side containing the query first
            first = jnp.where(near_left, rc, lc)      # deferred
            first_ok = jnp.where(near_left, push_right, push_left)
            second = jnp.where(near_left, lc, rc)     # visited next
            second_ok = jnp.where(near_left, push_left, push_right)

            # guarded pushes: ``.at[sp].set`` CLAMPS an out-of-bounds sp
            # under jit, which would silently overwrite the top stack slot
            # and corrupt the DFS frontier.  A push past the cap is dropped
            # instead and surfaced through the ``truncated`` flag.
            room1 = sp < stack_cap
            do1 = first_ok & room1
            stack = jnp.where(do1, stack.at[sp].set(first), stack)
            sp = sp + do1.astype(jnp.int32)
            room2 = sp < stack_cap
            do2 = second_ok & room2
            stack = jnp.where(do2, stack.at[sp].set(second), stack)
            sp = sp + do2.astype(jnp.int32)
            trunc = trunc | (first_ok & ~room1) | (second_ok & ~room2)
            return stack, sp, kd, ki, comps, trunc

        stack0 = jnp.zeros((stack_cap,), jnp.int32)
        init = (
            stack0,
            jnp.int32(1),
            jnp.full((k,), INF, jnp.float32),
            jnp.full((k,), -1, jnp.int32),
            jnp.int32(0),
            jnp.asarray(False),
        )
        _, _, kd, ki, comps, trunc = jax.lax.while_loop(cond, body, init)
        return ki, kd, comps, trunc

    return jax.vmap(per_query)(queries)


def search_best_first(
    tree: VPTree,
    queries: jax.Array,
    *,
    q: float,
    k: int = 1,
    X: Optional[jax.Array] = None,
    metric: str = "euclidean",
    max_comparisons: Optional[int] = None,
    valid: Optional[jax.Array] = None,
    with_truncated: bool = False,
):
    """Algorithm 2: best-first q-metric VP search with top-k results.

    With ``max_comparisons >= num_nodes`` this is the paper's exact search
    (returns the true NN w.r.t. the supplied dissimilarity if it satisfies
    the q-triangle inequality).  Smaller budgets truncate the DFS frontier —
    the approximate regime used for speed/recall sweeps.
    ``valid`` (n,) bool restricts the RESULTS to passing dataset points
    (filtered search): traversal still evaluates — and counts — every
    vantage distance, but only passing points can enter the top-k.
    Returns (idx (B, k), dist (B, k), comparisons (B,)); with
    ``with_truncated=True`` a fourth (B,) bool reports queries whose DFS
    stack hit its capacity (a dropped push — the default cap of
    ``2*depth+8`` never trips, since a binary DFS holds at most depth+1
    deferred nodes, but callers overriding the cap can detect it).
    """
    budget = tree.num_nodes if max_comparisons is None else max_comparisons
    cap = 2 * tree.depth + 8
    ki, kd, comps, trunc = _best_first_impl(
        (tree.vantage, tree.mu, tree.left, tree.right),
        X,
        queries,
        jnp.asarray(budget, jnp.int32),  # traced: int AND tracer budgets work
        metric,
        float(q),
        int(k),
        int(cap),
        None if valid is None else jnp.asarray(valid, bool),
    )
    if with_truncated:
        return ki, kd, comps, trunc
    return ki, kd, comps


# ---------------------------------------------------------------------------
# flattened tree + level-synchronous beam search (DESIGN.md §15)
# ---------------------------------------------------------------------------

class FlatVPTree(NamedTuple):
    """Level-order flattening of a ``VPTree`` with bucketed leaves.

    Internal node ``i`` (BFS order, root = 0) has its vantage point laid out
    at ROW ``i`` of the permuted corpus; bucket members follow, contiguous
    and bucket-major.  ``perm`` maps layout rows back to original dataset
    ids (``perm[row] = original id``), so ``Zf = Z[perm]`` is the search
    corpus and every gather during traversal is row-local.

    Child pointers encode three cases in one int32: ``>= 0`` internal child
    node id, ``-1`` no child, ``<= -2`` leaf bucket ``b`` as ``-(b + 2)``.
    All arrays are pad-safe for the ShardedIndex stacker (int pads -1,
    float pads +inf): a padded node is unreachable because only real nodes
    are ever pointed to and the root is always real.
    """

    mu: jax.Array  # (N,) float32 — node radius
    child_in: jax.Array  # (N,) int32 — inside child (see encoding above)
    child_out: jax.Array  # (N,) int32 — outside child
    rad_in: jax.Array  # (N,) f32 — max dist vantage->inside subtree (or inf)
    rad_out: jax.Array  # (N,) f32 — max dist vantage->outside subtree (or inf)
    bucket_rows: jax.Array  # (num_buckets, leaf_size) int32 layout rows, -1 pad
    centroids: Optional[jax.Array]  # (num_buckets, dim) f32 bucket means
    perm: jax.Array  # (n,) int32 — layout row -> original dataset id
    depth: int  # static: number of BFS levels (root level included)
    leaf_size: int  # static: bucket capacity L

    @property
    def num_nodes(self) -> int:
        return int(self.mu.shape[0])

    @property
    def num_buckets(self) -> int:
        return int(self.bucket_rows.shape[0])


def flatten_vptree(
    tree: VPTree,
    *,
    leaf_size: int = 16,
    Z: Optional[np.ndarray] = None,
    metric: str = "euclidean",
) -> FlatVPTree:
    """Build-time flattening pass (host): collapse every subtree holding at
    most ``leaf_size`` points into one contiguous leaf bucket, renumber the
    surviving internal nodes level-order (BFS), and emit the bucket-major
    corpus permutation.  The root never collapses, so ``num_nodes >= 1``
    and the beam always has a level-0 frontier to start from.

    When ``Z`` (the points the tree was built over, original-id indexed) is
    given, per-child subtree radii ``rad_in`` / ``rad_out`` — the max
    distance from a node's vantage to any point of its inside / outside
    subtree — are precomputed for the beam's triangle bounds
    (``d - rad >= 0`` lower-bounds the distance to every subtree point).
    Without ``Z`` the radii are +inf and the beam falls back to the
    mu-margin bounds alone."""
    van = np.asarray(tree.vantage)
    mu_a = np.asarray(tree.mu)
    left = np.asarray(tree.left)
    right = np.asarray(tree.right)
    nn = van.shape[0]
    L = int(leaf_size)
    if L < 1:
        raise ValueError(f"leaf_size must be >= 1, got {leaf_size}")

    # subtree point counts — children are appended after their parent during
    # the build DFS, so a reverse-id sweep sees every child before its parent
    size = np.ones(nn, np.int64)
    for i in range(nn - 1, -1, -1):
        for c in (left[i], right[i]):
            if c >= 0:
                size[i] += size[c]
    collapse = size <= L
    collapse[0] = False

    # BFS over surviving internal nodes: new id = visit order, level-ordered
    order: list[int] = []
    newid: dict[int, int] = {}
    levels: list[int] = []
    queue: list[tuple[int, int]] = [(0, 0)]
    head = 0
    while head < len(queue):
        o, lvl = queue[head]
        head += 1
        newid[o] = len(order)
        order.append(o)
        levels.append(lvl)
        for c in (left[o], right[o]):
            if c >= 0 and not collapse[c]:
                queue.append((int(c), lvl + 1))
    N = len(order)
    depth = levels[-1] + 1

    def subtree_points(r: int) -> list[int]:
        out, st = [], [r]
        while st:
            x = st.pop()
            out.append(int(van[x]))
            for c in (left[x], right[x]):
                if c >= 0:
                    st.append(int(c))
        return out

    child_in = np.full(N, -1, np.int32)
    child_out = np.full(N, -1, np.int32)
    rad_in = np.full(N, np.inf, np.float32)
    rad_out = np.full(N, np.inf, np.float32)
    Za = None if Z is None else np.asarray(Z)
    buckets: list[list[int]] = []
    for o in order:  # BFS order => bucket ids in encounter order
        ni = newid[o]
        for arr, rad, c in (
            (child_in, rad_in, left[o]),
            (child_out, rad_out, right[o]),
        ):
            if c < 0:
                continue
            members = subtree_points(int(c))
            if Za is not None:
                rad[ni] = float(
                    _np_dist_rows(
                        Za, int(van[o]), np.asarray(members, np.int64), metric
                    ).max()
                )
            if collapse[c]:
                arr[ni] = -(len(buckets) + 2)
                buckets.append(members)
            else:
                arr[ni] = newid[int(c)]

    # layout: rows 0..N-1 are the internal vantages (row == node id), then
    # bucket members, contiguous per bucket
    perm = [int(van[o]) for o in order]
    bucket_rows = np.full((max(len(buckets), 1), L), -1, np.int32)
    centroids = None
    if Za is not None:
        centroids = np.zeros((max(len(buckets), 1), Za.shape[1]), np.float32)
    row = N
    for b, members in enumerate(buckets):
        bucket_rows[b, : len(members)] = np.arange(
            row, row + len(members), dtype=np.int32
        )
        if centroids is not None:
            centroids[b] = Za[members].mean(0)
        perm.extend(members)
        row += len(members)
    assert len(perm) == nn, f"layout covers {len(perm)} of {nn} points"

    return FlatVPTree(
        mu=jnp.asarray(mu_a[order], jnp.float32),
        child_in=jnp.asarray(child_in),
        child_out=jnp.asarray(child_out),
        rad_in=jnp.asarray(rad_in),
        rad_out=jnp.asarray(rad_out),
        bucket_rows=jnp.asarray(bucket_rows),
        centroids=None if centroids is None else jnp.asarray(centroids),
        perm=jnp.asarray(perm, jnp.int32),
        depth=depth,
        leaf_size=L,
    )


def _pow2floor(x: int) -> int:
    return 1 << (max(int(x), 1).bit_length() - 1)


def _hofloor(x: int) -> int:
    """Largest half-octave value (2^j or 3 * 2^(j-1)) <= x — twice the
    granularity of pow2 bucketing at the same O(log) jit-key count."""
    p = _pow2floor(x)
    return p + p // 2 if x >= p + p // 2 else p


def beam_plan(
    max_comparisons: Optional[int],
    *,
    depth: int,
    leaf_size: int,
    num_nodes: int,
    num_buckets: int,
    k: int,
) -> tuple[int, int]:
    """Map a per-query comparison budget onto the beam's two static knobs.

    Returns ``(beam_width W, bucket_cap Bcap)``.  Cost accounting is EXACT,
    not the naive ``W * depth``: level l of a binary tree holds at most
    ``min(2^l, W)`` alive frontier slots, so a full-width beam over a small
    tree costs ~``num_nodes`` vantage evaluations — far less than
    ``W * depth`` — and every reached bucket adds one centroid evaluation
    (at most ``2 * vant`` and at most ``num_buckets``).  Whatever the
    traversal estimate leaves funds bucket rows.  W is power-of-two and
    Bcap half-octave (1, 2, 3, 4, 6, 8, 12, ...) bucketed — the static-knob
    discipline keeping budget sweeps at O(log) compiled programs.  With no
    budget the plan covers the whole tree (exact-regime default).
    """
    from repro.core.scan import pow2ceil

    levels = max(int(depth), 1)
    L = max(int(leaf_size), 1)
    nb = max(int(num_buckets), 1)
    full = num_nodes + nb + nb * L
    budget = full if max_comparisons is None else max(int(max_comparisons), 1)

    def traversal_cost(w: int) -> int:
        vant = sum(min(1 << min(lvl, 62), w) for lvl in range(levels))
        vant = min(vant, max(num_nodes, 1))
        return vant + min(2 * vant, nb)  # + centroid evaluations

    # widest affordable beam (wide frontiers are cheap under the exact
    # accounting), leaving at least half the budget for bucket rows
    W = min(64, pow2ceil(max(num_nodes, 1)))
    while W > 1 and traversal_cost(W) > budget // 2:
        W //= 2
    rem = max(budget - traversal_cost(W), L)
    # full coverage must mean FULL: only half-octave-bucket when the budget
    # actually forces dropping buckets
    Bcap = nb if rem // L >= nb else _hofloor(rem // L)
    # floor: enough bucket rows to fill k results even under tiny budgets
    need = -(-int(k) // L)
    return W, min(max(Bcap, pow2ceil(need)), nb)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "q", "k", "beam_width", "bucket_cap", "depth"),
)
def _beam_impl(
    flat_arrays, X, queries, metric: str, q: float, k: int, beam_width: int,
    bucket_cap: int, depth: int, valid=None, codes=None, scales=None,
):
    # One fused program for the whole batch: ``depth`` level steps, each a
    # batched (B, W) vantage-distance evaluation + vectorized q-CI/q-CO
    # pruning + top-W frontier selection, then ``bucket_cap`` leaf-bucket
    # scans through the core/scan running-merge discipline.  ``valid`` (n,)
    # bool (ORIGINAL ids) masks acceptance only — every evaluated distance
    # still counts, exactly like ``_best_first_impl``.  ``codes``/``scales``
    # switch the bucket scans to int8 rows (1 byte/dim read); traversal
    # stays f32 because navigation errors compound down the tree.
    (mu, child_in, child_out, rad_in, rad_out, bucket_rows, perm,
     centroids) = flat_arrays
    W, Bcap, K = beam_width, bucket_cap, k
    L = bucket_rows.shape[1]
    q_inf = math.isinf(q)
    pair = None if X is None else metrics_lib.pair_fn(metric)

    def vantage_dists(qr, nid):
        if X is None:
            return qr[perm[nid]]
        return jax.vmap(lambda v: pair(qr, v))(X[nid])

    def bucket_dists(qr, rows):
        if X is None:
            return qr[perm[rows]]
        if codes is not None:
            V = codes[rows].astype(jnp.float32) * scales[None, :]
            return jax.vmap(lambda v: pair(qr, v))(V)
        return jax.vmap(lambda v: pair(qr, v))(X[rows])

    def merge(best_d, best_i, ds, is_):
        cd = jnp.concatenate([best_d, ds])
        ci = jnp.concatenate([best_i, is_])
        neg, pos = jax.lax.top_k(-cd, K)
        return -neg, ci[pos]

    def per_query(qr):
        # comparison accounting is carried as THREE stage counters —
        # traversal (frontier vantage evals), centroid ranking, bucket rows
        # — threaded out of the jitted program as extra scalar outputs, the
        # no-host-callback route the telemetry layer reads (DESIGN.md §16).
        # Their sum is the engine-reported ``comparisons``.
        def level(_, st):
            frontier, flb, best_d, best_i, buf, bufp, c_trav, c_cent = st
            alive = frontier >= 0
            nid = jnp.maximum(frontier, 0)
            d = jnp.where(alive, vantage_dists(qr, nid), INF)
            c_trav = c_trav + jnp.sum(alive).astype(jnp.int32)
            # the vantages are dataset points: merge them (acceptance-masked)
            # before pruning, mirroring best_first's insert-then-prune order
            vid = perm[nid]
            acc = alive if valid is None else alive & valid[vid]
            best_d, best_i = merge(
                best_d, best_i,
                jnp.where(acc, d, INF), jnp.where(acc, vid, -1),
            )
            tau = best_d[K - 1]

            # q-CI / q-CO keep conditions — the EXACT mirror of
            # _best_first_impl's prune rules (paper semantics + parity
            # with the reference oracle)
            m = mu[nid]
            if q_inf:
                keep_in_c = ~(jnp.maximum(m, tau) <= d)
                keep_out_c = ~(jnp.maximum(d, tau) < m)
            else:
                s = jnp.maximum(jnp.maximum(d, m),
                                jnp.where(jnp.isfinite(tau), tau, 0.0))
                s = jnp.maximum(s, 1e-30)
                dq = (d / s) ** q
                mq = (m / s) ** q
                tq = jnp.where(jnp.isfinite(tau), (tau / s) ** q, INF)
                keep_in_c = ~(mq + tq <= dq)
                keep_out_c = ~(dq + tq < mq)

            cin, cout = child_in[nid], child_out[nid]
            ptr = jnp.concatenate([cin, cout])
            keep = jnp.concatenate(
                [alive & (cin != -1) & keep_in_c,
                 alive & (cout != -1) & keep_out_c]
            )
            # beam priority: (accumulated path bound, parent-vantage
            # distance) lexicographic.  The per-child 1-triangle bounds
            # max(d-m, 0) / max(m-d, 0) are sound for ANY q >= 1 (a
            # q-metric also satisfies the ordinary triangle inequality,
            # (a^q + b^q)^(1/q) <= a + b) — and, unlike the q-powered
            # bounds, they remain meaningful when the searched values are
            # Euclidean embedding distances that only approximate a
            # q-metric (the engine's reality, DESIGN.md §15).  The bound is
            # accumulated down the path (max with the parent's bound, the
            # monotone priority of a best-first queue): a child whose own
            # margin is zero still inherits every ancestor violation, so
            # exactly one root-leaf path per query scores 0 and the beam
            # discriminates at every level instead of only the last one.
            # The parent distance breaks the remaining lb == 0 ties toward
            # cells the query sits deep in.  The precomputed subtree radii
            # tighten both sides (``d - rad`` lower-bounds the distance to
            # every point of that child, and rad_in <= mu by construction);
            # radii are +inf when the flatten pass had no points, where the
            # max reduces back to the mu margins alone.
            rin = jnp.where(jnp.isfinite(rad_in[nid]), rad_in[nid], m)
            rout = rad_out[nid]
            lb = jnp.concatenate([
                jnp.maximum(d - rin, 0.0),
                jnp.maximum(jnp.maximum(m - d, d - rout), 0.0),
            ])
            bound = jnp.maximum(jnp.concatenate([flb, flb]), lb)
            prio = jnp.where(
                keep, bound * 1024.0 + jnp.concatenate([d, d]), INF
            )

            # reached leaf buckets: running top-Bcap merge by priority, so
            # overflow (the budget's Bcap) drops the GLOBALLY least
            # promising buckets, not merely the latest level's.  A bucket
            # has exactly one parent, so no id appears twice.  Buckets are
            # ranked by query->centroid distance when centroids are
            # available (vector mode): a min-distance bound barely
            # separates buckets in high dimension — some point of almost
            # every cell is close-ish — while the EXPECTED distance (the
            # IVF coarse-quantizer signal) tracks where the neighbors
            # actually are.  Each centroid evaluation is a real distance
            # computation and is counted in ``comparisons``.
            is_bucket = keep & (ptr <= -2)
            if centroids is not None:
                bidx = jnp.where(is_bucket, -(ptr + 2), 0)
                dcent = jax.vmap(lambda c: pair(qr, c))(centroids[bidx])
                bprio = jnp.where(is_bucket, dcent, INF)
                c_cent = c_cent + jnp.sum(is_bucket).astype(jnp.int32)
            else:
                bprio = jnp.where(is_bucket, prio, INF)
            cat_p = jnp.concatenate([bufp, bprio])
            cat_b = jnp.concatenate([buf, -(ptr + 2)])
            bneg, bpos = jax.lax.top_k(-cat_p, Bcap)
            bufp = -bneg
            buf = jnp.where(jnp.isfinite(bufp), cat_b[bpos], -1)

            # next frontier: the W most promising surviving internal
            # children (smallest priority), inheriting their path bounds
            is_node = keep & (ptr >= 0)
            neg, pos = jax.lax.top_k(-jnp.where(is_node, prio, INF), W)
            sel = jnp.isfinite(-neg)
            frontier = jnp.where(sel, ptr[pos], -1)
            flb = jnp.where(sel, bound[pos], 0.0)
            return frontier, flb, best_d, best_i, buf, bufp, c_trav, c_cent

        def bucket_scan(buf, best_d, best_i):
            # one fused scan over every selected bucket: gather the
            # (Bcap * L) member rows, evaluate all distances in one batched
            # computation (MXU-shaped in vector mode) and fold them into
            # the running best with a single top-k merge — buckets are
            # disjoint and never contain vantage rows, so no id repeats
            rows = jnp.where(
                (buf >= 0)[:, None], bucket_rows[jnp.maximum(buf, 0)], -1
            ).reshape(-1)
            rvalid = rows >= 0
            rsafe = jnp.maximum(rows, 0)
            d = jnp.where(rvalid, bucket_dists(qr, rsafe), INF)
            oid = perm[rsafe]
            c_buck = jnp.sum(rvalid).astype(jnp.int32)
            acc = rvalid if valid is None else rvalid & valid[oid]
            best_d, best_i = merge(
                best_d, best_i, jnp.where(acc, d, INF), jnp.where(acc, oid, -1)
            )
            return best_d, best_i, c_buck

        frontier0 = jnp.full((W,), -1, jnp.int32).at[0].set(0)
        init = (
            frontier0,
            jnp.zeros((W,), jnp.float32),
            jnp.full((K,), INF, jnp.float32),
            jnp.full((K,), -1, jnp.int32),
            jnp.full((Bcap,), -1, jnp.int32),
            jnp.full((Bcap,), INF, jnp.float32),
            jnp.int32(0),
            jnp.int32(0),
        )
        frontier, _, best_d, best_i, buf, _, c_trav, c_cent = jax.lax.fori_loop(
            0, depth, level, init
        )
        best_d, best_i, c_buck = bucket_scan(buf, best_d, best_i)
        return best_i, best_d, c_trav + c_cent + c_buck, c_trav, c_cent, c_buck

    return jax.vmap(per_query)(queries)


def search_beam(
    flat: FlatVPTree,
    queries: jax.Array,
    *,
    q: float,
    k: int = 1,
    X: Optional[jax.Array] = None,
    metric: str = "euclidean",
    max_comparisons: Optional[int] = None,
    beam_width: Optional[int] = None,
    bucket_cap: Optional[int] = None,
    valid: Optional[jax.Array] = None,
    codes: Optional[jax.Array] = None,
    scales: Optional[jax.Array] = None,
    with_stages: bool = False,
):
    """Level-synchronous beam search over a flattened VP tree — ONE jitted
    dispatch for the whole query batch (DESIGN.md §15).

    ``X`` is the LAYOUT-ORDERED corpus (``Z[flat.perm]``), not the original
    row order; with ``X=None`` each query is a precomputed (n,) distance row
    indexed by ORIGINAL dataset id (the canonical-projection mode shared
    with ``search_best_first``).  ``codes``/``scales`` (int8 codes of the
    layout-ordered corpus + per-dim scales) switch bucket scans to the
    1-byte/dim quantized read.  ``max_comparisons`` is a PLANNING input: it
    is mapped onto the static (beam_width, bucket_cap) knobs by
    ``beam_plan`` (explicit knobs win), and the returned per-query
    comparison counts — frontier evaluations plus scanned bucket rows —
    respect ``W * depth + Bcap * leaf_size``.

    At ``beam_width >= num_nodes`` and ``bucket_cap >= num_buckets`` no
    viable child is ever dropped, so (on a dissimilarity satisfying the
    q-triangle inequality) the result is exact — the same guarantee as
    best-first at full budget.  Returns (idx (B, k), dist (B, k),
    comparisons (B,)) with idx in ORIGINAL dataset ids.

    ``with_stages=True`` appends a fourth element: a dict of per-query
    (B,) int32 stage counters ``{"traversal", "centroid_rank",
    "bucket_scan"}`` whose elementwise sum equals ``comparisons`` — the
    jit-threaded accounting the telemetry layer records (DESIGN.md §16).
    """
    if codes is not None and X is None:
        raise ValueError("quantized bucket scan requires vector mode (X)")
    W0, B0 = beam_plan(
        max_comparisons, depth=flat.depth, leaf_size=flat.leaf_size,
        num_nodes=flat.num_nodes, num_buckets=flat.num_buckets, k=k,
    )
    W = int(beam_width) if beam_width is not None else W0
    Bcap = int(bucket_cap) if bucket_cap is not None else B0
    idx, dist, comps, c_trav, c_cent, c_buck = _beam_impl(
        (flat.mu, flat.child_in, flat.child_out, flat.rad_in, flat.rad_out,
         flat.bucket_rows, flat.perm,
         flat.centroids if X is not None else None),
        X,
        queries,
        metric,
        float(q),
        int(k),
        max(1, W),
        max(1, min(Bcap, flat.num_buckets)),
        flat.depth,
        None if valid is None else jnp.asarray(valid, bool),
        codes,
        None if scales is None else scales,
    )
    if with_stages:
        stages = {"traversal": c_trav, "centroid_rank": c_cent,
                  "bucket_scan": c_buck}
        return idx, dist, comps, stages
    return idx, dist, comps


# ---------------------------------------------------------------------------
# reference search (host, exact recursion) — oracle for tests
# ---------------------------------------------------------------------------

def search_reference(
    tree: VPTree,
    q_row_or_vec: np.ndarray,
    *,
    q: float,
    X: Optional[np.ndarray] = None,
    metric: str = "euclidean",
) -> tuple[int, float, int]:
    """Literal recursive Algorithm 2/3 in numpy (1 query, k=1)."""
    vantage = np.asarray(tree.vantage)
    mu = np.asarray(tree.mu)
    left = np.asarray(tree.left)
    right = np.asarray(tree.right)

    if X is None:
        def dist(j: int) -> float:
            return float(q_row_or_vec[j])
    else:
        Xq = np.concatenate([np.asarray(X), np.asarray(q_row_or_vec)[None]], axis=0)

        def dist(j: int) -> float:
            return float(_np_dist_rows(Xq, Xq.shape[0] - 1, np.asarray([j]), metric)[0])

    best = [-1, math.inf, 0]  # idx, tau, comparisons

    def visit(node: int) -> None:
        if node < 0:
            return
        j = int(vantage[node])
        d = dist(j)
        best[2] += 1
        if d < best[1]:
            best[1] = d
            best[0] = j
        tau = best[1]
        m = float(mu[node])
        if math.isinf(q):
            if d < m:
                visit(int(left[node]))
                if not max(d, tau) < m:  # unreachable: complementary conditions
                    visit(int(right[node]))
            else:
                visit(int(right[node]))
            return
        s = max(d, m, 0.0 if math.isinf(tau) else tau, 1e-30)
        dq, mq = (d / s) ** q, (m / s) ** q
        tq = math.inf if math.isinf(tau) else (tau / s) ** q
        if dq + tq < mq:
            visit(int(left[node]))
        elif mq + tq <= dq:
            visit(int(right[node]))
        else:
            if d < m:
                visit(int(left[node]))
                visit(int(right[node]))
            else:
                visit(int(right[node]))
                visit(int(left[node]))

    import sys

    old = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old, tree.num_nodes + 100))
    try:
        visit(0)
    finally:
        sys.setrecursionlimit(old)
    return best[0], best[1], best[2]
