"""Vantage-point trees for q-metric / infinity-metric search (paper App. C/D).

Build (host, numpy)
-------------------
``build_vptree`` follows Algorithm 1 literally: random (or max-spread)
vantage, radius = median of distances, ties assigned to the OUTSIDE set
(paper (5)/(16)).  The tree is stored as flat arrays — ``vantage[i]`` is the
dataset index of node i's vantage point, ``mu[i]`` its radius, ``left/right``
child node ids (-1 = none) — so the search phase is pure gather arithmetic.

Search (device, JAX) — DESIGN.md §3.2
-------------------------------------
* ``descend_infty``: the Theorem-1 path.  In an infinity-metric space the
  prune conditions (inf-CI)/(inf-CO) are complementary, so each query visits
  exactly one node per level; the whole batch advances in lockstep with one
  gather + one batched distance per level (fori_loop over depth).  Total
  comparisons per query = root-to-leaf path length <= tree depth.
* ``search_best_first``: Algorithm 2 (finite q) with its backtracking
  semantics — a while_loop with an explicit fixed-capacity DFS stack, a
  top-k result buffer and a ``max_comparisons`` budget.  Budget >= n
  reproduces the exact search; smaller budgets give the approximate
  speed/recall trade-off swept in the benchmarks.

Both searches accept either raw vectors (distances evaluated on the fly with
any registered metric) or precomputed query->dataset distance rows (used for
the canonical-projection experiments where d_q(x_o, x) comes from
``project_with_queries``).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as metrics_lib

INF = jnp.inf


class VPTree(NamedTuple):
    """Flat array representation of a VP tree (device-friendly)."""

    vantage: jax.Array  # (num_nodes,) int32 — dataset index of vantage point
    mu: jax.Array  # (num_nodes,) float32 — node radius
    left: jax.Array  # (num_nodes,) int32 — inside child node id or -1
    right: jax.Array  # (num_nodes,) int32 — outside child node id or -1
    depth: int  # static python int

    @property
    def num_nodes(self) -> int:
        return int(self.vantage.shape[0])


# ---------------------------------------------------------------------------
# host-side numpy distance rows (build-time only)
# ---------------------------------------------------------------------------

def _np_dist_rows(X: np.ndarray, i: int, idxs: np.ndarray, metric: str) -> np.ndarray:
    x = X[i]
    Y = X[idxs]
    if metric == "euclidean":
        return np.sqrt(np.maximum(((Y - x) ** 2).sum(-1), 0.0))
    if metric == "sqeuclidean":
        return ((Y - x) ** 2).sum(-1)
    if metric == "manhattan":
        return np.abs(Y - x).sum(-1)
    if metric == "chebyshev":
        return np.abs(Y - x).max(-1)
    if metric == "cosine":
        nx = max(float(np.linalg.norm(x)), 1e-12)
        ny = np.maximum(np.linalg.norm(Y, axis=-1), 1e-12)
        return 1.0 - (Y @ x) / (ny * nx)
    if metric == "correlation":
        xc = x - x.mean()
        Yc = Y - Y.mean(-1, keepdims=True)
        nx = max(float(np.linalg.norm(xc)), 1e-12)
        ny = np.maximum(np.linalg.norm(Yc, axis=-1), 1e-12)
        return 1.0 - (Yc @ xc) / (ny * nx)
    if metric == "jaccard":
        xb = x > 0
        Yb = Y > 0
        inter = (Yb & xb).sum(-1)
        union = (Yb | xb).sum(-1)
        return 1.0 - inter / np.maximum(union, 1)
    if metric == "dot":
        return -(Y @ x)
    raise KeyError(metric)


# ---------------------------------------------------------------------------
# build (Algorithm 1)
# ---------------------------------------------------------------------------

def build_vptree(
    X: Optional[np.ndarray] = None,
    *,
    D: Optional[np.ndarray] = None,
    metric: str = "euclidean",
    seed: int = 0,
    select: str = "random",
) -> VPTree:
    """Recursive median-split construction (Algorithm 1).

    Either ``X`` (vectors + metric) or ``D`` (precomputed (n, n) dissimilarity
    matrix, e.g. a canonical projection) must be given.  ``select='spread'``
    uses the Yianilos variance heuristic over a distance sample (Remark 2).
    """
    if (X is None) == (D is None):
        raise ValueError("exactly one of X / D must be provided")
    n = (X.shape[0] if X is not None else D.shape[0])
    if n == 0:
        raise ValueError("empty dataset")
    rng = np.random.default_rng(seed)

    def dist_rows(i: int, idxs: np.ndarray) -> np.ndarray:
        if D is not None:
            return np.asarray(D)[i, idxs]
        return _np_dist_rows(np.asarray(X), i, idxs, metric)

    vantage: list[int] = []
    mu: list[float] = []
    left: list[int] = []
    right: list[int] = []

    def new_node() -> int:
        vantage.append(-1)
        mu.append(0.0)
        left.append(-1)
        right.append(-1)
        return len(vantage) - 1

    max_depth = 0

    # Iterative DFS to avoid Python recursion limits on unbalanced trees.
    root = new_node()
    stack: list[tuple[int, np.ndarray, int]] = [(root, np.arange(n), 0)]
    while stack:
        node, idxs, d_level = stack.pop()
        max_depth = max(max_depth, d_level)
        if select == "spread" and len(idxs) > 2:
            cand = idxs[rng.choice(len(idxs), size=min(8, len(idxs)), replace=False)]
            probe = idxs[rng.choice(len(idxs), size=min(32, len(idxs)), replace=False)]
            spreads = [float(np.var(dist_rows(int(c), probe))) for c in cand]
            v = int(cand[int(np.argmax(spreads))])
        else:
            v = int(idxs[rng.integers(len(idxs))])
        rest = idxs[idxs != v]
        vantage[node] = v
        if rest.size == 0:
            continue
        dists = dist_rows(v, rest)
        m = float(np.median(dists))
        mu[node] = m
        inside = rest[dists < m]
        outside = rest[dists >= m]  # ties -> outside (paper (5))
        if inside.size:
            c = new_node()
            left[node] = c
            stack.append((c, inside, d_level + 1))
        if outside.size:
            c = new_node()
            right[node] = c
            stack.append((c, outside, d_level + 1))

    return VPTree(
        vantage=jnp.asarray(vantage, jnp.int32),
        mu=jnp.asarray(mu, jnp.float32),
        left=jnp.asarray(left, jnp.int32),
        right=jnp.asarray(right, jnp.int32),
        depth=max_depth + 1,
    )


# ---------------------------------------------------------------------------
# distance evaluation during search
# ---------------------------------------------------------------------------

def _make_dist(X: Optional[jax.Array], metric: str):
    """Returns f(q_repr, j) -> distance.

    If ``X`` is given, ``q_repr`` is a query vector; otherwise ``q_repr`` is a
    precomputed (n,) row of query->dataset dissimilarities and the evaluation
    is a single gather (canonical-projection search mode).
    """
    if X is None:
        def f(q_row: jax.Array, j: jax.Array) -> jax.Array:
            return q_row[j]
        return f
    pair = metrics_lib.pair_fn(metric)

    def f(q_vec: jax.Array, j: jax.Array) -> jax.Array:
        return pair(q_vec, X[j])

    return f


# ---------------------------------------------------------------------------
# infinity-metric descent (Theorem 1)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "depth"))
def _descend_impl(tree_arrays, X, queries, metric: str, depth: int):
    vantage, mu, left, right = tree_arrays
    dist = _make_dist(X, metric)

    def per_query(qr):
        def body(_, st):
            node, best_d, best_i, comps = st
            valid = node >= 0
            j = vantage[jnp.maximum(node, 0)]
            d = dist(qr, j)
            better = valid & (d < best_d)
            best_d = jnp.where(better, d, best_d)
            best_i = jnp.where(better, j, best_i)
            comps = comps + valid.astype(jnp.int32)
            go_left = d < mu[jnp.maximum(node, 0)]
            nxt = jnp.where(go_left, left[jnp.maximum(node, 0)], right[jnp.maximum(node, 0)])
            node = jnp.where(valid, nxt, node)
            return node, best_d, best_i, comps

        init = (jnp.int32(0), jnp.float32(INF), jnp.int32(-1), jnp.int32(0))
        _, bd, bi, c = jax.lax.fori_loop(0, depth, body, init)
        return bi, bd, c

    return jax.vmap(per_query)(queries)


def descend_infty(
    tree: VPTree,
    queries: jax.Array,
    *,
    X: Optional[jax.Array] = None,
    metric: str = "euclidean",
):
    """Single-path descent (Algorithm 3 / Theorem 1).

    ``queries`` is (B, d) vectors when ``X`` is given, else (B, n) precomputed
    distance rows.  Returns (best_idx (B,), best_dist (B,), comparisons (B,)).
    Comparisons <= tree depth by construction.
    """
    return _descend_impl(
        (tree.vantage, tree.mu, tree.left, tree.right), X, queries, metric, tree.depth
    )


# ---------------------------------------------------------------------------
# finite-q best-first search (Algorithm 2) with comparison budget
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("metric", "q", "k", "stack_cap")
)
def _best_first_impl(
    tree_arrays, X, queries, max_comparisons, metric: str, q: float, k: int,
    stack_cap: int, valid=None,
):
    # ``max_comparisons`` is a TRACED int32 scalar: it only gates the
    # while_loop condition, so different budgets (notably the per-shard
    # remainder split in core/index) share one compiled program.
    # ``valid`` (n,) bool masks ACCEPTANCE only (filtered search): every
    # vantage distance is still evaluated — navigation and pruning need it
    # — and still counts against the budget, but non-passing points never
    # enter the top-k buffer.  tau then upper-bounds the k-th best PASSING
    # distance, which is >= the unfiltered tau, so pruning only weakens:
    # conservative, never wrong (the subset argument of DESIGN.md §12).
    vantage, mu, left, right = tree_arrays
    dist = _make_dist(X, metric)
    q_inf = math.isinf(q)

    def per_query(qr):
        def cond(st):
            stack, sp, kd, ki, comps = st
            return (sp > 0) & (comps < max_comparisons)

        def body(st):
            stack, sp, kd, ki, comps = st
            node = stack[sp - 1]
            sp = sp - 1
            j = vantage[node]
            d = dist(qr, j)
            comps = comps + 1
            # top-k insert (k is small; argsort of k+1 elements); filtered-
            # out vantages insert as (+inf, -1) — a no-op slot
            if valid is None:
                ins_d, ins_i = d, j
            else:
                ok = valid[j]
                ins_d = jnp.where(ok, d, INF)
                ins_i = jnp.where(ok, j, -1)
            cd = jnp.concatenate([kd, ins_d[None]])
            ci = jnp.concatenate([ki, ins_i[None]])
            order = jnp.argsort(cd)
            kd = cd[order][:k]
            ki = ci[order][:k]
            tau = kd[k - 1]

            m = mu[node]
            lc, rc = left[node], right[node]
            if q_inf:
                # (inf-CI)/(inf-CO): complementary once tau <= d holds.
                prune_out = jnp.maximum(d, tau) < m
                prune_in = jnp.maximum(m, tau) <= d
            else:
                # powered conditions in a normalized domain: overflow-safe and
                # conservative (underflow can only disable pruning, never
                # prune a branch that may hold the NN).
                s = jnp.maximum(jnp.maximum(d, m), jnp.where(jnp.isfinite(tau), tau, 0.0))
                s = jnp.maximum(s, 1e-30)
                dq = (d / s) ** q
                mq = (m / s) ** q
                tq = jnp.where(jnp.isfinite(tau), (tau / s) ** q, INF)
                prune_out = dq + tq < mq  # (q-CI): only inside can hold NN
                prune_in = mq + tq <= dq  # (q-CO): only outside can hold NN

            # DFS order: push the deferred far child first, near child last.
            push_left = (lc >= 0) & ~prune_in
            push_right = (rc >= 0) & ~prune_out
            near_left = d < m  # visit the side containing the query first
            first = jnp.where(near_left, rc, lc)      # deferred
            first_ok = jnp.where(near_left, push_right, push_left)
            second = jnp.where(near_left, lc, rc)     # visited next
            second_ok = jnp.where(near_left, push_left, push_right)

            stack = jnp.where(first_ok, stack.at[sp].set(first), stack)
            sp = sp + first_ok.astype(jnp.int32)
            stack = jnp.where(second_ok, stack.at[sp].set(second), stack)
            sp = sp + second_ok.astype(jnp.int32)
            return stack, sp, kd, ki, comps

        stack0 = jnp.zeros((stack_cap,), jnp.int32)
        init = (
            stack0,
            jnp.int32(1),
            jnp.full((k,), INF, jnp.float32),
            jnp.full((k,), -1, jnp.int32),
            jnp.int32(0),
        )
        _, _, kd, ki, comps = jax.lax.while_loop(cond, body, init)
        return ki, kd, comps

    return jax.vmap(per_query)(queries)


def search_best_first(
    tree: VPTree,
    queries: jax.Array,
    *,
    q: float,
    k: int = 1,
    X: Optional[jax.Array] = None,
    metric: str = "euclidean",
    max_comparisons: Optional[int] = None,
    valid: Optional[jax.Array] = None,
):
    """Algorithm 2: best-first q-metric VP search with top-k results.

    With ``max_comparisons >= num_nodes`` this is the paper's exact search
    (returns the true NN w.r.t. the supplied dissimilarity if it satisfies
    the q-triangle inequality).  Smaller budgets truncate the DFS frontier —
    the approximate regime used for speed/recall sweeps.
    ``valid`` (n,) bool restricts the RESULTS to passing dataset points
    (filtered search): traversal still evaluates — and counts — every
    vantage distance, but only passing points can enter the top-k.
    Returns (idx (B, k), dist (B, k), comparisons (B,)).
    """
    budget = tree.num_nodes if max_comparisons is None else max_comparisons
    cap = 2 * tree.depth + 8
    return _best_first_impl(
        (tree.vantage, tree.mu, tree.left, tree.right),
        X,
        queries,
        jnp.asarray(budget, jnp.int32),  # traced: int AND tracer budgets work
        metric,
        float(q),
        int(k),
        int(cap),
        None if valid is None else jnp.asarray(valid, bool),
    )


# ---------------------------------------------------------------------------
# reference search (host, exact recursion) — oracle for tests
# ---------------------------------------------------------------------------

def search_reference(
    tree: VPTree,
    q_row_or_vec: np.ndarray,
    *,
    q: float,
    X: Optional[np.ndarray] = None,
    metric: str = "euclidean",
) -> tuple[int, float, int]:
    """Literal recursive Algorithm 2/3 in numpy (1 query, k=1)."""
    vantage = np.asarray(tree.vantage)
    mu = np.asarray(tree.mu)
    left = np.asarray(tree.left)
    right = np.asarray(tree.right)

    if X is None:
        def dist(j: int) -> float:
            return float(q_row_or_vec[j])
    else:
        Xq = np.concatenate([np.asarray(X), np.asarray(q_row_or_vec)[None]], axis=0)

        def dist(j: int) -> float:
            return float(_np_dist_rows(Xq, Xq.shape[0] - 1, np.asarray([j]), metric)[0])

    best = [-1, math.inf, 0]  # idx, tau, comparisons

    def visit(node: int) -> None:
        if node < 0:
            return
        j = int(vantage[node])
        d = dist(j)
        best[2] += 1
        if d < best[1]:
            best[1] = d
            best[0] = j
        tau = best[1]
        m = float(mu[node])
        if math.isinf(q):
            if d < m:
                visit(int(left[node]))
                if not max(d, tau) < m:  # unreachable: complementary conditions
                    visit(int(right[node]))
            else:
                visit(int(right[node]))
            return
        s = max(d, m, 0.0 if math.isinf(tau) else tau, 1e-30)
        dq, mq = (d / s) ** q, (m / s) ** q
        tq = math.inf if math.isinf(tau) else (tau / s) ** q
        if dq + tq < mq:
            visit(int(left[node]))
        elif mq + tq <= dq:
            visit(int(right[node]))
        else:
            if d < m:
                visit(int(left[node]))
                visit(int(right[node]))
            else:
                visit(int(right[node]))
                visit(int(left[node]))

    import sys

    old = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old, tree.num_nodes + 100))
    try:
        visit(0)
    finally:
        sys.setrecursionlimit(old)
    return best[0], best[1], best[2]
