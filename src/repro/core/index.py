"""Unified index protocol, registry, and the sharded search engine.

One ``build/search`` contract from kernels to serving (DESIGN.md §10):

* ``SearchResult`` — the triple every searcher returns.  ``idx`` (B, k)
  int32 dataset indices (-1 = no result), ``dist`` (B, k) f32 ascending,
  ``comparisons`` (B,) int32 — the engine's distance-evaluation count, the
  paper's implementation-agnostic cost metric (App. F.1).  For the scan
  engines these are original-space candidate scores; for the infinity
  engine they are embedding-space tree visits plus the two-stage rerank
  width (F.5's accounting — the k final original-metric scores attached to
  every result are reporting, not counted search work).
* ``Index`` — the protocol: ``build(X, cfg)`` / ``search(Q, k, budget)`` /
  ``memory_bytes()``.  ``cfg`` is one plain mapping describing the whole
  engine: keys matching the engine's ``build`` signature configure
  construction, keys matching its ``search`` signature become per-instance
  search defaults.
* registry — ``@register_index(name)`` + ``build(name, X, cfg)``.  The five
  built-ins ("brute", "ivf_flat", "ivf_pq", "nsw", "infinity") self-register
  when their modules load; ``_ensure_builtin`` loads them on first lookup so
  importing this module stays cheap.
* ``ShardedIndex`` — the corpus row-sharded over the ``data`` axis of a
  1-axis device mesh via ``shard_map`` (``dist/sharding.py`` conventions:
  corpus rows on "data", queries replicated).  Each shard runs any
  registered engine locally; per-shard top-k lists get their global indices
  back from the shard offsets and are merged with the ``core/scan`` running
  merge, so a multi-device run returns exactly what the single-device
  engine would for exhaustive engines (see DESIGN.md §10 for the argument).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Mapping, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scan as scan_lib


class SearchResult(NamedTuple):
    """Uniform search answer: unpacks as (idx, dist, comparisons)."""

    idx: jax.Array  # (B, k) int32, -1 = no result
    dist: jax.Array  # (B, k) f32, ascending (ties -> lowest index)
    comparisons: jax.Array  # (B,) int32 original-space distance evaluations


@runtime_checkable
class Index(Protocol):
    """What every registered engine implements (structural — no inheritance)."""

    @classmethod
    def build(cls, X, **cfg) -> "Index": ...

    def search(self, Q, k: int = 1, *, budget: Optional[int] = None) -> SearchResult: ...

    def memory_bytes(self) -> int: ...


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}
BUILTIN = ("brute", "ivf_flat", "ivf_pq", "nsw", "infinity", "sharded", "live")


def register_index(name: str):
    """Class decorator: expose an engine under a stable string key."""

    def deco(cls):
        for attr in ("build", "search", "memory_bytes"):
            if not hasattr(cls, attr):
                raise TypeError(f"{cls.__name__} lacks Index.{attr}")
        cls.registry_name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def _ensure_builtin() -> None:
    # engines self-register at module load; importing them here keeps the
    # registry lazily populated without import cycles
    import repro.core.baselines  # noqa: F401
    import repro.core.live  # noqa: F401
    import repro.core.search  # noqa: F401


def available() -> tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


def get_index(name: str) -> type:
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown index {name!r}; available: {available()}") from None


def build(name: str, X, cfg: Optional[Mapping[str, Any]] = None) -> Index:
    """Build any registered engine from one config mapping.

    Keys are split against the engine's ``build`` / ``search`` signatures;
    leftover search-time keys are stored as the instance's search defaults
    (so ``registry.build("ivf_flat", X, {"num_clusters": 48, "nprobe": 8})``
    probes 8 lists on every subsequent ``search``).
    """
    cls = get_index(name)
    hook = getattr(cls, "registry_build", None)
    if hook is not None:
        return hook(X, cfg)
    return generic_registry_build(cls, X, cfg)


def generic_registry_build(cls, X, cfg: Optional[Mapping[str, Any]]) -> Index:
    cfg = dict(cfg or {})
    bkeys = set(inspect.signature(cls.build).parameters) - {"cls", "X"}
    skeys = (set(inspect.signature(cls.search).parameters) - {"self", "Q", "k"}) | {"budget"}
    bkw = {k: cfg.pop(k) for k in list(cfg) if k in bkeys}
    skw = {k: cfg.pop(k) for k in list(cfg) if k in skeys}
    if cfg:
        raise TypeError(
            f"{cls.registry_name}: unknown cfg keys {sorted(cfg)} "
            f"(build takes {sorted(bkeys)}, search takes {sorted(skeys)})"
        )
    inst = cls.build(X, **bkw)
    inst.search_defaults = skw
    return inst


def resolve(value, defaults: Optional[Mapping[str, Any]], key: str, fallback=None):
    """Search-kwarg resolution order: explicit arg > stored default > fallback."""
    if value is not None:
        return value
    if defaults and defaults.get(key) is not None:
        return defaults[key]
    return fallback


def pytree_nbytes(tree) -> int:
    """Total device bytes of every array leaf (the memory_bytes() helper)."""
    return int(
        sum(
            np.prod(x.shape) * jnp.dtype(x.dtype).itemsize
            for x in jax.tree_util.tree_leaves(tree)
            if hasattr(x, "shape")
        )
    )


def default_merge_shard_static(statics: list[dict]) -> dict:
    """Per-shard static configs must agree (engines with per-shard statics —
    e.g. tree depth — override ``merge_shard_static``)."""
    merged = dict(statics[0])
    for s in statics[1:]:
        if s != merged:
            raise ValueError(f"shard statics disagree: {merged} vs {s}")
    return merged


# ---------------------------------------------------------------------------
# sharded engine
# ---------------------------------------------------------------------------

def _stack_shard_states(states: list):
    """Stack per-shard state pytrees along a new leading shard axis.

    Leaves whose trailing shapes differ across shards (IVF's padded inverted
    lists — Lmax follows the largest cluster) are first padded to the
    elementwise max shape: int leaves with -1 (the codebase-wide "invalid
    id"), float leaves with +inf ("no candidate").
    """
    flats, treedefs = zip(*(jax.tree_util.tree_flatten(s) for s in states))
    stacked = []
    for leaves in zip(*flats):
        leaves = [jnp.asarray(l) for l in leaves]
        shapes = [l.shape for l in leaves]
        if len(set(shapes)) > 1:
            target = tuple(max(s[i] for s in shapes) for i in range(len(shapes[0])))
            fill = -1 if jnp.issubdtype(leaves[0].dtype, jnp.integer) else jnp.inf
            leaves = [
                jnp.pad(
                    l,
                    [(0, t - s) for s, t in zip(l.shape, target)],
                    constant_values=fill,
                )
                for l in leaves
            ]
        stacked.append(jnp.stack(leaves))
    return jax.tree_util.tree_unflatten(treedefs[0], stacked)


@register_index("sharded")
@dataclasses.dataclass
class ShardedIndex:
    """Any registered engine, data-parallel over corpus shards.

    ``build`` splits the corpus into ``shards`` equal row-slices, builds one
    inner engine per shard, and stacks the per-shard device state along a
    leading shard axis that lives on the mesh's ``data`` axis.  ``search``
    runs every shard's engine locally under ``shard_map`` (queries
    replicated), restores global indices from the shard offsets, and merges
    the per-shard top-k lists with the ``core/scan`` running merge.
    Comparisons are summed across shards — the work really done — and a
    per-query ``budget`` is split evenly across shards so the summed count
    respects the same bound as a single-device engine (engine-cfg knobs
    like ``rerank`` remain per shard).
    """

    engine: str
    engine_cls: type
    stacked: Any  # pytree; every leaf (S, ...), placed on the mesh's data axis
    static: dict
    shard_size: int
    n: int
    dctx: Any  # dist.sharding.DistCtx over a ("data",) mesh
    search_defaults: dict = dataclasses.field(default_factory=dict)
    _jitted: dict = dataclasses.field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------ build
    @classmethod
    def registry_build(cls, X, cfg: Optional[Mapping[str, Any]] = None) -> "ShardedIndex":
        cfg = dict(cfg or {})
        engine = cfg.pop("engine", "brute")
        shards = int(cfg.pop("shards", 2))
        mesh = cfg.pop("mesh", None)
        engine_cfg = cfg.pop("engine_cfg", None)
        if engine_cfg is None:
            engine_cfg = cfg  # remaining keys configure the inner engine
        elif cfg:
            raise TypeError(f"sharded: pass engine keys via engine_cfg OR inline, not both: {sorted(cfg)}")
        return cls.build(X, engine=engine, shards=shards, mesh=mesh, engine_cfg=engine_cfg)

    @classmethod
    def build(
        cls, X, *, engine: str = "brute", shards: int = 2, mesh=None,
        engine_cfg: Optional[Mapping[str, Any]] = None,
    ) -> "ShardedIndex":
        from repro.dist.sharding import search_policy

        X = jnp.asarray(X, jnp.float32)
        n = X.shape[0]
        shards = int(shards)
        if shards < 1 or n % shards != 0:
            raise ValueError(f"corpus rows ({n}) must divide evenly into shards ({shards})")
        engine_cls = get_index(engine)
        if not hasattr(engine_cls, "shard_state"):
            raise TypeError(f"engine {engine!r} does not support sharding (no shard_state)")
        if mesh is None:
            from jax.sharding import Mesh

            devs = jax.devices()
            if len(devs) < shards:
                raise RuntimeError(
                    f"need {shards} devices for {shards} shards, have {len(devs)}"
                )
            mesh = Mesh(np.asarray(devs[:shards]), ("data",))
        if mesh.shape.get("data", 1) != shards:
            raise ValueError(f"mesh data axis {mesh.shape} != shards {shards}")
        shard_size = n // shards
        states, statics = [], []
        for s in range(shards):
            # bare `build` resolves to the module-level registry function
            # (the class namespace is not an enclosing scope)
            inner = build(engine, X[s * shard_size : (s + 1) * shard_size], engine_cfg)
            st, stat = inner.shard_state()
            states.append(st)
            statics.append(stat)
        merge = getattr(engine_cls, "merge_shard_static", None)
        static = merge(statics) if merge is not None else default_merge_shard_static(statics)
        from jax.sharding import NamedSharding, PartitionSpec as P

        # place the per-shard state on the data axis ONCE so serving-time
        # searches never re-transfer it
        stacked = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("data"))),
            _stack_shard_states(states),
        )
        return cls(
            engine=engine,
            engine_cls=engine_cls,
            stacked=stacked,
            static=static,
            shard_size=shard_size,
            n=n,
            dctx=search_policy(mesh),
        )

    # ----------------------------------------------------------------- search
    def search(self, Q, k: int = 1, *, budget: Optional[int] = None) -> SearchResult:
        budget = resolve(budget, self.search_defaults, "budget")
        S = self.dctx.mesh.shape["data"]
        base = rem = None
        if budget is not None:
            # the budget is per QUERY, not per shard: split it so the summed
            # comparisons stay within the requested bound (floor of 1 per
            # shard — a budget below the shard count degrades to 1 each).
            # The remainder goes to the first ``rem`` shards as a traced
            # per-shard vector so the summed budget is TIGHT, not floored —
            # engines whose budget knob is traceable (infinity's
            # max_comparisons) consume base+1 there; engines with static
            # knobs (IVF's nprobe, NSW's max_steps) resolve from the floor.
            base, rem = divmod(int(budget), S)
            if base == 0:
                base, rem = 1, 0
        Q = jnp.asarray(Q, jnp.float32)
        k = int(k)
        # one compile per knob setting (serving discipline).  Engines whose
        # budget is a traced operand compile ONE program for every budget
        # value (the point of the traced while-gate in vptree) — only the
        # budgeted/unbudgeted distinction stays in their key.
        traced = budget is not None and getattr(
            self.engine_cls, "shard_traced_budget", False
        )
        key = (k, True) if traced else (k, base)
        fn = self._jitted.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(
                self._search_impl, k=k, budget=base, traced=traced))
            self._jitted[key] = fn
        budget_vec = jnp.full((S,), 0 if base is None else base, jnp.int32)
        if rem:
            budget_vec = budget_vec + (jnp.arange(S, dtype=jnp.int32) < rem)
        idx, dist, comps = fn(self.stacked, Q, budget_vec)
        return SearchResult(idx, dist, comps)

    def _search_impl(self, stacked, Q, budget_vec, *, k: int,
                     budget: Optional[int], traced: bool):
        from jax.sharding import PartitionSpec as P

        from repro.dist.sharding import shard_map_compat

        cls, static, shard_size = self.engine_cls, self.static, self.shard_size
        traced_budget = traced

        def local(state, Qr, bvec):
            state = jax.tree_util.tree_map(lambda x: x[0], state)  # drop shard axis
            extra = {"budget_t": bvec[0]} if traced_budget else {}
            idx, dist, comps = cls.shard_search(
                state, Qr, k=k, budget=budget, static=static, **extra
            )
            off = jax.lax.axis_index("data").astype(jnp.int32) * shard_size
            idx = jnp.where(idx >= 0, idx + off, -1)  # local -> global ids
            return idx[None], dist[None], comps[None]

        fn = shard_map_compat(
            local, mesh=self.dctx.mesh,
            in_specs=(P("data"), P(), P("data")), out_specs=P("data"),
        )
        idx, dist, comps = fn(stacked, Q, budget_vec)  # (S, B, k) x2, (S, B)
        # shards are in ascending-offset order, so the running merge keeps
        # the global tie-to-lowest-index contract (DESIGN.md §10)
        mdist, midx = scan_lib.merge_topk(
            jnp.swapaxes(dist, 0, 1), jnp.swapaxes(idx, 0, 1), k=k
        )
        return midx, mdist, jnp.sum(comps, axis=0).astype(jnp.int32)

    def memory_bytes(self) -> int:
        return pytree_nbytes(self.stacked)

    # --------------------------------------------------------------- snapshot
    def snapshot_state(self):
        arrays = {
            "stacked": jax.tree_util.tree_map(np.asarray, self.stacked),
        }
        statics = {
            "engine": self.engine,
            "static": self.static,
            "shard_size": self.shard_size,
            "n": self.n,
            "search_defaults": self.search_defaults,
        }
        return arrays, statics

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "ShardedIndex":
        """Re-place the stacked per-shard state on a fresh ("data",) mesh —
        the host must expose at least as many devices as the snapshot had
        shards (same requirement as ``build``)."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        from repro.dist.sharding import search_policy

        engine = statics["engine"]
        n, shard_size = int(statics["n"]), int(statics["shard_size"])
        shards = n // shard_size
        devs = jax.devices()
        if len(devs) < shards:
            raise RuntimeError(
                f"snapshot has {shards} shards but only {len(devs)} devices"
            )
        mesh = Mesh(np.asarray(devs[:shards]), ("data",))
        stacked = jax.tree_util.tree_map(
            lambda x: jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data"))),
            arrays["stacked"],
        )
        inst = cls(
            engine=engine, engine_cls=get_index(engine), stacked=stacked,
            static=dict(statics["static"]), shard_size=shard_size, n=n,
            dctx=search_policy(mesh),
            search_defaults=dict(statics.get("search_defaults") or {}),
        )
        return inst
