"""Unified index protocol, registry, and the sharded search engine.

One ``build/search`` contract from kernels to serving (DESIGN.md §10):

* ``SearchResult`` — the triple every searcher returns.  ``idx`` (B, k)
  int32 dataset indices (-1 = no result), ``dist`` (B, k) f32 ascending,
  ``comparisons`` (B,) int32 — the engine's distance-evaluation count, the
  paper's implementation-agnostic cost metric (App. F.1).  For the scan
  engines these are original-space candidate scores; for the infinity
  engine they are embedding-space tree visits plus the two-stage rerank
  width (F.5's accounting — the k final original-metric scores attached to
  every result are reporting, not counted search work).
* ``Index`` — the protocol: ``build(X, cfg)`` / ``search(Q, k, budget)`` /
  ``memory_bytes()``.  ``cfg`` is one plain mapping describing the whole
  engine: keys matching the engine's ``build`` signature configure
  construction, keys matching its ``search`` signature become per-instance
  search defaults.
* registry — ``@register_index(name)`` + ``build(name, X, cfg)``.  The five
  built-ins ("brute", "ivf_flat", "ivf_pq", "nsw", "infinity") self-register
  when their modules load; ``_ensure_builtin`` loads them on first lookup so
  importing this module stays cheap.
* ``ShardedIndex`` — the corpus row-sharded over the ``data`` axis of a
  1-axis device mesh via ``shard_map`` (``dist/sharding.py`` conventions:
  corpus rows on "data", queries replicated).  Each shard runs any
  registered engine locally; per-shard top-k lists get their global indices
  back from the shard offsets and are merged with the ``core/scan`` running
  merge, so a multi-device run returns exactly what the single-device
  engine would for exhaustive engines (see DESIGN.md §10 for the argument).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Mapping, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scan as scan_lib
from repro.core import telemetry as telem


class SearchResult(NamedTuple):
    """Uniform search answer: unpacks as (idx, dist, comparisons)."""

    idx: jax.Array  # (B, k) int32, -1 = no result
    dist: jax.Array  # (B, k) f32, ascending (ties -> lowest index)
    comparisons: jax.Array  # (B,) int32 original-space distance evaluations


@runtime_checkable
class Index(Protocol):
    """What every registered engine implements (structural — no inheritance).

    ``search``'s optional ``filter`` is a predicate spec (``core/filter``
    AST or its dict sugar, compiled against the engine's attribute store —
    the ``attrs`` cfg key at build) or a precomputed ``(n,)`` bool mask;
    engines AND it into their candidate validity so a filtered search only
    answers from passing rows (DESIGN.md §12)."""

    @classmethod
    def build(cls, X, **cfg) -> "Index": ...

    def search(self, Q, k: int = 1, *, budget: Optional[int] = None,
               filter=None) -> SearchResult: ...

    def memory_bytes(self) -> int: ...


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}
BUILTIN = ("brute", "ivf_flat", "ivf_pq", "nsw", "infinity", "sharded", "live")


def register_index(name: str):
    """Class decorator: expose an engine under a stable string key."""

    def deco(cls):
        for attr in ("build", "search", "memory_bytes"):
            if not hasattr(cls, attr):
                raise TypeError(f"{cls.__name__} lacks Index.{attr}")
        cls.registry_name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def _ensure_builtin() -> None:
    # engines self-register at module load; importing them here keeps the
    # registry lazily populated without import cycles
    import repro.core.baselines  # noqa: F401
    import repro.core.live  # noqa: F401
    import repro.core.search  # noqa: F401


def available() -> tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


def list_engines() -> dict[str, str]:
    """{registry key: one-line summary} for every registered engine — the
    operator-facing discovery surface (``serve.py --list-engines``)."""
    _ensure_builtin()
    out = {}
    for name in sorted(_REGISTRY):
        doc = (_REGISTRY[name].__doc__ or "").strip()
        out[name] = doc.splitlines()[0].strip() if doc else ""
    return out


def get_index(name: str) -> type:
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown index {name!r}; available: {available()}") from None


def build(name: str, X, cfg: Optional[Mapping[str, Any]] = None) -> Index:
    """Build any registered engine from one config mapping.

    Keys are split against the engine's ``build`` / ``search`` signatures;
    leftover search-time keys are stored as the instance's search defaults
    (so ``registry.build("ivf_flat", X, {"num_clusters": 48, "nprobe": 8})``
    probes 8 lists on every subsequent ``search``).

    The reserved key ``attrs`` — ``{column: per-row values}`` — builds a
    columnar ``core/attrs`` store aligned with the corpus rows and attaches
    it to the instance, enabling predicate filters on ``search``.  It is
    handled HERE, once for every engine, so no engine signature carries it;
    engines with structural needs (live's slot capacity, sharded's mesh
    placement) override the ``attach_attrs`` hook.

    The reserved key ``quant`` (truthy) quantizes the corpus to int8 codes
    (``core/quant.QuantStore``) and attaches the store the same way
    (``attach_quant`` hook: live extends to slot capacity and quantizes
    upserts, sharded places codes on its mesh's data axis).  Scan engines
    (brute, ivf_flat, infinity's rerank, live's delta) then run their first
    pass on codes — 1 byte/dim read — and exactly rerank a
    ``quant.shortlist_width``-wide shortlist in f32; engines without a
    corpus-scan stage (nsw's graph walk, ivf_pq's own PQ codes) hold the
    store but search unchanged (DESIGN.md §13).

    The reserved key ``chaos`` — a ``core/chaos.FaultPlan`` or its dict
    sugar — arms deterministic fault injection (DESIGN.md §14): plain
    engines get their ``search`` wrapped with the latency/transient
    injector; sharded and live engines hold the plan and consult it at
    their own fault sites (shard death, compaction publish, delta
    overflow, snapshot corruption).  A ``build``-site fault fires here,
    after construction: the poisoned instance never escapes.
    """
    cls = get_index(name)
    cfg = dict(cfg or {})
    attr_values = cfg.pop("attrs", None)
    quant_cfg = cfg.pop("quant", None)
    chaos_cfg = cfg.pop("chaos", None)
    hook = getattr(cls, "registry_build", None)
    if hook is not None:
        inst = hook(X, cfg)
    else:
        inst = generic_registry_build(cls, X, cfg)
    if attr_values:
        from repro.core import attrs as attrs_lib

        n = int(jnp.asarray(X).shape[0])
        attach_store(inst, attrs_lib.AttributeStore.build(attr_values, n))
    if quant_cfg:
        from repro.core import quant as quant_lib

        attach_quant_store(inst, quant_lib.QuantStore.build(X))
    if chaos_cfg is not None:
        from repro.core import chaos as chaos_lib

        plan = chaos_lib.FaultPlan.from_cfg(chaos_cfg)
        plan.on_build()  # a poisoned build never escapes
        attach_chaos(inst, plan)
    return inst


def attach_store(inst, store) -> None:
    """Attach a built ``AttributeStore`` to an engine instance — through
    its ``attach_attrs`` hook when it has one (live extends to slot
    capacity, sharded places columns on the mesh), else as a plain
    ``attrs`` attribute.  Also the re-attachment path of ``store.load``."""
    hook = getattr(inst, "attach_attrs", None)
    if hook is not None:
        hook(store)
    else:
        inst.attrs = store


def attach_quant_store(inst, store) -> None:
    """Attach a built ``core/quant.QuantStore`` — through the engine's
    ``attach_quant`` hook when it has one (live extends to slot capacity,
    sharded places codes on the mesh's data axis), else as a plain
    ``quant`` attribute.  Also the re-attachment path of ``store.load``
    (format v3)."""
    hook = getattr(inst, "attach_quant", None)
    if hook is not None:
        hook(store)
    else:
        inst.quant = store


def attach_chaos(inst, plan) -> None:
    """Arm an engine instance with a ``core/chaos.FaultPlan`` — through its
    ``attach_chaos`` hook when it has one (sharded draws per-shard deaths,
    live fires compaction/delta faults itself), else by wrapping ``search``
    with the generic injector: every call first runs the plan's ``search``
    site (latency spikes sleep, transient rules raise), then the engine."""
    hook = getattr(inst, "attach_chaos", None)
    if hook is not None:
        hook(plan)
        return
    inst.chaos = plan
    orig = inst.search

    def chaotic_search(*args, **kwargs):
        plan.on_search()
        return orig(*args, **kwargs)

    inst.search = chaotic_search


def side_store_bytes(inst) -> int:
    """Bytes of the per-instance side stores (``attrs`` columns, ``quant``
    codes) — every engine's ``memory_bytes`` adds this so the report covers
    ALL device-resident arrays, not just the engine's own state."""
    total = 0
    for name in ("attrs", "quant"):
        store = getattr(inst, name, None)
        if store is not None:
            total += store.memory_bytes()
    return int(total)


def generic_registry_build(cls, X, cfg: Optional[Mapping[str, Any]]) -> Index:
    cfg = dict(cfg or {})
    bkeys = set(inspect.signature(cls.build).parameters) - {"cls", "X"}
    skeys = (set(inspect.signature(cls.search).parameters) - {"self", "Q", "k"}) | {"budget"}
    bkw = {k: cfg.pop(k) for k in list(cfg) if k in bkeys}
    skw = {k: cfg.pop(k) for k in list(cfg) if k in skeys}
    if cfg:
        raise TypeError(
            f"{cls.registry_name}: unknown cfg keys {sorted(cfg)} "
            f"(build takes {sorted(bkeys)}, search takes {sorted(skeys)})"
        )
    inst = cls.build(X, **bkw)
    inst.search_defaults = skw
    return inst


def resolve(value, defaults: Optional[Mapping[str, Any]], key: str, fallback=None):
    """Search-kwarg resolution order: explicit arg > stored default > fallback."""
    if value is not None:
        return value
    if defaults and defaults.get(key) is not None:
        return defaults[key]
    return fallback


def pytree_nbytes(tree) -> int:
    """Total device bytes of every array leaf (the memory_bytes() helper)."""
    return int(
        sum(
            np.prod(x.shape) * jnp.dtype(x.dtype).itemsize
            for x in jax.tree_util.tree_leaves(tree)
            if hasattr(x, "shape")
        )
    )


def default_merge_shard_static(statics: list[dict]) -> dict:
    """Per-shard static configs must agree (engines with per-shard statics —
    e.g. tree depth — override ``merge_shard_static``)."""
    merged = dict(statics[0])
    for s in statics[1:]:
        if s != merged:
            raise ValueError(f"shard statics disagree: {merged} vs {s}")
    return merged


# ---------------------------------------------------------------------------
# sharded engine
# ---------------------------------------------------------------------------

def _stack_shard_states(states: list):
    """Stack per-shard state pytrees along a new leading shard axis.

    Leaves whose trailing shapes differ across shards (IVF's padded inverted
    lists — Lmax follows the largest cluster) are first padded to the
    elementwise max shape: int leaves with -1 (the codebase-wide "invalid
    id"), float leaves with +inf ("no candidate").
    """
    flats, treedefs = zip(*(jax.tree_util.tree_flatten(s) for s in states))
    stacked = []
    for leaves in zip(*flats):
        leaves = [jnp.asarray(l) for l in leaves]
        shapes = [l.shape for l in leaves]
        if len(set(shapes)) > 1:
            target = tuple(max(s[i] for s in shapes) for i in range(len(shapes[0])))
            fill = -1 if jnp.issubdtype(leaves[0].dtype, jnp.integer) else jnp.inf
            leaves = [
                jnp.pad(
                    l,
                    [(0, t - s) for s, t in zip(l.shape, target)],
                    constant_values=fill,
                )
                for l in leaves
            ]
        stacked.append(jnp.stack(leaves))
    return jax.tree_util.tree_unflatten(treedefs[0], stacked)


@register_index("sharded")
@dataclasses.dataclass
class ShardedIndex:
    """Any registered engine, data-parallel over corpus shards.

    ``build`` splits the corpus into ``shards`` equal row-slices, builds one
    inner engine per shard, and stacks the per-shard device state along a
    leading shard axis that lives on the mesh's ``data`` axis.  ``search``
    runs every shard's engine locally under ``shard_map`` (queries
    replicated), restores global indices from the shard offsets, and merges
    the per-shard top-k lists with the ``core/scan`` running merge.
    Comparisons are summed across shards — the work really done — and a
    per-query ``budget`` is split evenly across shards so the summed count
    respects the same bound as a single-device engine (engine-cfg knobs
    like ``rerank`` remain per shard).
    """

    engine: str
    engine_cls: type
    stacked: Any  # pytree; every leaf (S, ...), placed on the mesh's data axis
    static: dict
    shard_size: int
    n: int
    dctx: Any  # dist.sharding.DistCtx over a ("data",) mesh
    search_defaults: dict = dataclasses.field(default_factory=dict)
    attrs: Any = None  # core/attrs store, columns placed on the data axis
    quant: Any = None  # core/quant store, codes placed on the data axis
    chaos: Any = None  # core/chaos.FaultPlan — per-shard fault injection
    _jitted: dict = dataclasses.field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------ build
    @classmethod
    def registry_build(cls, X, cfg: Optional[Mapping[str, Any]] = None) -> "ShardedIndex":
        cfg = dict(cfg or {})
        engine = cfg.pop("engine", "brute")
        shards = int(cfg.pop("shards", 2))
        mesh = cfg.pop("mesh", None)
        engine_cfg = cfg.pop("engine_cfg", None)
        if engine_cfg is None:
            engine_cfg = cfg  # remaining keys configure the inner engine
        elif cfg:
            raise TypeError(f"sharded: pass engine keys via engine_cfg OR inline, not both: {sorted(cfg)}")
        return cls.build(X, engine=engine, shards=shards, mesh=mesh, engine_cfg=engine_cfg)

    @classmethod
    def build(
        cls, X, *, engine: str = "brute", shards: int = 2, mesh=None,
        engine_cfg: Optional[Mapping[str, Any]] = None,
    ) -> "ShardedIndex":
        from repro.dist.sharding import search_policy

        X = jnp.asarray(X, jnp.float32)
        n = X.shape[0]
        shards = int(shards)
        if shards < 1 or n % shards != 0:
            raise ValueError(f"corpus rows ({n}) must divide evenly into shards ({shards})")
        engine_cls = get_index(engine)
        if not hasattr(engine_cls, "shard_state"):
            raise TypeError(f"engine {engine!r} does not support sharding (no shard_state)")
        if mesh is None:
            from jax.sharding import Mesh

            devs = jax.devices()
            if len(devs) < shards:
                raise RuntimeError(
                    f"need {shards} devices for {shards} shards, have {len(devs)}"
                )
            mesh = Mesh(np.asarray(devs[:shards]), ("data",))
        if mesh.shape.get("data", 1) != shards:
            raise ValueError(f"mesh data axis {mesh.shape} != shards {shards}")
        shard_size = n // shards
        states, statics = [], []
        for s in range(shards):
            # bare `build` resolves to the module-level registry function
            # (the class namespace is not an enclosing scope)
            inner = build(engine, X[s * shard_size : (s + 1) * shard_size], engine_cfg)
            st, stat = inner.shard_state()
            states.append(st)
            statics.append(stat)
        merge = getattr(engine_cls, "merge_shard_static", None)
        static = merge(statics) if merge is not None else default_merge_shard_static(statics)
        from jax.sharding import NamedSharding, PartitionSpec as P

        # place the per-shard state on the data axis ONCE so serving-time
        # searches never re-transfer it
        stacked = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("data"))),
            _stack_shard_states(states),
        )
        return cls(
            engine=engine,
            engine_cls=engine_cls,
            stacked=stacked,
            static=static,
            shard_size=shard_size,
            n=n,
            dctx=search_policy(mesh),
        )

    # -------------------------------------------------------------- attrs
    def attach_attrs(self, store) -> None:
        """Pin the attribute columns on the mesh's data axis: compiled
        predicate masks are then row-sharded alongside the corpus, and the
        per-shard slice reaches each shard's engine with zero reshuffling."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        if store.n != self.n:
            raise ValueError(f"attrs cover {store.n} rows != corpus {self.n}")
        store.place(NamedSharding(self.dctx.mesh, P("data")))
        self.attrs = store

    def attach_quant(self, store) -> None:
        """Pin the int8 corpus codes on the mesh's data axis: each shard's
        engine receives its own (shard_size, d) code slice (plus the
        replicated scale vector) with zero reshuffling — the quantized twin
        of ``attach_attrs``.  Only engines whose ``shard_search`` takes a
        ``quant=`` operand can use it; attaching to others would silently
        scan f32, so it raises instead."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        if store.rows != self.n:
            raise ValueError(
                f"quant codes cover {store.rows} rows != corpus {self.n}"
            )
        if not getattr(self.engine_cls, "shard_supports_quant", False):
            raise TypeError(
                f"engine {self.engine!r} has no quantized shard scan "
                "(shard_supports_quant)"
            )
        store.place(NamedSharding(self.dctx.mesh, P("data")))
        self.quant = store

    def attach_chaos(self, plan) -> None:
        """Hold the fault plan: ``search`` consults it per call — latency /
        transient rules via the generic ``search`` site, then the ``shard``
        site, raising ``ShardFault`` for any drawn-dead shard the caller
        did not already exclude via ``shard_alive``."""
        self.chaos = plan

    # ----------------------------------------------------------------- search
    def search(self, Q, k: int = 1, *, budget: Optional[int] = None,
               filter=None, shard_alive=None) -> SearchResult:
        """``shard_alive`` — optional per-shard bool sequence: False shards
        are masked out of the merge (their candidates become (-1, +inf) and
        their comparisons 0), the degraded-serving path of DESIGN.md §14.
        The per-query budget split stays S-way, so surviving shards do not
        silently inherit the dead shard's comparison share."""
        from repro.core import filter as filter_lib

        S_total = self.dctx.mesh.shape["data"]
        if shard_alive is not None:
            shard_alive = tuple(bool(a) for a in shard_alive)
            if len(shard_alive) != S_total:
                raise ValueError(
                    f"shard_alive covers {len(shard_alive)} shards, have {S_total}"
                )
            if not any(shard_alive):
                raise ValueError("shard_alive: at least one shard must survive")
        if self.chaos is not None:
            self.chaos.on_search()
            excluded = (set() if shard_alive is None else
                        {i for i, a in enumerate(shard_alive) if not a})
            dead = self.chaos.dead_shards(S_total) - excluded
            dead = {s for s in dead if s < S_total}
            if dead:
                from repro.core import chaos as chaos_lib

                raise chaos_lib.ShardFault(min(dead), n_shards=S_total)

        budget = resolve(budget, self.search_defaults, "budget")
        filter = resolve(filter, self.search_defaults, "filter")
        mask = filter_lib.resolve_mask(filter, self.attrs, self.n)
        S = self.dctx.mesh.shape["data"]
        base = rem = None
        if budget is not None:
            # the budget is per QUERY, not per shard: split it so the summed
            # comparisons stay within the requested bound (floor of 1 per
            # shard — a budget below the shard count degrades to 1 each).
            # The remainder goes to the first ``rem`` shards as a traced
            # per-shard vector so the summed budget is TIGHT, not floored —
            # engines whose budget knob is traceable (infinity's
            # max_comparisons) consume base+1 there; engines with static
            # knobs (IVF's nprobe, NSW's max_steps) resolve from the floor.
            base, rem = divmod(int(budget), S)
            if base == 0:
                base, rem = 1, 0
        Q = jnp.asarray(Q, jnp.float32)
        k = int(k)
        # one compile per knob setting (serving discipline).  Engines whose
        # budget is a traced operand compile ONE program for every budget
        # value (the point of the traced while-gate in vptree) — only the
        # budgeted/unbudgeted distinction stays in their key.
        traced = budget is not None and getattr(
            self.engine_cls, "shard_traced_budget", False
        )
        # engines that size a static knob off the filter's selectivity
        # (infinity's scaled rerank width) get the GLOBAL passing fraction,
        # power-of-two bucketed so it stays a bounded jit-key dimension
        # (cached per predicate: one device sync per distinct filter)
        sel = None
        if mask is not None and getattr(
            self.engine_cls, "shard_uses_selectivity", False
        ):
            sel = filter_lib.bucket_selectivity(
                filter_lib.cached_selectivity(filter, self.attrs, mask))
        key = (k, True if traced else base, mask is not None,
               self.quant is not None, sel, shard_alive)
        fn = self._jitted.get(key)
        if fn is None:
            telem.count("jit_cache_misses_total", engine=self.engine,
                        scope="shard", k=k)
            fn = jax.jit(functools.partial(
                self._search_impl, k=k, budget=base, traced=traced, sel=sel,
                has_mask=mask is not None, has_quant=self.quant is not None,
                shard_alive=shard_alive))
            self._jitted[key] = fn
        else:
            telem.count("jit_cache_hits_total", engine=self.engine,
                        scope="shard", k=k)
        if shard_alive is not None and not all(shard_alive):
            telem.count("shard_masked_total",
                        sum(1 for a in shard_alive if not a),
                        engine=self.engine)
        budget_vec = jnp.full((S,), 0 if base is None else base, jnp.int32)
        if rem:
            budget_vec = budget_vec + (jnp.arange(S, dtype=jnp.int32) < rem)
        args = (self.stacked, Q, budget_vec)
        if mask is not None:
            args = args + (mask,)
        if self.quant is not None:
            codes, scales, sqnorms = self.quant.device_view()
            args = args + (codes, scales, sqnorms)
        # one span covers shard dispatch + per-shard merge: the shard_map
        # body is traced code, so the host boundary is the whole program
        with telem.span("shard_dispatch", engine=self.engine,
                        shards=S_total):
            idx, dist, comps = fn(*args)
            if telem.enabled():
                jax.block_until_ready(comps)
        return SearchResult(idx, dist, comps)

    def _search_impl(self, stacked, Q, budget_vec, *rest, k: int,
                     budget: Optional[int], traced: bool,
                     sel: Optional[float] = None, has_mask: bool = False,
                     has_quant: bool = False, shard_alive=None):
        from jax.sharding import PartitionSpec as P

        from repro.dist.sharding import shard_map_compat

        cls, static, shard_size = self.engine_cls, self.static, self.shard_size
        traced_budget = traced

        def local(state, Qr, bvec, *rest):
            state = jax.tree_util.tree_map(lambda x: x[0], state)  # drop shard axis
            rest = list(rest)
            extra = {"budget_t": bvec[0]} if traced_budget else {}
            if has_mask:
                # the (shard_size,) row slice of the global mask: the shard's
                # engine ANDs it into its own candidate validity, and local
                # ids stay local — the offset fix below is unchanged
                extra["valid"] = rest.pop(0)
                if sel is not None:
                    extra["sel"] = sel
            if has_quant:
                # (shard_size, d) code slice + replicated scales + the row
                # slice of the precomputed sq-norms: the shard's engine runs
                # its quantized first pass on ITS rows only
                extra["quant"] = (rest.pop(0), rest.pop(0), rest.pop(0))
            idx, dist, comps = cls.shard_search(
                state, Qr, k=k, budget=budget, static=static, **extra
            )
            off = jax.lax.axis_index("data").astype(jnp.int32) * shard_size
            idx = jnp.where(idx >= 0, idx + off, -1)  # local -> global ids
            return idx[None], dist[None], comps[None]

        in_specs = (P("data"), P(), P("data"))
        if has_mask:
            in_specs = in_specs + (P("data"),)
        if has_quant:
            in_specs = in_specs + (P("data"), P(), P("data"))
        fn = shard_map_compat(
            local, mesh=self.dctx.mesh, in_specs=in_specs, out_specs=P("data"),
        )
        args = (stacked, Q, budget_vec) + tuple(rest)
        idx, dist, comps = fn(*args)  # (S, B, k) x2, (S, B)
        if shard_alive is not None and not all(shard_alive):
            # degraded serving: the dead shards' lists become (-1, +inf)
            # no-result slots (merge_topk's padding convention) and their
            # work is not counted — the answer is exactly the merge over
            # the surviving shards' corpora
            alive = jnp.asarray(shard_alive, bool)
            idx = jnp.where(alive[:, None, None], idx, -1)
            dist = jnp.where(alive[:, None, None], dist, jnp.inf)
            comps = jnp.where(alive[:, None], comps, 0)
        # shards are in ascending-offset order, so the running merge keeps
        # the global tie-to-lowest-index contract (DESIGN.md §10)
        mdist, midx = scan_lib.merge_topk(
            jnp.swapaxes(dist, 0, 1), jnp.swapaxes(idx, 0, 1), k=k
        )
        return midx, mdist, jnp.sum(comps, axis=0).astype(jnp.int32)

    def memory_bytes(self) -> int:
        return pytree_nbytes(self.stacked) + side_store_bytes(self)

    # --------------------------------------------------------------- snapshot
    def snapshot_state(self):
        arrays = {
            "stacked": jax.tree_util.tree_map(np.asarray, self.stacked),
        }
        statics = {
            "engine": self.engine,
            "static": self.static,
            "shard_size": self.shard_size,
            "n": self.n,
            "search_defaults": self.search_defaults,
        }
        return arrays, statics

    @classmethod
    def from_snapshot(cls, arrays, statics) -> "ShardedIndex":
        """Re-place the stacked per-shard state on a fresh ("data",) mesh —
        the host must expose at least as many devices as the snapshot had
        shards (same requirement as ``build``)."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        from repro.dist.sharding import search_policy

        engine = statics["engine"]
        n, shard_size = int(statics["n"]), int(statics["shard_size"])
        shards = n // shard_size
        devs = jax.devices()
        if len(devs) < shards:
            raise RuntimeError(
                f"snapshot has {shards} shards but only {len(devs)} devices"
            )
        mesh = Mesh(np.asarray(devs[:shards]), ("data",))
        stacked = jax.tree_util.tree_map(
            lambda x: jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data"))),
            arrays["stacked"],
        )
        inst = cls(
            engine=engine, engine_cls=get_index(engine), stacked=stacked,
            static=dict(statics["static"]), shard_size=shard_size, n=n,
            dctx=search_policy(mesh),
            search_defaults=dict(statics.get("search_defaults") or {}),
        )
        return inst
