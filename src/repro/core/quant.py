"""Int8 corpus quantization: the scanned-bytes side of search (DESIGN.md §13).

Every scan in the pipeline is memory-bandwidth bound: the corpus is read
once per query batch and 4 bytes/dimension is the whole bill.  The paper's
two-stage design (approximate shortlist -> exact rerank, App. F.5) already
tolerates approximate first-pass distances, so the first pass can read
1 byte/dimension instead: per-dimension absmax symmetric int8 codes plus an
f32 scale vector, with the shortlist re-scored exactly in f32.

This module is the ONE quantization definition repo-wide:

* ``absmax_scales`` / ``encode`` / ``decode`` — symmetric absmax int8:
  ``scale = max(|x|) / 127`` (per whatever axis), ``code = clip(round(x /
  scale), -127, 127)``, ``decode = code * scale``.  ``fake_quant`` is the
  whole-tensor quantize->dequantize round-trip ``dist/compression`` models
  the gradient wire with — same formula, same clipping, same eps floor.
* ``shortlist_width`` — the rerank-width rule shared by every quantized
  engine: a first pass on codes keeps ``min(n, pow2ceil(max(4k, 32)))``
  candidates, the exact f32 rerank keeps k.  Power-of-two so the width is
  a bounded jit-key dimension (the repo-wide bucketing discipline), 4x-k
  with a floor of 32 so int8 rank inversions (bounded by scale/2 per dim)
  fall inside the shortlist — recall@10 >= 0.99 at benchmark scale.
* ``QuantStore`` — the engine-facing container: host codes ``(rows, d)``
  int8 + scales ``(d,)`` f32 with a lazily-built device mirror (the
  ``core/attrs`` / live ``device_view`` pattern: the hot query path
  re-uploads nothing until a mutation invalidates it), ``place()`` for
  ShardedIndex to pin codes on its mesh's data axis, ``take``/``set_rows``
  for the live subsystem's slot buffers, and snapshot hooks so codes ride
  inside every ``core/store`` format-v3 snapshot.

The registry key ``"quant"`` (``core/index.build``) builds one store per
engine; see ``index.attach_quant_store`` for the routing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

#: absmax floor — keeps all-zero dimensions from dividing by zero (codes
#: come out 0 and decode to exactly 0.0).  Shared with dist/compression.
EPS = 1e-30


# ---------------------------------------------------------------------------
# the quantization definition
# ---------------------------------------------------------------------------

def absmax_scales(x, axis=None, keepdims: bool = False):
    """Symmetric absmax scale(s): ``max(|x|) / 127`` along ``axis`` (None =
    whole tensor, the gradient-compression form; 0 = per-dimension, the
    corpus form; 1 + keepdims = per-row, the kernel's query form)."""
    s = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.maximum(s, EPS) / 127.0


def encode(x, scales) -> jnp.ndarray:
    """f32 -> int8 codes under ``scales`` (broadcastable against ``x``)."""
    return jnp.clip(jnp.round(x / scales), -127, 127).astype(jnp.int8)


def decode(codes, scales) -> jnp.ndarray:
    """int8 codes -> f32 under ``scales``; max error scale/2 per entry."""
    return codes.astype(jnp.float32) * scales


def fake_quant(x):
    """Whole-tensor quantize->dequantize round-trip, dtype preserved — what
    ``dist/compression`` transmits on the modeled int8 gradient wire."""
    scales = absmax_scales(x)
    return decode(encode(x, scales), scales).astype(x.dtype)


def shortlist_width(k: int, n: int, *, mult: int = 4, floor: int = 32) -> int:
    """The rerank-width rule: how many code-space candidates the exact f32
    rerank re-scores for a final top-k over n rows (DESIGN.md §13)."""
    from repro.core.scan import pow2ceil

    return min(int(n), pow2ceil(max(mult * int(k), floor)))


# ---------------------------------------------------------------------------
# the engine-facing container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QuantStore:
    """Per-dimension absmax int8 codes for a corpus (or a live slot buffer).

    ``codes`` ``(rows, d)`` int8 and ``scales`` ``(d,)`` f32 live as host
    numpy arrays (the live subsystem writes delta rows in place on upsert);
    ``device_view()`` uploads them — plus the precomputed per-row squared
    dequant norms the int8 kernel regime consumes — once per mutation.
    """

    codes: np.ndarray  # (rows, d) int8
    scales: np.ndarray  # (d,) f32
    _dev: Optional[tuple] = dataclasses.field(default=None, repr=False)
    _sharding: Any = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, X) -> "QuantStore":
        """Quantize a corpus: per-dimension scales from the corpus absmax.
        New rows added later (live upserts) reuse these scales inductively —
        the same apply-to-unseen-points argument as Phi; out-of-range values
        clip, and the exact rerank absorbs the error."""
        X = jnp.asarray(X, jnp.float32)
        scales = absmax_scales(X, axis=0)
        return cls(
            codes=np.asarray(encode(X, scales)),
            scales=np.asarray(scales, np.float32),
        )

    # -------------------------------------------------------------- accessors
    @property
    def rows(self) -> int:
        return int(self.codes.shape[0])

    @property
    def dim(self) -> int:
        return int(self.codes.shape[1])

    def invalidate(self) -> None:
        self._dev = None

    def place(self, sharding) -> None:
        """Pin the row-aligned device arrays (codes, sq-norms) onto
        ``sharding`` — ShardedIndex's data axis, so code slices reach each
        shard's engine with zero reshuffling.  Scales stay replicated."""
        self._sharding = sharding
        self.invalidate()

    def device_view(self):
        """(codes_dev (rows, d) int8, scales_dev (d,) f32, sqnorms_dev
        (rows,) f32) — ``sqnorms[i] = sum_j (codes[i,j] * scales[j])^2``,
        the candidate-norm operand of the int8 kernel regime."""
        if self._dev is None:
            import jax

            codes = jnp.asarray(self.codes)
            scales = jnp.asarray(self.scales)
            sqnorms = jnp.sum(decode(codes, scales) ** 2, axis=1)
            if self._sharding is not None:
                codes = jax.device_put(codes, self._sharding)
                sqnorms = jax.device_put(sqnorms, self._sharding)
            self._dev = (codes, scales, sqnorms)
        return self._dev

    # -------------------------------------------------------------- mutation
    def set_rows(self, start: int, X_rows, count: int) -> None:
        """Quantize ``count`` new rows in place at ``start`` with the
        EXISTING scales (live upsert hook — see ``build`` on inductive
        scale reuse)."""
        X_rows = jnp.asarray(np.asarray(X_rows, np.float32))
        self.codes[start : start + count] = np.asarray(
            encode(X_rows, jnp.asarray(self.scales))
        )
        self.invalidate()

    def take(self, idx: np.ndarray, *, capacity: Optional[int] = None
             ) -> "QuantStore":
        """Row-gathered copy under the same scales (frozen views, shard
        slices, compaction realignment), zero-padded up to ``capacity``
        rows — unoccupied slots are masked out of every scan, so their
        code content never matters."""
        idx = np.asarray(idx, np.int64)
        pad = 0 if capacity is None else int(capacity) - idx.shape[0]
        if pad < 0:
            raise ValueError(f"take: capacity {capacity} < {idx.shape[0]} rows")
        return QuantStore(
            codes=np.concatenate(
                [self.codes[idx], np.zeros((pad, self.dim), np.int8)]
            ),
            scales=self.scales.copy(),
        )

    def memory_bytes(self) -> int:
        # codes + scales + the derived device-resident sq-norm row
        return int(self.codes.nbytes + self.scales.nbytes + 4 * self.rows)

    # -------------------------------------------------------------- snapshot
    def snapshot_state(self) -> tuple[dict, dict]:
        """(arrays, statics) under the ``core/store`` hook contract — the
        store rides inside every engine snapshot as the format-v3 payload
        (sq-norms are derived, not persisted)."""
        return {"codes": self.codes, "scales": self.scales}, {}

    @classmethod
    def from_snapshot(cls, arrays: dict, statics: dict) -> "QuantStore":
        return cls(
            codes=np.asarray(arrays["codes"], np.int8),
            scales=np.asarray(arrays["scales"], np.float32),
        )
