"""Batched scan engine: k-nearest selection without the (m, n) matrix.

``topk_scan`` is the single integration point for every "distance matrix +
select k" site in the pipeline (kNN-graph build, brute-force ground truth,
IVF candidate scoring, two-stage rerank).  Two implementations with one
contract — (dists (m, k) f32 ascending, idxs (m, k) int32, -1 past the
valid candidate count, ties to the lowest index):

* ``impl='pallas'`` — the fused ``kernels/topk`` kernel: distance tiles and
  the running top-k stay in VMEM; only (m, k) reaches HBM.
* ``impl='jnp'``    — a blocked ``lax.fori_loop`` running-merge so CPU/GPU
  get the same O(m·(block + k)) peak memory: each step computes one
  (m, block) distance panel, concatenates it with the running (m, k) best
  and re-selects.  The (m, n) matrix never exists in the compiled program
  (asserted by tests/test_topk.py against the HLO).

Both paths support a per-candidate ``valid`` mask (IVF's padded inverted
lists, filter predicates, live delta slots) — masked candidates score +inf
and surface only as (-1, +inf) "no result" slots once every valid candidate
is taken.  The kernel takes the mask as a (1, n) operand (DESIGN.md §13),
so masked scans no longer fall back to the jnp path.

``topk_scan_quant`` is the int8 twin: the corpus arrives as per-dimension
absmax codes + scales (``core/quant``), the kernel path runs the int8 MXU
regime, and the jnp path dequantizes one block at a time — either way the
corpus is read at 1 byte/dim and the caller exactly reranks a pow2-widened
shortlist in f32 (``quant.shortlist_width``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import metrics as metrics_lib
from repro.core import telemetry as telem

DEFAULT_BLOCK = 4096


def pow2ceil(x: int) -> int:
    """Smallest power of two >= x — the shared width-bucketing discipline
    (live oversampling, filtered rerank scaling, serve batch buckets): a
    pow2-rounded static knob bounds jit recompilation to O(log n) keys."""
    p = 1
    while p < x:
        p *= 2
    return p


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "impl", "exclude_self", "block")
)
def topk_scan(
    Q: jax.Array,
    Y: jax.Array,
    *,
    k: int,
    metric: str = "euclidean",
    impl: str = "jnp",
    exclude_self: bool = False,
    valid: Optional[jax.Array] = None,
    block: int = DEFAULT_BLOCK,
) -> tuple[jax.Array, jax.Array]:
    """k nearest rows of Y for every row of Q, streaming over Y.

    Q (m, d), Y (n, d) -> (dists (m, k), idxs (m, k)).  ``exclude_self``
    masks global_row == global_col (Q must be Y row-aligned).  ``valid``
    (n,) bool masks candidates out — on BOTH paths: the kernel takes it as
    a per-candidate bitmask operand, so IVF/filtered/live scans stay fused.
    """
    m, d = Q.shape
    n = Y.shape[0]
    k = int(k)
    # dispatch-regime counters fire at TRACE time (this fn is jitted): they
    # count compiled programs per regime, not calls — which is exactly the
    # silent question they answer ("did this shape/metric take the kernel
    # or the fallback?"), see DESIGN.md §16
    if impl == "pallas":
        from repro.kernels.topk import ops as topk_ops

        if metric in topk_ops.SUPPORTED:
            telem.count("scan_dispatch_total", regime="pallas", metric=metric)
            return topk_ops.topk(
                Q, Y, k=k, metric=metric, exclude_self=exclude_self,
                valid=valid,
            )
    telem.count("scan_dispatch_total", regime="jnp", metric=metric)
    # jnp streaming path (also the fallback for kernel-unsupported metrics)
    fn = metrics_lib.matrix_fn(metric)
    bn = max(1, min(int(block), n))
    nb = -(-n // bn)
    Yp = jnp.pad(Y, ((0, nb * bn - n), (0, 0)))
    validp = None
    if valid is not None:
        validp = jnp.pad(valid.astype(bool), (0, nb * bn - n))
    best_d = jnp.full((m, k), jnp.inf, jnp.float32)
    best_i = jnp.full((m, k), -1, jnp.int32)

    def body(b, carry):
        best_d, best_i = carry
        yb = jax.lax.dynamic_slice_in_dim(Yp, b * bn, bn, axis=0)
        D = fn(Q, yb).astype(jnp.float32)  # (m, bn) — peak panel, not (m, n)
        cols = b * bn + jnp.arange(bn, dtype=jnp.int32)
        invalid = cols >= n
        if validp is not None:
            blk_valid = jax.lax.dynamic_slice_in_dim(validp, b * bn, bn)
            invalid = invalid | ~blk_valid
        D = jnp.where(invalid[None, :], jnp.inf, D)
        if exclude_self:
            D = jnp.where(
                cols[None, :] == jnp.arange(m, dtype=jnp.int32)[:, None],
                jnp.inf,
                D,
            )
        cat_d = jnp.concatenate([best_d, D], axis=1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(cols[None, :], (m, bn))], axis=1
        )
        neg, pos = jax.lax.top_k(-cat_d, k)
        return -neg, jnp.take_along_axis(cat_i, pos, axis=1)

    best_d, best_i = jax.lax.fori_loop(0, nb, body, (best_d, best_i))
    # +inf slots (padding, masked candidates, excluded self) are "no
    # result": their column index must not leak through.  idx -1 matches
    # the kernel and the ref oracle.
    best_i = jnp.where((best_i >= n) | jnp.isinf(best_d), -1, best_i)
    return best_d, best_i


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "impl", "block")
)
def topk_scan_quant(
    Q: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    *,
    k: int,
    metric: str = "euclidean",
    impl: str = "jnp",
    valid: Optional[jax.Array] = None,
    sqnorms: Optional[jax.Array] = None,
    block: int = DEFAULT_BLOCK,
) -> tuple[jax.Array, jax.Array]:
    """``topk_scan`` over int8 corpus codes — the quantized first pass.

    Q (m, d) f32, codes (n, d) int8, scales (d,) f32 (a
    ``core/quant.QuantStore`` view) -> the usual (dists, idxs) contract.
    ``impl='pallas'`` runs the fused int8 MXU regime (euclidean family;
    ``sqnorms`` is the store's precomputed per-row norm operand); the jnp
    path dequantizes ONE block at a time against ``metrics.matrix_fn`` —
    any metric, and the (n, d) f32 corpus never exists.  Distances are
    approximate (code-space); callers rerank a ``quant.shortlist_width``-
    wide shortlist exactly in f32 (``topk_candidates``).
    """
    m, d = Q.shape
    n = codes.shape[0]
    k = int(k)
    # trace-time regime counters, same semantics as topk_scan's
    if impl == "pallas":
        from repro.kernels.topk import ops as topk_ops

        if metric in topk_ops.QUANT_METRICS:
            telem.count("scan_dispatch_total", regime="pallas_quant",
                        metric=metric)
            return topk_ops.topk_quant(
                Q, codes, scales, k=k, metric=metric, valid=valid,
                sqnorms=sqnorms,
            )
    telem.count("scan_dispatch_total", regime="jnp_quant", metric=metric)
    fn = metrics_lib.matrix_fn(metric)
    bn = max(1, min(int(block), n))
    nb = -(-n // bn)
    Cp = jnp.pad(codes, ((0, nb * bn - n), (0, 0)))
    validp = None
    if valid is not None:
        validp = jnp.pad(valid.astype(bool), (0, nb * bn - n))
    best_d = jnp.full((m, k), jnp.inf, jnp.float32)
    best_i = jnp.full((m, k), -1, jnp.int32)

    def body(b, carry):
        best_d, best_i = carry
        cb = jax.lax.dynamic_slice_in_dim(Cp, b * bn, bn, axis=0)
        yb = cb.astype(jnp.float32) * scales[None, :]  # per-block dequant
        D = fn(Q, yb).astype(jnp.float32)
        cols = b * bn + jnp.arange(bn, dtype=jnp.int32)
        invalid = cols >= n
        if validp is not None:
            blk_valid = jax.lax.dynamic_slice_in_dim(validp, b * bn, bn)
            invalid = invalid | ~blk_valid
        D = jnp.where(invalid[None, :], jnp.inf, D)
        cat_d = jnp.concatenate([best_d, D], axis=1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(cols[None, :], (m, bn))], axis=1
        )
        neg, pos = jax.lax.top_k(-cat_d, k)
        return -neg, jnp.take_along_axis(cat_i, pos, axis=1)

    best_d, best_i = jax.lax.fori_loop(0, nb, body, (best_d, best_i))
    best_i = jnp.where((best_i >= n) | jnp.isinf(best_d), -1, best_i)
    return best_d, best_i


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(
    dists: jax.Array, idxs: jax.Array, *, k: int
) -> tuple[jax.Array, jax.Array]:
    """Merge pre-scored per-source top-k lists into one global top-k.

    ``dists`` / ``idxs`` (B, S, kk): S sources in ascending-index-offset
    order (shard 0 holds the lowest global ids), each row already obeying
    the ``topk_scan`` contract (ascending, ties to the lowest index, -1/inf
    past the valid count).  Returns (dists (B, k), idxs (B, k)) under the
    same contract — the ``topk_scan`` running merge applied to lists that
    were scored elsewhere (the shard-merge path of ``core/index``).
    Correctness of the tie order: within the running buffer earlier
    sources occupy earlier positions, and sources arrive in ascending
    offset order, so ``lax.top_k``'s first-occurrence tie-break selects the
    lowest global index, exactly like a single-device scan.
    """
    B, S, kk = dists.shape
    best_d = jnp.full((B, k), jnp.inf, jnp.float32)
    best_i = jnp.full((B, k), -1, jnp.int32)

    def body(s, carry):
        best_d, best_i = carry
        d = jax.lax.dynamic_index_in_dim(dists, s, axis=1, keepdims=False)
        i = jax.lax.dynamic_index_in_dim(idxs, s, axis=1, keepdims=False)
        cat_d = jnp.concatenate([best_d, d.astype(jnp.float32)], axis=1)
        cat_i = jnp.concatenate([best_i, i.astype(jnp.int32)], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, k)
        return -neg, jnp.take_along_axis(cat_i, pos, axis=1)

    best_d, best_i = jax.lax.fori_loop(0, S, body, (best_d, best_i))
    return best_d, jnp.where(jnp.isinf(best_d), -1, best_i)


def topk_candidates(
    q: jax.Array,
    cand: jax.Array,
    X: jax.Array,
    *,
    k: int,
    metric: str,
    impl: str = "jnp",
) -> tuple[jax.Array, jax.Array]:
    """Exact top-k over a gathered candidate list (one query).

    q (d,), cand (C,) int32 dataset indices with -1 padding, X (n, d) ->
    (idx (k,) dataset indices or -1, dists (k,) ascending).  The shortlist
    scoring pattern shared by IVF probing, IVF-PQ rerank and the two-stage
    rerank; vmap over queries.  ``impl`` reaches ``topk_scan`` (the
    kernel/jnp dispatch) — callers that score one query at a time outside a
    vmap can route through the fused kernel tile regime.
    """
    d, pos = topk_scan(
        q[None], X[jnp.maximum(cand, 0)], k=k, metric=metric, impl=impl,
        valid=cand >= 0,
    )
    idx = jnp.where(pos[0] >= 0, cand[jnp.maximum(pos[0], 0)], -1)
    return idx, d[0]


def quant_candidates(
    q: jax.Array,
    cand: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    *,
    k: int,
    metric: str,
    impl: str = "jnp",
) -> tuple[jax.Array, jax.Array]:
    """``topk_candidates`` on int8 codes: approximate top-k over a gathered
    candidate list, scored against the dequantized codes (one query; vmap
    over a batch).  The quantized engines' shortlist-within-a-shortlist —
    e.g. IVF's probed members, the infinity rerank's tree frontier — before
    the exact f32 rerank."""
    gathered = codes[jnp.maximum(cand, 0)].astype(jnp.float32) * scales[None, :]
    d, pos = topk_scan(
        q[None], gathered, k=k, metric=metric, impl=impl, valid=cand >= 0,
    )
    idx = jnp.where(pos[0] >= 0, cand[jnp.maximum(pos[0], 0)], -1)
    return idx, d[0]
