"""Deterministic fault injection for the serving stack (DESIGN.md §14).

A ``FaultPlan`` scripts failures so tests and ``benchmarks/bench_fault.py``
can reproduce them byte-for-byte: every draw is a pure function of
``(seed, site, rule, per-site call number)`` — no global RNG, no wall
clock — so the same plan against the same call sequence injects the same
faults on every run.

The plan reaches an engine through the reserved registry cfg key
``chaos`` (``index.build`` pops it, like ``attrs`` / ``quant``): plain
engines get their ``search`` wrapped with the generic latency/transient
injector; ``ShardedIndex`` and ``LiveIndex`` hold the plan and consult it
at their own fault sites (per-shard death, compaction publish, delta
overflow).  ``core/store.save`` consults the engine's plan to corrupt a
just-written snapshot (bit-flip / truncation / member drop) — what the
sha256 manifest added in DESIGN.md §14 must catch on restore.

Sites and what fires there:

==========  ===============================================================
``search``  every ``search()`` entry — ``latency`` rules sleep ``ms``,
            ``error`` rules raise ``TransientFault``
``shard``   ``ShardedIndex.search`` — rules (or ``kill_shard``) mark shard
            ids dead; searching a dead, non-excluded shard raises
            ``ShardFault(shard)``
``build``   ``index.build`` after construction — raises ``BuildFault``
            (a poisoned build: the instance never escapes)
``compact`` ``LiveIndex.compact`` just before the atomic publish — raises
            ``CompactFault`` (all rebuild work done, crash before the swap)
``delta``   ``LiveIndex.upsert`` entry — raises ``DeltaOverflow``
``snapshot``  ``core/store.save`` after the commit — corrupts the arrays
            member on disk (``mode``: bitflip / truncate / drop)
``slow_search``  the async runtime's per-batch dispatch
            (``launch/runtime.py``, DESIGN.md §18) — ``latency`` rules
            sleep ``ms`` *inside* the dispatch window (deadline misses
            accrue, the circuit breaker's trip condition), ``error``
            rules raise ``TransientFault`` at the runtime level.  Kept
            separate from ``search`` so overload experiments slow the
            serving path without also arming the engine-level injector.
==========  ===============================================================

Rules fire by probability (``rate``, an independent deterministic draw per
call) or by window (``start``/``stop`` in per-site call numbers — dead /
firing while ``start <= callno < stop``).  ``kill_shard`` / ``revive_shard``
are imperative toggles for tests that want exact control mid-run.

Every injected fault ticks ``plan.counters`` (by ``site:kind``) so the
serving layer can surface injection totals next to its own retry/recovery
counters in ``stats()``.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import threading
import time
from typing import Optional


class FaultError(RuntimeError):
    """Base of every injected fault — catch this to catch chaos."""


class TransientFault(FaultError):
    """Whole-engine failure expected to pass on retry (rate-based draws
    redraw per call; window-based ones clear when the window ends)."""


class ShardFault(FaultError):
    """One shard of a ``ShardedIndex`` failed; ``shard`` names it so the
    serving controller can mask it out and answer from the survivors."""

    def __init__(self, shard: int, *, n_shards: int):
        self.shard = int(shard)
        self.n_shards = int(n_shards)
        super().__init__(f"injected: shard {shard}/{n_shards} is down")


class BuildFault(FaultError):
    """Index construction was poisoned — the instance never escaped."""


class CompactFault(FaultError):
    """Compaction died after the rebuild, before the atomic publish."""


class DeltaOverflow(FaultError):
    """The delta buffer rejected a write (simulated exhaustion)."""


@dataclasses.dataclass
class Rule:
    """One scripted fault source; see the module table for sites/kinds."""

    site: str  # search | shard | build | compact | delta | snapshot | slow_search
    kind: str = "error"  # "error" | "latency" (search/slow_search) | ignored for snapshot
    rate: float = 0.0  # per-call firing probability (deterministic draw)
    start: Optional[int] = None  # with stop: fire while start <= callno < stop
    stop: Optional[int] = None
    shard: Optional[int] = None  # site="shard": which shard dies (None = drawn per shard)
    ms: float = 0.0  # kind="latency": injected spike
    mode: str = "bitflip"  # site="snapshot": bitflip | truncate | drop

    _SITES = ("search", "shard", "build", "compact", "delta", "snapshot",
              "slow_search")

    def __post_init__(self):
        if self.site not in self._SITES:
            raise ValueError(f"chaos rule: unknown site {self.site!r} "
                             f"(one of {self._SITES})")
        if self.rate == 0.0 and self.start is None:
            raise ValueError(
                f"chaos rule on {self.site!r} never fires: give a rate or a "
                "[start, stop) window")


def _draw(seed: int, site: str, rule_no: int, callno: int, extra: int = 0) -> float:
    """Uniform [0, 1) from a stable hash — the deterministic coin flip."""
    key = f"{seed}:{site}:{rule_no}:{callno}:{extra}".encode()
    h = int.from_bytes(hashlib.blake2b(key, digest_size=8).digest(), "big")
    return h / 2.0 ** 64


class FaultPlan:
    """A seeded, scriptable schedule of failures (see module docstring).

    Construct with ``Rule`` instances or their dict sugar::

        FaultPlan(seed=0, rules=[
            {"site": "search", "kind": "latency", "rate": 0.1, "ms": 20},
            {"site": "shard", "shard": 1, "start": 4, "stop": 12},
            {"site": "snapshot", "rate": 1.0, "mode": "truncate"},
        ])

    The plan is stateful only in its per-site call counters (and the
    imperative ``kill_shard`` set) — two plans with equal seed/rules fed
    the same call sequence inject identically.
    """

    def __init__(self, seed: int = 0, rules=(), sleep=time.sleep):
        self.seed = int(seed)
        self.rules = [r if isinstance(r, Rule) else Rule(**r) for r in rules]
        self.calls: collections.Counter = collections.Counter()
        self.counters: collections.Counter = collections.Counter()
        self._killed: set[int] = set()
        self._sleep = sleep  # injectable for tests that must not wait
        # the async runtime (DESIGN.md §18) consults the plan from ingress
        # worker threads concurrently with the dispatch thread: per-site
        # call numbers and injection counters must not lose increments
        # (Counter += is a read-modify-write)
        self._lock = threading.Lock()

    @classmethod
    def from_cfg(cls, spec) -> "FaultPlan":
        """The reserved-cfg-key entry point: pass a built plan through, or
        build one from ``{"seed": ..., "rules": [...]}``."""
        if isinstance(spec, FaultPlan):
            return spec
        if isinstance(spec, dict):
            return cls(**spec)
        raise TypeError(
            f"chaos cfg must be a FaultPlan or a dict, got {type(spec).__name__}"
        )

    # ------------------------------------------------------------- internals
    def _tick(self, site: str) -> int:
        with self._lock:
            callno = self.calls[site]
            self.calls[site] += 1
            return callno

    def _fires(self, rule: Rule, rule_no: int, callno: int, extra: int = 0) -> bool:
        if rule.start is not None:
            stop = rule.stop if rule.stop is not None else float("inf")
            if rule.start <= callno < stop:
                return True
        if rule.rate > 0.0:
            return _draw(self.seed, rule.site, rule_no, callno, extra) < rule.rate
        return False

    def _count(self, rule: Rule) -> None:
        with self._lock:
            self.counters[f"{rule.site}:{rule.kind}"] += 1

    def _search_like(self, site: str) -> None:
        """Shared latency/transient injector for the per-call sites."""
        callno = self._tick(site)
        for i, rule in enumerate(self.rules):
            if rule.site != site or not self._fires(rule, i, callno):
                continue
            self._count(rule)
            if rule.kind == "latency":
                self._sleep(rule.ms / 1e3)
            else:
                raise TransientFault(
                    f"injected: {site} call {callno} failed")

    # ----------------------------------------------------------- fault sites
    def on_search(self) -> None:
        """Per-call latency spikes and transient whole-engine failures."""
        self._search_like("search")

    def on_slow_search(self) -> None:
        """The async runtime's dispatch-level site (DESIGN.md §18):
        ``latency`` rules stretch the dispatch window (stacking deadline
        misses — the breaker's trip fuel), ``error`` rules fail the whole
        batch at the runtime level.  Separate call counter from ``search``
        so engine-level and runtime-level scripts compose independently."""
        self._search_like("slow_search")

    def dead_shards(self, n_shards: int) -> set[int]:
        """Shard ids dead for THIS call (ticks the ``shard`` site once)."""
        callno = self._tick("shard")
        dead = set(self._killed)
        for i, rule in enumerate(self.rules):
            if rule.site != "shard":
                continue
            if rule.shard is not None:
                if self._fires(rule, i, callno):
                    dead.add(rule.shard % n_shards)
            else:  # independent draw per shard
                for s in range(n_shards):
                    if self._fires(rule, i, callno, extra=s):
                        dead.add(s)
        with self._lock:
            self.counters["shard:down"] += len(dead)
        return dead

    def kill_shard(self, shard: int) -> None:
        """Imperative kill: the shard stays dead until ``revive_shard``."""
        self._killed.add(int(shard))

    def revive_shard(self, shard: int) -> None:
        self._killed.discard(int(shard))

    def on_build(self) -> None:
        callno = self._tick("build")
        for i, rule in enumerate(self.rules):
            if rule.site == "build" and self._fires(rule, i, callno):
                self._count(rule)
                raise BuildFault(f"injected: build {callno} poisoned")

    def on_compact(self) -> None:
        callno = self._tick("compact")
        for i, rule in enumerate(self.rules):
            if rule.site == "compact" and self._fires(rule, i, callno):
                self._count(rule)
                raise CompactFault(
                    f"injected: compaction {callno} died before publish")

    def on_delta(self) -> None:
        callno = self._tick("delta")
        for i, rule in enumerate(self.rules):
            if rule.site == "delta" and self._fires(rule, i, callno):
                self._count(rule)
                raise DeltaOverflow(
                    f"injected: delta buffer overflow at upsert {callno}")

    # ------------------------------------------------------ snapshot corruption
    def corrupt_snapshot(self, path: str, arrays_file: str) -> Optional[str]:
        """Called by ``core/store.save`` after the commit: corrupt the
        arrays member per the first firing ``snapshot`` rule.  Returns the
        mode applied (None = clean save)."""
        callno = self._tick("snapshot")
        for i, rule in enumerate(self.rules):
            if rule.site == "snapshot" and self._fires(rule, i, callno):
                with self._lock:
                    self.counters[f"snapshot:{rule.mode}"] += 1
                corrupt_snapshot(path, arrays_file=arrays_file,
                                 mode=rule.mode, seed=self.seed + callno)
                return rule.mode
        return None

    # -------------------------------------------------------------- reporting
    def stats(self) -> dict:
        """Injected-fault totals by ``site:kind`` plus per-site call counts —
        what ``SearchServer.stats()`` surfaces under ``chaos``."""
        with self._lock:
            return {
                "injected": dict(self.counters),
                "calls": dict(self.calls),
                "killed_shards": sorted(self._killed),
            }


def corrupt_snapshot(
    path: str, *, arrays_file: Optional[str] = None, mode: str = "bitflip",
    seed: int = 0,
) -> str:
    """Deterministically damage a ``core/store`` snapshot on disk — the
    direct test harness (the plan-driven path calls this too).

    ``mode``: ``bitflip`` XORs one byte at a seed-derived offset,
    ``truncate`` halves the file, ``drop`` unlinks it.  Returns the path of
    the member damaged.
    """
    if arrays_file is None:
        import json

        with open(os.path.join(path, "meta.json")) as f:
            arrays_file = json.load(f)["arrays"]
    member = os.path.join(path, arrays_file)
    if mode == "drop":
        os.unlink(member)
        return member
    size = os.path.getsize(member)
    if mode == "truncate":
        with open(member, "r+b") as f:
            f.truncate(size // 2)
        return member
    if mode == "bitflip":
        # keep clear of the npz central directory tail so the zip still
        # opens — the sha256 manifest, not zipfile, must be the detector
        off = int(_draw(seed, "corrupt", 0, 0) * max(1, size // 2))
        with open(member, "r+b") as f:
            f.seek(off)
            byte = f.read(1)
            f.seek(off)
            f.write(bytes([byte[0] ^ 0xFF]))
        return member
    raise ValueError(f"corrupt_snapshot: unknown mode {mode!r}")
