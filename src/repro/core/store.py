"""Snapshot persistence for every registry engine (DESIGN.md §11/§12).

One directory per snapshot:

* ``arrays-<id>.npz`` — every array leaf of the engine, flattened to
  ``/``-joined path keys (nested dicts and lists of dicts — e.g. the Phi
  MLP's ``layers/0/w`` — round-trip through the same paths).  Format v2
  namespaces the engine's tree under ``engine/`` and, when the engine
  carries a ``core/attrs`` attribute store, its columns under ``attrs/``;
  format v3 adds the ``core/quant`` int8 codes + scales under ``quant/``.
* ``meta.json``   — ``{"format_version", "engine", "arrays", "statics",
  "attrs_statics", "quant_statics"}``; ``arrays`` names the npz generation
  this meta commits.  Statics are plain-JSON engine config (tuples become
  lists; the engine's ``from_snapshot`` re-tuples what it needs;
  ``Infinity`` floats survive via Python json's literal).

Engines participate through two hooks, mirroring the ``shard_state``
pattern: ``snapshot_state() -> (arrays_tree, statics)`` and
``from_snapshot(arrays_tree, statics) -> instance``.  The attribute store
is persisted HERE, once for every engine — engines never see it in their
hooks; ``load`` re-attaches it through ``index.attach_store`` (live
re-extends to slot capacity, sharded re-places on its mesh).
``save``/``load`` are the only writers/readers, so the on-disk format has
a single owner.

Versioning: the reader accepts every version it knows how to read
(``1`` — pre-attrs flat layout — ``2``, and ``3``) and REJECTS a snapshot
whose ``format_version`` exceeds ``FORMAT_VERSION`` with a clear error
instead of misreading a future layout.

Crash safety: each save writes a FRESH ``arrays-<id>.npz`` and then
commits by atomically replacing ``meta.json`` (which names that arrays
file) — the meta replace is the single commit point, so a save that dies
at any step leaves the previous snapshot fully intact and loadable; stale
arrays files are swept only after the commit.

Integrity (DESIGN.md §14): ``save`` records a sha256 manifest —
``meta["sha256"][arrays_file]`` — the same content-hash idiom as
``train/checkpoint.py``.  ``load`` and ``verify`` check the members UP
FRONT: a missing / zero-length / digest-mismatched arrays file raises one
clear ``SnapshotCorruption`` (a ``ValueError``) naming the member, instead
of failing deep inside ``np.load``.  Snapshots written before the manifest
existed (any version) still load — they just skip the digest check.
Chaos: when the engine carries a ``core/chaos.FaultPlan`` with a
``snapshot`` rule, ``save`` corrupts the just-committed arrays member
(bit-flip / truncation / drop) so the self-healing path can be scripted.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import tempfile
import uuid
from typing import Any

import numpy as np

from repro.core import index as index_lib
from repro.core import telemetry as telem


def _snap_span(op: str):
    """Time a snapshot operation under the telemetry ``snapshot`` stage
    (DESIGN.md §16) — the span closes with ``error=True`` when the body
    raises (e.g. ``SnapshotCorruption``), so failed verifies are visible."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with telem.span("snapshot", op=op):
                return fn(*a, **kw)
        return wrapper
    return deco

FORMAT_VERSION = 3
_META = "meta.json"


class SnapshotCorruption(ValueError):
    """A snapshot member is missing, empty, or fails its sha256 — the
    restore path's single corruption signal (DESIGN.md §14)."""


# ---------------------------------------------------------------------------
# array-tree <-> flat npz keys
# ---------------------------------------------------------------------------

def flatten_arrays(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    """Nested dicts / lists of arrays -> {path: array}.  List positions
    become numeric path parts, restored as lists by ``unflatten_arrays``."""
    out: dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for key, val in tree.items():
            if "/" in str(key):
                raise ValueError(f"snapshot keys may not contain '/': {key!r}")
            out.update(flatten_arrays(val, f"{prefix}{key}/"))
    elif isinstance(tree, (list, tuple)):
        for i, val in enumerate(tree):
            out.update(flatten_arrays(val, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def unflatten_arrays(flat: dict[str, np.ndarray]) -> Any:
    """Inverse of ``flatten_arrays``: all-numeric sibling keys become a list
    (in index order), everything else a dict."""
    if list(flat.keys()) == [""]:
        return flat[""]
    groups: dict[str, dict] = {}
    for key, val in flat.items():
        head, _, rest = key.partition("/")
        groups.setdefault(head, {})[rest] = val
    if groups and all(k.isdigit() for k in groups):
        return [unflatten_arrays(groups[k]) for k in sorted(groups, key=int)]
    return {k: unflatten_arrays(v) for k, v in groups.items()}


# ---------------------------------------------------------------------------
# engine hooks
# ---------------------------------------------------------------------------

def engine_snapshot_state(engine) -> tuple[Any, dict]:
    """(arrays_tree, statics) of any registered engine instance."""
    hook = getattr(engine, "snapshot_state", None)
    if hook is None:
        raise TypeError(
            f"{type(engine).__name__} does not support snapshots "
            "(no snapshot_state)"
        )
    return hook()


def engine_from_snapshot(name: str, arrays: Any, statics: dict):
    """Rebuild an engine instance from its snapshot pieces."""
    cls = index_lib.get_index(name)
    hook = getattr(cls, "from_snapshot", None)
    if hook is None:
        raise TypeError(f"{cls.__name__} does not support snapshots (no from_snapshot)")
    return hook(arrays, statics)


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

@_snap_span("save")
def save(engine, path: str) -> str:
    """Write ``engine`` to the snapshot directory ``path``; returns it."""
    name = getattr(engine, "registry_name", None)
    if name is None:
        raise TypeError(f"{type(engine).__name__} is not a registered engine")
    arrays, statics = engine_snapshot_state(engine)
    payload = {"engine": arrays}
    attrs_statics = quant_statics = None
    store = getattr(engine, "attrs", None)
    if store is not None:
        attr_arrays, attrs_statics = store.snapshot_state()
        payload["attrs"] = attr_arrays
    qstore = getattr(engine, "quant", None)
    if qstore is not None:
        quant_arrays, quant_statics = qstore.snapshot_state()
        payload["quant"] = quant_arrays
    arrays_file = f"arrays-{uuid.uuid4().hex[:12]}.npz"

    os.makedirs(path, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flatten_arrays(payload))
        digest = _file_sha256(tmp)
        os.replace(tmp, os.path.join(path, arrays_file))
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    meta = {"format_version": FORMAT_VERSION, "engine": name,
            "arrays": arrays_file, "statics": statics,
            "attrs_statics": attrs_statics, "quant_statics": quant_statics,
            "sha256": {arrays_file: digest}}
    # json round-trip now: a non-serializable static should fail the save,
    # not the eventual load
    meta_str = json.dumps(meta, indent=1, default=_json_static)
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".json.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(meta_str)
        os.replace(tmp, os.path.join(path, _META))  # the commit point
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    for stale in os.listdir(path):  # sweep pre-commit generations
        if stale.startswith("arrays-") and stale.endswith(".npz") \
                and stale != arrays_file:
            os.unlink(os.path.join(path, stale))
    plan = getattr(engine, "chaos", None)
    if plan is not None:
        # scripted bit-rot lands AFTER the commit: the snapshot looks
        # published, and only the sha256 check on restore/verify exposes it
        plan.corrupt_snapshot(path, arrays_file)
    return path


def _file_sha256(fpath: str) -> str:
    h = hashlib.sha256()
    with open(fpath, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def check_members(path: str, meta: dict) -> None:
    """Up-front integrity gate shared by ``load`` and ``verify``: the
    arrays member named by ``meta`` must exist, be non-empty, and (when the
    meta carries a sha256 manifest) match its recorded digest.  Raises one
    ``SnapshotCorruption`` naming the member — never a deep np.load error."""
    arrays_file = meta.get("arrays")
    if not arrays_file:
        raise SnapshotCorruption(
            f"snapshot {path}: meta.json names no arrays member"
        )
    member = os.path.join(path, arrays_file)
    if not os.path.exists(member):
        raise SnapshotCorruption(
            f"snapshot {path}: arrays member {arrays_file!r} is missing "
            "(partially-written snapshot?)"
        )
    if os.path.getsize(member) == 0:
        raise SnapshotCorruption(
            f"snapshot {path}: arrays member {arrays_file!r} is zero-length "
            "(truncated write)"
        )
    recorded = (meta.get("sha256") or {}).get(arrays_file)
    if recorded is not None and _file_sha256(member) != recorded:
        raise SnapshotCorruption(
            f"snapshot {path}: arrays member {arrays_file!r} fails its "
            f"sha256 manifest (on-disk corruption); re-save or restore an "
            "older snapshot"
        )


@_snap_span("verify")
def verify(path: str) -> dict:
    """Validate the snapshot at ``path`` without materializing arrays:
    member presence, size, and sha256 manifest.  Returns the meta dict;
    raises ``SnapshotCorruption`` (member damage) or ``ValueError``
    (malformed/future format) — the health check the serving layer runs
    before trusting a snapshot as its restore point."""
    meta = peek(path)
    _check_version(path, meta)
    check_members(path, meta)
    return meta


def _check_version(path: str, meta: dict) -> None:
    version = meta.get("format_version")
    if not isinstance(version, int) or version < 1:
        raise ValueError(
            f"snapshot {path}: malformed format_version {version!r}"
        )
    if version > FORMAT_VERSION:
        raise ValueError(
            f"snapshot {path}: format_version {version} was written by a "
            f"newer release than this reader (v{FORMAT_VERSION}) — refusing "
            "to misread it; upgrade, or re-save with this version"
        )


@_snap_span("restore")
def load(path: str):
    """Rebuild the engine stored at ``path`` (a ``save`` directory).

    Integrity runs BEFORE any array is touched: a partially-written
    snapshot (meta.json committed but the arrays member missing or
    zero-length) or sha256-mismatched member raises ``SnapshotCorruption``
    naming the member up front."""
    with open(os.path.join(path, _META)) as f:
        meta = json.load(f)
    version = meta.get("format_version")
    _check_version(path, meta)
    check_members(path, meta)
    try:
        with np.load(os.path.join(path, meta["arrays"])) as z:
            tree = unflatten_arrays({k: z[k] for k in z.files})
    except SnapshotCorruption:
        raise
    except Exception as e:
        # pre-manifest snapshots have no sha256 to catch damage above; a
        # zip/np parse failure here is still one clear corruption signal
        raise SnapshotCorruption(
            f"snapshot {path}: arrays member {meta['arrays']!r} is "
            f"unreadable ({type(e).__name__}: {e})"
        ) from e
    if version == 1:  # pre-attrs layout: the engine tree sat at the root
        engine_arrays, attr_arrays, quant_arrays = tree, None, None
    else:
        engine_arrays = tree["engine"]
        attr_arrays = tree.get("attrs")
        quant_arrays = tree.get("quant")  # v3; absent from v2 snapshots
    inst = engine_from_snapshot(meta["engine"], engine_arrays, meta["statics"])
    if attr_arrays is not None:
        from repro.core import attrs as attrs_lib

        index_lib.attach_store(
            inst,
            attrs_lib.AttributeStore.from_snapshot(
                attr_arrays, meta["attrs_statics"]
            ),
        )
    if quant_arrays is not None:
        from repro.core import quant as quant_lib

        index_lib.attach_quant_store(
            inst,
            quant_lib.QuantStore.from_snapshot(
                quant_arrays, meta.get("quant_statics")
            ),
        )
    return inst


def peek(path: str) -> dict:
    """The snapshot's meta.json without loading arrays (ops tooling)."""
    with open(os.path.join(path, _META)) as f:
        return json.load(f)


def _json_static(obj):
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"snapshot static not JSON-serializable: {type(obj).__name__}")
