"""Synthetic dataset generators (offline stand-ins for the paper's corpora).

The container has no network access, so the paper's datasets are replaced by
distribution-matched synthetics (DESIGN.md §9.4):

* ``fashion_like``  — 784-d mixture of 10 Gaussians with per-class structured
                      means (blocky, non-negative, clipped to [0, 1]), the
                      statistical silhouette of flattened Fashion-MNIST.
* ``glove_like``    — 200-d anisotropic unit vectors in clusters (cosine
                      geometry of word embeddings).
* ``sparse_binary`` — Kosarak-style sparse binary transactions over a large
                      vocabulary with a power-law item distribution (Jaccard).
* ``deep_like``     — 96-d PCA-flavoured descriptors: decaying per-dimension
                      variance (Deep1B geometry).
* ``clustered``     — generic Gaussian mixture for unit tests.

All return float32 numpy arrays and are deterministic in (name, n, seed).
"""
from __future__ import annotations

import numpy as np


def clustered(
    n: int, d: int = 32, *, num_clusters: int = 10, spread: float = 0.3, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_clusters, d)).astype(np.float32)
    labels = rng.integers(0, num_clusters, size=n)
    X = means[labels] + spread * rng.normal(size=(n, d)).astype(np.float32)
    return X.astype(np.float32)


def fashion_like(n: int, *, d: int = 784, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(d))
    num_classes = 10
    means = []
    for c in range(num_classes):
        img = np.zeros((side, side), np.float32)
        crng = np.random.default_rng(1000 + c)
        for _ in range(6):  # blocky class template
            r0, c0 = crng.integers(0, side - 6, size=2)
            h, w = crng.integers(4, 12, size=2)
            img[r0 : r0 + h, c0 : c0 + w] += crng.uniform(0.3, 1.0)
        means.append(img.reshape(-1)[:d])
    means = np.stack(means)
    labels = rng.integers(0, num_classes, size=n)
    X = means[labels] + 0.15 * rng.normal(size=(n, d)).astype(np.float32)
    return np.clip(X, 0.0, 1.0).astype(np.float32)


def glove_like(n: int, *, d: int = 200, num_clusters: int = 50, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_clusters, d)).astype(np.float32)
    means /= np.linalg.norm(means, axis=1, keepdims=True)
    labels = rng.integers(0, num_clusters, size=n)
    X = means[labels] + 0.4 * rng.normal(size=(n, d)).astype(np.float32)
    # anisotropic scaling, then renormalize-ish (word vectors aren't unit)
    scales = np.exp(-np.arange(d) / (d / 3)).astype(np.float32)
    return (X * scales).astype(np.float32)


def sparse_binary(
    n: int, *, vocab: int = 2048, avg_items: int = 16, seed: int = 0
) -> np.ndarray:
    """Power-law sparse binary rows (Jaccard experiments)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    p /= p.sum()
    X = np.zeros((n, vocab), np.float32)
    sizes = np.maximum(1, rng.poisson(avg_items, size=n))
    for i in range(n):
        items = rng.choice(vocab, size=min(sizes[i], vocab), replace=False, p=p)
        X[i, items] = 1.0
    return X


def manifold(
    n: int, *, d: int = 96, latent: int = 12, num_clusters: int = 20,
    noise: float = 0.02, seed: int = 0,
) -> np.ndarray:
    """Low-dimensional manifold embedded in R^d (the geometry of real image/
    text embeddings): clustered latents -> fixed random 2-layer decoder ->
    small ambient noise.  Nearest neighbors are determined by the latent,
    so locality is *learnable* — unlike pure-noise Gaussians where NN
    structure is isotropic noise that no compressed index can capture."""
    rng = np.random.default_rng(seed)
    wrng = np.random.default_rng(99)  # decoder fixed across seeds
    means = wrng.normal(size=(num_clusters, latent)).astype(np.float32)
    z = means[rng.integers(0, num_clusters, size=n)] + 0.5 * rng.normal(
        size=(n, latent)
    ).astype(np.float32)
    h = 64
    W1 = wrng.normal(size=(latent, h)).astype(np.float32) / np.sqrt(latent)
    W2 = wrng.normal(size=(h, d)).astype(np.float32) / np.sqrt(h)
    X = np.tanh(z @ W1) @ W2
    X = X + noise * rng.normal(size=(n, d)).astype(np.float32)
    return X.astype(np.float32)


def deep_like(n: int, *, d: int = 96, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    var = np.exp(-np.arange(d) / (d / 4)).astype(np.float32)
    X = rng.normal(size=(n, d)).astype(np.float32) * np.sqrt(var)
    return X.astype(np.float32)


DATASETS = {
    "clustered": clustered,
    "fashion_like": fashion_like,
    "glove_like": glove_like,
    "sparse_binary": sparse_binary,
    "deep_like": deep_like,
    "manifold": manifold,
}


def make(name: str, n: int, *, seed: int = 0, **kw) -> np.ndarray:
    return DATASETS[name](n, seed=seed, **kw)


def train_query_split(X: np.ndarray, *, query_frac: float = 0.2, seed: int = 0):
    """80/20 index/query split (paper F.1)."""
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    perm = rng.permutation(n)
    nq = max(1, int(n * query_frac))
    return X[perm[nq:]], X[perm[:nq]]
