"""Deterministic synthetic token pipeline for LM training.

Host-side, sharded by (host_id, num_hosts) so every host materializes only
its slice of the global batch — the 1000-node layout.  Sequences are drawn
from a Zipfian unigram model with Markov bigram structure (enough statistical
texture for loss curves to move) and are reproducible from (seed, step).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        rng = np.random.default_rng(self.seed)
        # Zipf unigram + low-rank bigram mixing matrix
        ranks = np.arange(1, self.vocab_size + 1)
        self.unigram = (1.0 / ranks**1.1)
        self.unigram /= self.unigram.sum()
        self.shift = rng.integers(1, self.vocab_size, size=64)

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4099 + self.host_id
        )
        b = self.local_batch
        base = rng.choice(
            self.vocab_size, size=(b, self.seq_len), p=self.unigram
        ).astype(np.int32)
        # Markov-ish structure: half the positions continue the previous
        # token through a fixed permutation-shift
        cont = rng.random((b, self.seq_len)) < 0.5
        shifted = (np.roll(base, 1, axis=1) + self.shift[step % 64]) % self.vocab_size
        tokens = np.where(cont, shifted, base).astype(np.int32)
        return {"tokens": tokens}


def recsys_batch(step: int, batch: int, vocabs, *, seed: int = 0,
                 host_id: int = 0, num_hosts: int = 1) -> dict:
    rng = np.random.default_rng((seed * 999_983 + step) * 4099 + host_id)
    b = batch // num_hosts
    ids = np.stack(
        [rng.integers(0, v, size=b) for v in vocabs], axis=1
    ).astype(np.int32)
    # labels correlated with a random linear score of the ids (learnable)
    w = np.random.default_rng(seed).normal(size=len(vocabs))
    score = (ids % 97) @ w / (97 * np.sqrt(len(vocabs)))
    labels = (score + 0.25 * rng.normal(size=b) > 0).astype(np.float32)
    return {"ids": ids, "labels": labels}
