"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines CONFIG (exact public-literature dims) and
REDUCED (same family, tiny dims — the CPU smoke-test configs).
"""
from __future__ import annotations

import importlib

ARCHS = {
    # LM family
    "smollm-135m": "repro.configs.smollm_135m",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    # GNN
    "gcn-cora": "repro.configs.gcn_cora",
    # RecSys
    "deepfm": "repro.configs.deepfm",
    "xdeepfm": "repro.configs.xdeepfm",
    "fm": "repro.configs.fm",
    "autoint": "repro.configs.autoint",
    # the paper's own pipeline as a selectable config
    "infinity-search": "repro.configs.infinity_search",
}

FAMILY = {
    "smollm-135m": "lm",
    "deepseek-coder-33b": "lm",
    "gemma-2b": "lm",
    "qwen3-moe-235b-a22b": "lm",
    "deepseek-v3-671b": "lm",
    "gcn-cora": "gnn",
    "deepfm": "recsys",
    "xdeepfm": "recsys",
    "fm": "recsys",
    "autoint": "recsys",
    "infinity-search": "search",
}


def get(arch: str):
    mod = importlib.import_module(ARCHS[arch])
    return mod.CONFIG


def get_reduced(arch: str):
    mod = importlib.import_module(ARCHS[arch])
    return mod.REDUCED


def family(arch: str) -> str:
    return FAMILY[arch]
