"""AutoInt [arXiv:1810.11921]: 3 self-attn layers, 2 heads, d_attn=32,
embed_dim=16, no deep branch (attention output direct to logit)."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="autoint",
    interaction="self-attn",
    n_sparse=39,
    embed_dim=16,
    mlp=(),
    n_attn_layers=3,
    n_heads=2,
    d_attn=32,
)

REDUCED = RecsysConfig(
    name="autoint-reduced",
    interaction="self-attn",
    n_sparse=6,
    embed_dim=8,
    vocabs=(64, 32, 32, 16, 16, 8),
    mlp=(),
    n_attn_layers=2,
    n_heads=2,
    d_attn=8,
)
