"""Architecture configuration dataclasses + the shape registry.

One config instance per assigned architecture lives in
``repro/configs/<arch_id>.py``; the registry in ``__init__`` maps
``--arch`` ids to (config, family).  Shapes are per-family (the assignment
pairs each arch family with its own input-shape set).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention flavor
    attention: str = "gqa"  # gqa | mla
    mla: Optional[MLAConfig] = None
    qk_norm: bool = False  # qwen3-style per-head RMSNorm on q/k
    rope_theta: float = 10000.0
    # mlp flavor
    activation: str = "swiglu"  # swiglu | geglu
    # moe
    moe: bool = False
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0  # leading dense layers (deepseek-v3: 3)
    router: str = "softmax"  # softmax | sigmoid (ds-v3 aux-free style)
    capacity_factor: float = 1.25
    # extras
    norm_eps: float = 1e-6
    gemma_norm: bool = False  # (1 + w) RMSNorm scaling + embed * sqrt(d)
    tie_embeddings: bool = False
    mtp: bool = False  # deepseek-v3 multi-token-prediction head (1 module)
    # numerics
    dtype: str = "bfloat16"  # activation dtype
    param_dtype: str = "float32"
    remat: bool = True

    @property
    def num_moe_layers(self) -> int:
        return (self.num_layers - self.first_dense_layers) if self.moe else 0

    @property
    def num_dense_layers(self) -> int:
        return self.first_dense_layers if self.moe else self.num_layers

    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES = (
    LMShape("train_4k", seq_len=4096, global_batch=256, kind="train"),
    LMShape("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    LMShape("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    LMShape("long_500k", seq_len=524288, global_batch=1, kind="decode"),
)


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    num_layers: int = 2
    d_hidden: int = 16
    num_classes: int = 7
    aggregator: str = "mean"
    norm: str = "sym"  # symmetric degree normalization (GCN)
    dropout: float = 0.5
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str  # full | sampled | batched
    n_nodes: int
    n_edges: int
    d_feat: int
    batch_nodes: int = 0
    fanout: tuple[int, ...] = ()
    n_graphs: int = 0  # batched-small-graphs


GNN_SHAPES = (
    GNNShape("full_graph_sm", kind="full", n_nodes=2708, n_edges=10556, d_feat=1433),
    GNNShape(
        "minibatch_lg", kind="sampled", n_nodes=232965, n_edges=114615892,
        d_feat=602, batch_nodes=1024, fanout=(15, 10),
    ),
    GNNShape("ogb_products", kind="full", n_nodes=2449029, n_edges=61859140, d_feat=100),
    GNNShape("molecule", kind="batched", n_nodes=30, n_edges=64, d_feat=16, n_graphs=128),
)


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

# Criteo-flavoured vocabulary sizes for 39 sparse fields: a few huge ID
# spaces, a tail of small categorical fields (sums to ~38M rows).
RECSYS_VOCABS = tuple(
    [10_000_000, 8_000_000, 5_000_000, 3_000_000, 2_000_000, 1_000_000]
    + [500_000, 300_000, 200_000, 100_000, 50_000, 20_000, 10_000]
    + [5000] * 6 + [2000] * 6 + [500] * 7 + [100] * 7
)
assert len(RECSYS_VOCABS) == 39


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    interaction: str  # fm | fm2 | cin | self-attn
    n_sparse: int = 39
    embed_dim: int = 10
    vocabs: tuple[int, ...] = RECSYS_VOCABS
    mlp: tuple[int, ...] = (400, 400, 400)
    # xDeepFM CIN
    cin_layers: tuple[int, ...] = ()
    # AutoInt
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    dtype: str = "float32"

    @property
    def total_vocab(self) -> int:
        return sum(self.vocabs[: self.n_sparse])


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    kind: str  # train | serve | retrieval
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = (
    RecsysShape("train_batch", kind="train", batch=65536),
    RecsysShape("serve_p99", kind="serve", batch=512),
    RecsysShape("serve_bulk", kind="serve", batch=262144),
    RecsysShape("retrieval_cand", kind="retrieval", batch=1, n_candidates=1_000_000),
)
