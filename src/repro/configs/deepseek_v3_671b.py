"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA + fine-grained MoE + MTP.

61L (3 dense + 58 MoE), d_model=7168, 128 heads MLA (q_lora=1536,
kv_lora=512, nope=128, rope=64, v=128), MoE 256 routed experts top-8 +
1 shared, moe_d_ff=2048, dense d_ff=18432, vocab=129280, sigmoid router
with top-k renorm + routed scaling 2.5, MTP (1 module).
"""
import dataclasses
from repro.configs.base import LMConfig, MLAConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,   # MLA: per-head latent KV; field kept for the record
    head_dim=128,
    d_ff=18432,         # the 3 leading dense layers
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    ),
    moe=True,
    num_experts=256,
    num_experts_per_tok=8,
    moe_d_ff=2048,
    num_shared_experts=1,
    first_dense_layers=3,
    router="sigmoid",
    mtp=True,
)

REDUCED = LMConfig(
    name="deepseek-v3-reduced",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    ),
    moe=True,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=32,
    num_shared_experts=1,
    first_dense_layers=1,
    router="sigmoid",
    mtp=True,
    remat=False,
    dtype="float32",
)
