"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-235B-A22B family].

94L, d_model=4096, 64 heads (GQA kv=4, head_dim=128), MoE 128 experts top-8,
moe_d_ff=1536, vocab=151936, qk-norm, SwiGLU, softmax router.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen3-moe-235b-a22b",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,          # unused (all layers MoE); kept for reference
    vocab_size=151936,
    qk_norm=True,
    moe=True,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=1536,
    router="softmax",
)

REDUCED = LMConfig(
    name="qwen3-moe-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    qk_norm=True,
    moe=True,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=64,
    router="softmax",
    remat=False,
    dtype="float32",
)
