"""xDeepFM [arXiv:1803.05170]: CIN 200-200-200 + 400-400 MLP, embed_dim=10."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="xdeepfm",
    interaction="cin",
    n_sparse=39,
    embed_dim=10,
    cin_layers=(200, 200, 200),
    mlp=(400, 400),
)

REDUCED = RecsysConfig(
    name="xdeepfm-reduced",
    interaction="cin",
    n_sparse=6,
    embed_dim=4,
    vocabs=(64, 32, 32, 16, 16, 8),
    cin_layers=(16, 16),
    mlp=(32,),
)
