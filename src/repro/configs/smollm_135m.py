"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small.

30L, d_model=576, 9 heads (GQA kv=3, head_dim=64), d_ff=1536, vocab=49152,
tied embeddings, SwiGLU, RMSNorm, rope theta 10000.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="smollm-135m",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
)

REDUCED = LMConfig(
    name="smollm-135m-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    tie_embeddings=True,
    remat=False,
    dtype="float32",
)
