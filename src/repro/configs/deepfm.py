"""DeepFM [arXiv:1703.04247]: FM branch + 400-400-400 MLP, embed_dim=10."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="deepfm",
    interaction="fm",
    n_sparse=39,
    embed_dim=10,
    mlp=(400, 400, 400),
)

REDUCED = RecsysConfig(
    name="deepfm-reduced",
    interaction="fm",
    n_sparse=6,
    embed_dim=4,
    vocabs=(64, 32, 32, 16, 16, 8),
    mlp=(32, 32),
)
