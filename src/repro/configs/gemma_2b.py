"""Gemma-2B [arXiv:2403.08295] — GeGLU, MQA, head_dim=256.

18L, d_model=2048, 8 heads (MQA kv=1), d_ff=16384 (GeGLU), vocab=256000,
tied embeddings, (1+w) RMSNorm, sqrt(d) embedding scale.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="gemma-2b",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    gemma_norm=True,
    tie_embeddings=True,
)

REDUCED = LMConfig(
    name="gemma-2b-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    activation="geglu",
    gemma_norm=True,
    tie_embeddings=True,
    remat=False,
    dtype="float32",
)
