"""Factorization Machine [Rendle ICDM'10]: pure 2-way FM via the O(nk)
sum-square trick, embed_dim=10, no deep branch."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="fm",
    interaction="fm2",
    n_sparse=39,
    embed_dim=10,
    mlp=(),
)

REDUCED = RecsysConfig(
    name="fm-reduced",
    interaction="fm2",
    n_sparse=6,
    embed_dim=4,
    vocabs=(64, 32, 32, 16, 16, 8),
    mlp=(),
)
