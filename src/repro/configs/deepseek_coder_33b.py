"""DeepSeek-Coder-33B [arXiv:2401.14196] — llama-arch.

62L, d_model=7168, 56 heads (GQA kv=8, head_dim=128), d_ff=19200,
vocab=32256, SwiGLU, rope.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="deepseek-coder-33b",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
)

REDUCED = LMConfig(
    name="deepseek-coder-33b-reduced",
    num_layers=2,
    d_model=96,
    num_heads=6,  # not divisible by small test meshes either — exercises SP
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    remat=False,
    dtype="float32",
)
