"""The paper's own pipeline as a selectable config (IndexConfig defaults)."""
import math
from repro.core.search import IndexConfig

CONFIG = IndexConfig(q=math.inf, metric="euclidean")
REDUCED = IndexConfig(
    q=math.inf, metric="euclidean", proj_sample=256, knn_k=8, num_hops=4,
    embed_dim=16, hidden=(64,), train_steps=200, batch_pairs=256,
)
