"""GCN on Cora [arXiv:1609.02907]: 2 layers, 16 hidden, mean agg, sym norm."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gcn-cora",
    num_layers=2,
    d_hidden=16,
    num_classes=7,
    aggregator="mean",
    norm="sym",
)

REDUCED = GNNConfig(
    name="gcn-cora-reduced",
    num_layers=2,
    d_hidden=8,
    num_classes=4,
    aggregator="mean",
    norm="sym",
    dropout=0.0,
)
