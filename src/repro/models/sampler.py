"""Host-side fanout neighbor sampler (GraphSAGE-style) for ``minibatch_lg``.

Produces padded, static-shape subgraph batches from a CSR adjacency:
seed nodes -> fanout[0] neighbors -> fanout[1] neighbors of those, with
relabeled local node ids, padded edge lists (-1 padding, masked by the GCN
conv) and the seed positions for the loss.  This IS part of the system —
JAX has no dynamic-shape gather pipeline, so sampling runs on host and the
device step consumes fixed shapes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # (n+1,)
    indices: np.ndarray  # (nnz,)

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @classmethod
    def from_edges(cls, edges: np.ndarray, n_nodes: int) -> "CSRGraph":
        src, dst = edges
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return cls(indptr=indptr, indices=src.astype(np.int32))

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]


def random_graph(n_nodes: int, avg_degree: int, *, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    return CSRGraph.from_edges(np.stack([src, dst]), n_nodes)


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanout: tuple[int, ...],
    *,
    rng: np.random.Generator,
):
    """Returns dict with local_x_index (map to global), edges (2, E_max) with
    -1 padding, seed_local (positions of seeds), sized statically by
    (len(seeds), fanout)."""
    layers = [np.asarray(seeds, np.int64)]
    edge_src: list[np.ndarray] = []
    edge_dst: list[np.ndarray] = []
    frontier = layers[0]
    for f in fanout:
        nbrs = np.full((len(frontier), f), -1, np.int64)
        for i, v in enumerate(frontier):
            nb = graph.neighbors(int(v))
            if len(nb) == 0:
                continue
            take = rng.choice(nb, size=f, replace=len(nb) < f)
            nbrs[i] = take
        src = nbrs.reshape(-1)
        dst = np.repeat(frontier, f)
        ok = src >= 0
        edge_src.append(src[ok])
        edge_dst.append(dst[ok])
        frontier = np.unique(src[ok])
        layers.append(frontier)

    nodes = np.unique(np.concatenate(layers))
    relabel = {int(g): i for i, g in enumerate(nodes)}
    e_src = np.array([relabel[int(s)] for s in np.concatenate(edge_src)], np.int32)
    e_dst = np.array([relabel[int(d)] for d in np.concatenate(edge_dst)], np.int32)

    # static max sizes from the fanout tree
    max_nodes = int(len(seeds) * np.prod([f + 1 for f in fanout]))
    max_edges = int(len(seeds) * sum(np.prod([fanout[j] for j in range(i + 1)]) for i in range(len(fanout))))
    n_loc = len(nodes)
    edges = np.full((2, max_edges), -1, np.int32)
    edges[0, : len(e_src)] = e_src
    edges[1, : len(e_dst)] = e_dst
    node_index = np.full((max_nodes,), 0, np.int32)
    node_index[:n_loc] = nodes.astype(np.int32)
    node_valid = np.zeros((max_nodes,), bool)
    node_valid[:n_loc] = True
    seed_local = np.array([relabel[int(s)] for s in seeds], np.int32)
    return {
        "node_index": node_index,  # (max_nodes,) global node id per local id
        "node_valid": node_valid,
        "edges": edges,  # (2, max_edges) local ids, -1 padded
        "seed_local": seed_local,  # (n_seeds,)
        "num_nodes": max_nodes,
    }
