"""LM transformer (llama/gemma/qwen3/deepseek families) — pure JAX, scanned
layers, GQA/MQA/MLA attention, optional MoE with expert parallelism.

Layer stack is ``lax.scan`` over stacked parameters (MaxText-style): HLO size
stays O(1) in depth, remat applies per layer.  MoE models with leading dense
layers (deepseek-v3) run two scans: dense stack then MoE stack.

Public entry points:
  lm_decls(cfg)                          — Param declarations (shardable)
  lm_forward(params, tokens, cfg, dctx)  — (B,S) -> logits (B,S,V) [+aux]
  lm_loss(params, batch, cfg, dctx)      — next-token CE + MoE aux + MTP
  lm_prefill(params, tokens, cfg, dctx, max_len) -> (logits_last, cache)
  lm_decode_step(params, cache, token, pos, cfg, dctx) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.dist.sharding import DistCtx, act
from repro.models import moe as moe_lib
from repro.models.attention import gqa_attention, mla_attention
from repro.models.layers import glu_mlp, rms_norm, softmax_cross_entropy
from repro.models.params import Param

PyTree = Any


# ---------------------------------------------------------------------------
# parameter declarations
# ---------------------------------------------------------------------------

def _attn_decls(cfg: LMConfig, L: int) -> dict:
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pdt = cfg.pdtype()
    if cfg.attention == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        out = {
            "wdq": Param((L, d, m.q_lora_rank), ("layers", "embed", "q_lora"), dtype=pdt),
            "q_norm": Param((L, m.q_lora_rank), ("layers", "q_lora"), init="ones", dtype=pdt),
            "wuq": Param((L, m.q_lora_rank, H, qk), ("layers", "q_lora", "q_heads", "head_dim"), dtype=pdt),
            "wdkv": Param((L, d, m.kv_lora_rank + m.qk_rope_head_dim), ("layers", "embed", "kv_lora"), dtype=pdt),
            "kv_norm": Param((L, m.kv_lora_rank), ("layers", "kv_lora"), init="ones", dtype=pdt),
            "wuk": Param((L, m.kv_lora_rank, H, m.qk_nope_head_dim), ("layers", "kv_lora", "q_heads", "head_dim"), dtype=pdt),
            "wuv": Param((L, m.kv_lora_rank, H, m.v_head_dim), ("layers", "kv_lora", "q_heads", "head_dim"), dtype=pdt),
            "wo": Param((L, H, m.v_head_dim, d), ("layers", "q_heads", "head_dim", "embed"), dtype=pdt),
        }
        return out
    out = {
        "wq": Param((L, d, H, Dh), ("layers", "embed", "q_heads", "head_dim"), dtype=pdt),
        "wk": Param((L, d, KV, Dh), ("layers", "embed", "kv_heads", "head_dim"), dtype=pdt),
        "wv": Param((L, d, KV, Dh), ("layers", "embed", "kv_heads", "head_dim"), dtype=pdt),
        "wo": Param((L, H, Dh, d), ("layers", "q_heads", "head_dim", "embed"), dtype=pdt),
    }
    if cfg.qk_norm:
        out["q_norm"] = Param((L, Dh), ("layers", "head_dim"), init="ones", dtype=pdt)
        out["k_norm"] = Param((L, Dh), ("layers", "head_dim"), init="ones", dtype=pdt)
    return out


def _dense_mlp_decls(cfg: LMConfig, L: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pdt = cfg.pdtype()
    return {
        "wg": Param((L, d, f), ("layers", "embed", "mlp"), dtype=pdt),
        "wu": Param((L, d, f), ("layers", "embed", "mlp"), dtype=pdt),
        "wd": Param((L, f, d), ("layers", "mlp", "embed"), dtype=pdt),
    }


def _moe_decls(cfg: LMConfig, L: int) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    pdt = cfg.pdtype()
    out = {
        "router": Param((L, d, E), ("layers", "embed", "experts_r"), dtype=pdt),
        # expert weights get their own d_model logical name (embed_x): the
        # EP mode decides their sharding, independent of the FSDP rule
        "wg": Param((L, E, d, f), ("layers", "experts", "embed_x", "expert_mlp"), dtype=pdt),
        "wu": Param((L, E, d, f), ("layers", "experts", "embed_x", "expert_mlp"), dtype=pdt),
        "wd": Param((L, E, f, d), ("layers", "experts", "expert_mlp", "embed_x"), dtype=pdt),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        out["shared_wg"] = Param((L, d, fs), ("layers", "embed", "mlp"), dtype=pdt)
        out["shared_wu"] = Param((L, d, fs), ("layers", "embed", "mlp"), dtype=pdt)
        out["shared_wd"] = Param((L, fs, d), ("layers", "mlp", "embed"), dtype=pdt)
    return out


def _block_decls(cfg: LMConfig, L: int, *, moe: bool) -> dict:
    pdt = cfg.pdtype()
    out = {
        "attn": _attn_decls(cfg, L),
        "attn_norm": Param((L, cfg.d_model), ("layers", "embed"), init="zeros" if cfg.gemma_norm else "ones", dtype=pdt),
        "mlp_norm": Param((L, cfg.d_model), ("layers", "embed"), init="zeros" if cfg.gemma_norm else "ones", dtype=pdt),
    }
    out["mlp"] = _moe_decls(cfg, L) if moe else _dense_mlp_decls(cfg, L)
    return out


def lm_decls(cfg: LMConfig) -> dict:
    pdt = cfg.pdtype()
    decls: dict = {
        "embed": Param((cfg.vocab_size, cfg.d_model), ("vocab_in", "embed_tbl"), init="embed", dtype=pdt),
        "final_norm": Param((cfg.d_model,), ("embed",), init="zeros" if cfg.gemma_norm else "ones", dtype=pdt),
    }
    if not cfg.tie_embeddings:
        decls["head"] = Param((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dtype=pdt)
    if cfg.num_dense_layers > 0:
        decls["dense_blocks"] = _block_decls(cfg, cfg.num_dense_layers, moe=False)
    if cfg.num_moe_layers > 0:
        decls["moe_blocks"] = _block_decls(cfg, cfg.num_moe_layers, moe=True)
    if cfg.mtp:
        decls["mtp"] = {
            "proj": Param((2 * cfg.d_model, cfg.d_model), ("embed2", "embed"), dtype=pdt),
            "block": _block_decls(cfg, 1, moe=False),
        }
    return decls


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_call(p, x, positions, cfg, dctx, cache=None, cache_index=None,
               mla_absorb=False):
    x = act(dctx, x, "batch", "attn_seq", "embed_act")
    if cfg.attention == "mla":
        out, new_cache = mla_attention(
            p, x, positions, cfg, cache=cache, cache_index=cache_index,
            absorb=mla_absorb,
        )
    else:
        out, new_cache = gqa_attention(
            p, x, positions, cfg, cache=cache, cache_index=cache_index
        )
    out = act(dctx, out, "batch", "seq", "embed_act")
    return out, new_cache


def _dense_ffn(p, x, cfg, dctx):
    h = glu_mlp(x, p["wg"], p["wu"], p["wd"], activation=cfg.activation)
    return act(dctx, h, "batch", "seq", "embed_act")


def _moe_ffn(p, x, cfg, dctx):
    """Routed experts (+ optional shared expert). Returns (out, aux_loss)."""
    probs = moe_lib.router_probs(x, p["router"], cfg)
    _, top_i = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    aux = moe_lib.load_balance_loss(probs, top_i, cfg)
    batch_axes = dctx.batch_axes if dctx is not None else ()
    B = x.shape[0]
    shards = 1
    if dctx is not None:
        for a in batch_axes:
            shards *= dctx.mesh.shape[a]
    use_ep = (
        dctx is not None
        and "model" in dctx.mesh.shape
        and cfg.num_experts % dctx.mesh.shape["model"] == 0
        and B % shards == 0
        and batch_axes
    )
    if use_ep:
        impl = dctx.opt("moe_impl", "gathered")
        fn = moe_lib.moe_ffn_ep_zero3 if impl == "zero3" else moe_lib.moe_ffn_ep
        out = fn(
            x, probs.astype(x.dtype), p, cfg,
            mesh=dctx.mesh, batch_axes=batch_axes,
        )
    else:
        out = moe_lib.moe_ffn_dense(x, probs, p, cfg)
    if cfg.num_shared_experts:
        out = out + glu_mlp(
            x, p["shared_wg"], p["shared_wu"], p["shared_wd"],
            activation=cfg.activation,
        )
    return act(dctx, out, "batch", "seq", "embed_act"), aux


def _block(p, h, positions, cfg, dctx, *, moe, cache=None, cache_index=None,
           mla_absorb=False):
    hn = rms_norm(h, p["attn_norm"], eps=cfg.norm_eps, gemma_style=cfg.gemma_norm)
    attn_out, new_cache = _attn_call(
        p["attn"], hn, positions, cfg, dctx, cache=cache,
        cache_index=cache_index, mla_absorb=mla_absorb,
    )
    h = h + attn_out
    hn = rms_norm(h, p["mlp_norm"], eps=cfg.norm_eps, gemma_style=cfg.gemma_norm)
    if moe:
        ffn_out, aux = _moe_ffn(p["mlp"], hn, cfg, dctx)
    else:
        ffn_out, aux = _dense_ffn(p["mlp"], hn, cfg, dctx), jnp.float32(0.0)
    return h + ffn_out, new_cache, aux


def _scan_blocks(blocks, h, positions, cfg, dctx, *, moe, caches=None,
                 cache_index=None, mla_absorb=False, remat=None):
    """lax.scan over the stacked layer params (and caches when decoding)."""

    def body(carry, xs):
        h = carry
        if caches is None:
            p = xs
            h, _, aux = _block(p, h, positions, cfg, dctx, moe=moe)
            return h, aux
        p, cache = xs
        h, new_cache, aux = _block(
            p, h, positions, cfg, dctx, moe=moe, cache=cache,
            cache_index=cache_index, mla_absorb=mla_absorb,
        )
        return h, (new_cache, aux)

    # remat matters only where gradients flow (training forward); decode and
    # prefill pass remat=False.
    if cfg.remat if remat is None else remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = blocks if caches is None else (blocks, caches)
    h, ys = jax.lax.scan(body, h, xs)
    if caches is None:
        return h, None, jnp.sum(ys)
    new_caches, aux = ys
    return h, new_caches, jnp.sum(aux)


# ---------------------------------------------------------------------------
# forward / loss / prefill / decode
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg, dctx):
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.act_dtype())
    if cfg.gemma_norm:
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
    return act(dctx, h, "batch", "seq", "embed_act")


def _head(params, h, cfg, dctx):
    h = rms_norm(h, params["final_norm"], eps=cfg.norm_eps, gemma_style=cfg.gemma_norm)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = h @ w.astype(h.dtype)
    return act(dctx, logits, "batch", "seq", "vocab")


def lm_forward(
    params: PyTree, tokens: jax.Array, cfg: LMConfig,
    dctx: Optional[DistCtx] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full causal forward. Returns (logits, final_hidden, moe_aux_loss)."""
    S = tokens.shape[1]
    positions = jnp.arange(S)
    h = _embed(params, tokens, cfg, dctx)
    aux = jnp.float32(0.0)
    if cfg.num_dense_layers > 0:
        h, _, _ = _scan_blocks(params["dense_blocks"], h, positions, cfg, dctx, moe=False)
    if cfg.num_moe_layers > 0:
        h, _, a = _scan_blocks(params["moe_blocks"], h, positions, cfg, dctx, moe=True)
        aux = aux + a
    logits = _head(params, h, cfg, dctx)
    return logits, h, aux


def lm_loss(
    params: PyTree, batch: dict, cfg: LMConfig, dctx: Optional[DistCtx] = None,
    *, aux_weight: float = 0.01, mtp_weight: float = 0.1,
) -> tuple[jax.Array, dict]:
    """Next-token CE (+ MoE aux + MTP second-token CE)."""
    tokens, mask = batch["tokens"], batch.get("mask")
    logits, h, aux = lm_forward(params, tokens, cfg, dctx)
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    valid = jnp.ones_like(tokens, jnp.float32) if mask is None else mask.astype(jnp.float32)
    valid = valid.at[:, -1].set(0.0)
    ce = softmax_cross_entropy(logits, labels, valid)
    loss = ce + aux_weight * aux
    metrics = {"ce": ce, "moe_aux": aux}
    if cfg.mtp:
        # MTP (deepseek-v3): one extra block sees [h_t ; emb(t+1)] and
        # predicts token t+2 through the shared head.
        emb_next = jnp.take(params["embed"], labels, axis=0).astype(h.dtype)
        mtp_in = jnp.concatenate([h, emb_next], axis=-1) @ params["mtp"]["proj"].astype(h.dtype)
        positions = jnp.arange(tokens.shape[1])
        hm, _, _ = _scan_blocks(params["mtp"]["block"], mtp_in, positions, cfg, dctx, moe=False)
        logits2 = _head(params, hm, cfg, dctx)
        labels2 = jnp.pad(tokens[:, 2:], ((0, 0), (0, 2)))
        valid2 = valid.at[:, -2:].set(0.0)
        ce2 = softmax_cross_entropy(logits2, labels2, valid2)
        loss = loss + mtp_weight * ce2
        metrics["mtp_ce"] = ce2
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int, dctx=None) -> dict:
    """Stacked per-layer decode caches (L, B, T, ...)."""
    dt = cfg.act_dtype()
    out = {}

    def c(shape, *names):
        z = jnp.zeros(shape, dt)
        return act(dctx, z, *names)

    if cfg.attention == "mla":
        m = cfg.mla
        mk = lambda L: {
            "ckv": c((L, batch, max_len, m.kv_lora_rank), "layers", "batch", "kv_seq", "kv_lora"),
            "krope": c((L, batch, max_len, m.qk_rope_head_dim), "layers", "batch", "kv_seq", "rope"),
        }
    else:
        mk = lambda L: {
            "k": c((L, batch, max_len, cfg.num_kv_heads, cfg.head_dim), "layers", "batch", "kv_seq", "kv_heads", "head_dim"),
            "v": c((L, batch, max_len, cfg.num_kv_heads, cfg.head_dim), "layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        }
    if cfg.num_dense_layers > 0:
        out["dense"] = mk(cfg.num_dense_layers)
    if cfg.num_moe_layers > 0:
        out["moe"] = mk(cfg.num_moe_layers)
    return out


def _cache_axis_fix(cache_tree):
    """Caches are stored (L, B, T, ...) but attention wants (B, T, ...) per
    layer — scan's xs axis is the leading L, so nothing to do; helper kept
    for clarity."""
    return cache_tree


def lm_decode_step(
    params: PyTree, cache: dict, tokens: jax.Array, pos: jax.Array,
    cfg: LMConfig, dctx: Optional[DistCtx] = None, *, mla_absorb: bool = False,
) -> tuple[jax.Array, dict]:
    """One decode step. tokens (B, 1) int32; pos scalar int32 (write index).
    Returns (logits (B, 1, V), new cache)."""
    h = _embed(params, tokens, cfg, dctx)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    new_cache = {}
    if cfg.num_dense_layers > 0:
        h, nc, _ = _scan_blocks(
            params["dense_blocks"], h, positions, cfg, dctx, moe=False,
            caches=cache["dense"], cache_index=pos, mla_absorb=mla_absorb,
            remat=False,
        )
        new_cache["dense"] = nc
    if cfg.num_moe_layers > 0:
        h, nc, _ = _scan_blocks(
            params["moe_blocks"], h, positions, cfg, dctx, moe=True,
            caches=cache["moe"], cache_index=pos, mla_absorb=mla_absorb,
            remat=False,
        )
        new_cache["moe"] = nc
    logits = _head(params, h, cfg, dctx)
    return logits, new_cache


def lm_prefill(
    params: PyTree, tokens: jax.Array, cfg: LMConfig,
    dctx: Optional[DistCtx] = None, *, max_len: Optional[int] = None,
) -> tuple[jax.Array, dict]:
    """Prefill: full forward over the prompt; returns (last-token logits,
    cache sized max_len or S)."""
    B, S = tokens.shape
    T = max_len or S
    positions = jnp.arange(S)
    h = _embed(params, tokens, cfg, dctx)
    cache = {}

    def run(blocks, h, moe, L):
        def body(carry, p):
            h = carry
            hn = rms_norm(h, p["attn_norm"], eps=cfg.norm_eps, gemma_style=cfg.gemma_norm)
            attn_out, kv = _attn_call(p["attn"], hn, positions, cfg, dctx)
            h = h + attn_out
            hn = rms_norm(h, p["mlp_norm"], eps=cfg.norm_eps, gemma_style=cfg.gemma_norm)
            if moe:
                out, _ = _moe_ffn(p["mlp"], hn, cfg, dctx)
            else:
                out = _dense_ffn(p["mlp"], hn, cfg, dctx)
            # pad the prefill KV to the serving window
            kv_pad = jax.tree_util.tree_map(
                lambda a: jnp.pad(a, [(0, 0), (0, T - S)] + [(0, 0)] * (a.ndim - 2)),
                kv,
            )
            return h + out, kv_pad

        return jax.lax.scan(body, h, blocks)

    if cfg.num_dense_layers > 0:
        h, kv = run(params["dense_blocks"], h, False, cfg.num_dense_layers)
        cache["dense"] = kv
    if cfg.num_moe_layers > 0:
        h, kv = run(params["moe_blocks"], h, True, cfg.num_moe_layers)
        cache["moe"] = kv
    logits = _head(params, h[:, -1:, :], cfg, dctx)
    return logits, cache
