"""GCN (Kipf & Welling 2017) with edge-list message passing.

JAX sparse is BCOO-only, so message passing is implemented the TPU-idiomatic
way: an edge-index gather + ``jax.ops.segment_sum`` scatter (the SpMM
``Ã X W`` in scatter form).  Symmetric normalization 1/sqrt(deg_i deg_j)
per edge (GCN's sym norm); self-loops added by the data pipeline.

Three input regimes (the assigned shapes):
  full    — one (n_nodes, d) graph, edges (2, E)
  sampled — fanout-sampled subgraph batches from the host-side neighbor
            sampler (models/sampler.py), padded to static shapes
  batched — many small graphs packed with a graph-id segment vector

Distribution: node features replicated, edge list sharded over all mesh axes;
each shard scatter-adds its partial messages and a psum completes the
aggregation — ``segment_sum`` over a sharded edge axis lowers to exactly
that under pjit.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.dist.sharding import DistCtx, act
from repro.models.params import Param

PyTree = Any


def gcn_decls(cfg: GNNConfig, d_feat: int) -> dict:
    dims = (d_feat,) + (cfg.d_hidden,) * (cfg.num_layers - 1) + (cfg.num_classes,)
    return {
        "layers": [
            {
                "w": Param((dims[i], dims[i + 1]), ("feat", "hidden")),
                "b": Param((dims[i + 1],), ("hidden",), init="zeros"),
            }
            for i in range(cfg.num_layers)
        ]
    }


def gcn_conv(
    x: jax.Array,
    edges: jax.Array,  # (2, E) int32 [src, dst]; may contain -1 padding
    w: jax.Array,
    b: jax.Array,
    *,
    n_nodes: int,
    norm: str = "sym",
    aggregator: str = "mean",
    dctx: Optional[DistCtx] = None,
) -> jax.Array:
    src, dst = edges[0], edges[1]
    valid = (src >= 0) & (dst >= 0)
    src = jnp.maximum(src, 0)
    dst = jnp.maximum(dst, 0)
    h = x @ w + b  # transform first: (n, d_out), d_out <= d_in for GCN

    ones = valid.astype(h.dtype)
    deg = jax.ops.segment_sum(ones, dst, num_segments=n_nodes)
    deg = jnp.maximum(deg, 1.0)
    if norm == "sym":
        coef = jax.lax.rsqrt(deg[src] * deg[dst]) * ones
    elif aggregator == "mean":
        coef = (1.0 / deg[dst]) * ones
    else:
        coef = ones
    msgs = h[src] * coef[:, None]
    out = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    return out


def gcn_forward(
    params: PyTree,
    x: jax.Array,
    edges: jax.Array,
    cfg: GNNConfig,
    dctx: Optional[DistCtx] = None,
    *,
    train: bool = False,
    rng: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-graph / subgraph forward -> (n_nodes, num_classes) logits."""
    n = x.shape[0]
    edges = act(dctx, edges, None, "edges")
    h = x
    for i, layer in enumerate(params["layers"]):
        h = gcn_conv(
            h, edges, layer["w"], layer["b"], n_nodes=n, norm=cfg.norm,
            aggregator=cfg.aggregator, dctx=dctx,
        )
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
            if train and cfg.dropout > 0 and rng is not None:
                rng, sub = jax.random.split(rng)
                keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, h.shape)
                h = jnp.where(keep, h / (1.0 - cfg.dropout), 0.0)
    return h


def gcn_loss(
    params: PyTree, batch: dict, cfg: GNNConfig, dctx: Optional[DistCtx] = None,
    *, rng: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    """batch: x (n, d), edges (2, E), labels (n,), label_mask (n,)."""
    logits = gcn_forward(
        params, batch["x"], batch["edges"], cfg, dctx, train=rng is not None, rng=rng
    )
    labels = batch["labels"]
    mask = batch.get("label_mask")
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    if mask is not None:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    acc_mask = jnp.ones_like(nll) if mask is None else mask
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * acc_mask) / jnp.maximum(
        jnp.sum(acc_mask), 1.0
    )
    return loss, {"loss": loss, "acc": acc}
