"""Parameter declaration system.

Models declare their parameters as a pytree of ``Param`` records: shape +
logical axis names + initializer.  From the declarations we derive
  * materialized parameters   (``init_params`` — real training),
  * abstract parameters       (``abstract_params`` — dry-run, no allocation),
  * PartitionSpecs            (``dist.sharding.specs_for`` maps logical axis
                               names -> mesh axes per the arch's policy).

Logical axis vocabulary (DESIGN.md §6):
  layers, vocab, embed, q_heads, kv_heads, head_dim, mlp, experts,
  expert_mlp, q_lora, kv_lora, fields, table, feat, hidden, cin, none
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Param:
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]  # one name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None  # fan-in override for 'normal'
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _fan_in(shape: tuple[int, ...]) -> int:
    # last dim is the output dim by convention (x @ w)
    return max(1, math.prod(shape[:-1])) if len(shape) > 1 else max(1, shape[0])


def _init_leaf(rng: jax.Array, p: Param) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "embed":
        # GPT-2-style 0.02 scale keeps tied-head logits sane at init
        return (0.02 * jax.random.normal(rng, p.shape, jnp.float32)).astype(p.dtype)
    scale = p.scale if p.scale is not None else 1.0 / math.sqrt(_fan_in(p.shape))
    return (scale * jax.random.normal(rng, p.shape, jnp.float32)).astype(p.dtype)


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def init_params(rng: jax.Array, decls: PyTree) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(decls, is_leaf=is_param)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(k, p) for k, p in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(decls: PyTree) -> PyTree:
    """ShapeDtypeStructs — the dry-run path never allocates parameters."""
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), decls, is_leaf=is_param
    )


def logical_specs(decls: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p: p.logical, decls, is_leaf=is_param)


def param_count(decls: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(decls, is_leaf=is_param)
    return sum(math.prod(p.shape) for p in leaves)


def param_bytes(decls: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(decls, is_leaf=is_param)
    return sum(math.prod(p.shape) * jnp.dtype(p.dtype).itemsize for p in leaves)


def map_with_decls(fn: Callable[[Param, Any], Any], decls: PyTree, tree: PyTree) -> PyTree:
    leaves_d, treedef = jax.tree_util.tree_flatten(decls, is_leaf=is_param)
    leaves_t = treedef.flatten_up_to(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [fn(d, t) for d, t in zip(leaves_d, leaves_t)]
    )
