"""Mixture-of-Experts FFN with expert parallelism (DESIGN.md §6).

Two execution paths sharing the same parameters:

* ``moe_ffn_dense`` — reference path (no mesh): every expert processes every
  token, outputs combined by routing weights.  Exact (no capacity drops);
  used by smoke tests and as the correctness oracle for the EP path.

* ``moe_ffn_ep``   — production path under ``shard_map``: activations are
  sharded over the batch axes and replicated over the model axis; experts are
  sharded over the model axis.  Each chip sort-free-dispatches its local
  tokens to its local experts (position-in-expert via a (T*k, E_loc) one-hot
  cumsum — E_loc is small, so this stays tiny), runs the expert FFNs as one
  batched (E_loc, C, d) x (E_loc, d, f) matmul, combines weighted outputs,
  and a single psum over the model axis sums the expert groups.  No
  all-to-all is needed because activations are model-replicated (the TP
  psum this replaces would have moved the same bytes).

Routing: softmax (Switch/GShard, qwen3) or sigmoid with top-k renorm
(DeepSeek-V3 aux-free style) + routed scaling.  Capacity-dropped tokens
contribute zero (standard dropped-token semantics).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from repro.dist.sharding import shard_map_compat as _shard_map


MOE_CHUNK_TOKENS = 32768  # gathered tokens processed per EP chunk


def router_probs(x: jax.Array, wr: jax.Array, cfg: LMConfig) -> jax.Array:
    """(B, S, d) -> (B, S, E) routing probabilities (f32)."""
    logits = jnp.einsum("bsd,de->bse", x, wr.astype(x.dtype)).astype(jnp.float32)
    if cfg.router == "sigmoid":
        return jax.nn.sigmoid(logits)
    return jax.nn.softmax(logits, axis=-1)


def topk_weights(probs: jax.Array, cfg: LMConfig):
    """Top-k selection + renormalization. probs (..., E) f32."""
    top_w, top_i = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    scaling = getattr(cfg, "routed_scaling", 1.0)
    return top_w * scaling, top_i


def load_balance_loss(probs: jax.Array, top_i: jax.Array, cfg: LMConfig) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    E = cfg.num_experts
    pe = jnp.mean(probs.reshape(-1, E), axis=0)
    counts = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    fe = counts / jnp.maximum(jnp.sum(counts), 1.0)
    return E * jnp.sum(fe * pe)


def _slot_maps(top_i, top_w, eo, E_loc: int, C: int, T: int, k: int, dtype):
    """Capacity-slot assignment without materializing (T*k, d) anything.

    Returns slot_tok (E_loc, C) int32 — source token per expert slot (T =
    empty), and slot_w (E_loc, C) — routing weight per slot (0 = empty).
    Position-in-expert comes from a (T*k, E_loc) one-hot cumsum (E_loc is
    per-chip small); capacity overflow lands in a trash column that is
    sliced off.
    """
    flat_i = top_i.reshape(-1)
    flat_w = top_w.reshape(-1).astype(dtype)
    tok = jnp.repeat(jnp.arange(T), k)
    local = (flat_i >= eo) & (flat_i < eo + E_loc)
    lid = jnp.clip(flat_i - eo, 0, E_loc - 1)
    onehot = (lid[:, None] == jnp.arange(E_loc)[None, :]) & local[:, None]
    pos_all = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    pos = jnp.take_along_axis(pos_all, lid[:, None], axis=1)[:, 0]
    keep = local & (pos < C)
    wpos = jnp.where(keep, pos, C)  # C = trash column
    slot_tok = jnp.full((E_loc, C + 1), T, jnp.int32).at[lid, wpos].set(tok.astype(jnp.int32))
    slot_w = jnp.zeros((E_loc, C + 1), dtype).at[lid, wpos].set(flat_w * keep.astype(dtype))
    return slot_tok[:, :C], slot_w[:, :C]


def _expert_ffn(buf: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                activation: str) -> jax.Array:
    """buf (E, C, d) -> (E, C, d) through per-expert GLU FFNs."""
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype))
    act = jax.nn.silu if activation == "swiglu" else partial(jax.nn.gelu, approximate=True)
    return jnp.einsum("ecf,efd->ecd", act(g) * u, wd.astype(buf.dtype))


# ---------------------------------------------------------------------------
# dense reference path
# ---------------------------------------------------------------------------

def moe_ffn_dense(x: jax.Array, probs: jax.Array, p: dict, cfg: LMConfig) -> jax.Array:
    """All experts on all tokens; exact combine. For tests / tiny configs."""
    B, S, d = x.shape
    top_w, top_i = topk_weights(probs, cfg)  # (B,S,k)
    oh = jax.nn.one_hot(top_i, cfg.num_experts, dtype=jnp.float32)  # (B,S,k,E)
    full_w = jnp.einsum("bsk,bske->bse", top_w, oh)
    g = jnp.einsum("bsd,edf->bsef", x, p["wg"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, p["wu"].astype(x.dtype))
    act = jax.nn.silu if cfg.activation == "swiglu" else partial(jax.nn.gelu, approximate=True)
    h = jnp.einsum("bsef,efd->bsed", act(g) * u, p["wd"].astype(x.dtype))
    return jnp.einsum("bsed,bse->bsd", h, full_w.astype(x.dtype))


# ---------------------------------------------------------------------------
# expert-parallel path (shard_map)
# ---------------------------------------------------------------------------

def ep_mode(cfg: LMConfig, mesh, *, model_axis="model", data_axis="data") -> str:
    """How expert weights shard (DESIGN.md §6, EXPERIMENTS.md §Perf/H1):

    '2d'     — experts over (model x data): E % (model*data) == 0.
               Every chip owns whole experts; nothing else to slice.
    'fslice' — experts over model, expert d_ff over data.
    'model'  — experts over model only (weights replicated over data — only
               sane for small E*d*f).
    """
    msz = mesh.shape.get(model_axis, 1)
    dsz = mesh.shape.get(data_axis, 1)
    E, f = cfg.num_experts, cfg.moe_d_ff
    if E % (msz * dsz) == 0:
        return "2d"
    if E % msz == 0 and f % dsz == 0:
        return "fslice"
    return "model"


def expert_weight_specs(cfg: LMConfig, mesh, *, model_axis="model", data_axis="data"):
    mode = ep_mode(cfg, mesh, model_axis=model_axis, data_axis=data_axis)
    if mode == "2d":
        e = P((model_axis, data_axis), None, None)
        return mode, {"wg": e, "wu": e, "wd": e}
    if mode == "fslice":
        return mode, {
            "wg": P(model_axis, None, data_axis),
            "wu": P(model_axis, None, data_axis),
            "wd": P(model_axis, data_axis, None),
        }
    e = P(model_axis, None, None)
    return mode, {"wg": e, "wu": e, "wd": e}


def moe_ffn_ep(
    x: jax.Array,
    probs: jax.Array,
    p: dict,
    cfg: LMConfig,
    *,
    mesh,
    batch_axes: tuple[str, ...],
    model_axis: str = "model",
    data_axis: str = "data",
) -> jax.Array:
    """Gathered-token expert parallelism under shard_map.

    Tokens are all-gathered across the data axis (activations are ~25x
    smaller than expert weights at these shapes — gathering tokens instead
    of ZeRO-3-gathering expert weights is what keeps temp memory inside
    HBM; see EXPERIMENTS.md §Perf/H1), every chip dispatches the gathered
    tokens to the experts it owns, and one psum over (data, model) combines
    expert-group and d_ff-slice partials in a single collective.
    """
    E = cfg.num_experts
    k = cfg.num_experts_per_tok
    mode = ep_mode(cfg, mesh, model_axis=model_axis, data_axis=data_axis)
    msz = mesh.shape.get(model_axis, 1)
    dsz = mesh.shape.get(data_axis, 1)
    B, S, d = x.shape
    batch_shards = math.prod(mesh.shape[a] for a in batch_axes)
    do_gather = data_axis in batch_axes and dsz > 1
    gsz = dsz if do_gather else 1
    T_loc = (B // max(batch_shards, 1)) * S  # tokens per chip before gather
    # chunk the token stream so expert buffers stay VMEM/HBM-friendly even
    # for 1M-token prefills: ~MOE_CHUNK_TOKENS gathered tokens per chunk
    tc_loc = max(1, min(T_loc, max(MOE_CHUNK_TOKENS // gsz, 1)))
    while T_loc % tc_loc:
        tc_loc -= 1
    n_chunks = T_loc // tc_loc
    T_g = tc_loc * gsz  # gathered tokens per chunk
    if mode == "2d":
        E_loc = E // (msz * dsz)
    else:
        E_loc = E // msz
    C = max(int(math.ceil(T_g * k / E * cfg.capacity_factor)), 8)
    psum_axes = (
        (model_axis, data_axis) if (mode in ("2d", "fslice") and dsz > 1)
        else (model_axis,)
    )

    def local_moe(x_loc, probs_loc, wg, wu, wd):
        xf_l = x_loc.reshape(T_loc, d)
        pf_l = probs_loc.reshape(T_loc, E)
        if mode == "2d":
            eo = (jax.lax.axis_index(model_axis) * dsz + jax.lax.axis_index(data_axis)) * E_loc
        else:
            eo = jax.lax.axis_index(model_axis) * E_loc

        def chunk_body(_, xc_pc):
            xc, pc = xc_pc  # (tc_loc, d), (tc_loc, E)
            if do_gather:
                xg = jax.lax.all_gather(xc, data_axis, axis=0, tiled=True)
                pg = jax.lax.all_gather(pc, data_axis, axis=0, tiled=True)
            else:
                xg, pg = xc, pc
            top_w, top_i = topk_weights(pg, cfg)
            # slot-map dispatch: scatter token INDICES (not d-wide rows) so
            # nothing of size (T*k, d) materializes (EXPERIMENTS §Perf/H1)
            slot_tok, slot_w = _slot_maps(top_i, top_w, eo, E_loc, C, T_g, k, xg.dtype)
            xg_pad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)])
            buf = xg_pad[slot_tok]  # (E_loc, C, d)
            hbuf = _expert_ffn(buf, wg, wu, wd, cfg.activation)
            contrib = hbuf * slot_w[..., None]
            out = jnp.zeros((T_g + 1, d), xg.dtype).at[slot_tok].add(contrib)[:T_g]
            # one psum folds expert groups (model[, data]) + f-slice partials.
            # NOTE: a psum_scatter over 'data' (reduce-scatter instead of
            # psum+slice) was tried and MEASURED WORSE — its backward pass
            # re-gathers the cotangent, erasing the forward saving
            # (EXPERIMENTS.md §Perf/H1-i4, refuted).
            out = jax.lax.psum(out, psum_axes)
            if do_gather:
                out = jax.lax.dynamic_slice_in_dim(
                    out, jax.lax.axis_index(data_axis) * tc_loc, tc_loc, axis=0
                )
            return None, out

        xs = (xf_l.reshape(n_chunks, tc_loc, d), pf_l.reshape(n_chunks, tc_loc, E))
        _, outs = jax.lax.scan(chunk_body, None, xs)
        return outs.reshape(B // max(batch_shards, 1), S, d)

    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    x_spec = P(bspec, None, None)
    _, wspecs = expert_weight_specs(cfg, mesh, model_axis=model_axis, data_axis=data_axis)
    fn = _shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(x_spec, x_spec, wspecs["wg"], wspecs["wu"], wspecs["wd"]),
        out_specs=x_spec,
    )
    return fn(x, probs, p["wg"], p["wu"], p["wd"])


def moe_ffn_ep_zero3(
    x: jax.Array,
    probs: jax.Array,
    p: dict,
    cfg: LMConfig,
    *,
    mesh,
    batch_axes: tuple[str, ...],
    model_axis: str = "model",
) -> jax.Array:
    """The original formulation kept for the §Perf A/B: experts sharded over
    'model' only, expert weights ZeRO-3 (embed-dim over 'data', re-gathered
    per layer per microbatch by SPMD).  Local dispatch, psum over model."""
    E = cfg.num_experts
    k = cfg.num_experts_per_tok
    model_size = mesh.shape[model_axis]
    assert E % model_size == 0, (E, model_size)
    E_loc = E // model_size
    batch_shards = math.prod(mesh.shape[a] for a in batch_axes)
    B, S, d = x.shape
    T_loc = (B // batch_shards) * S
    C = max(int(math.ceil(T_loc * k / E * cfg.capacity_factor)), 8)

    def local_moe(x_loc, probs_loc, wg, wu, wd):
        Bl = x_loc.shape[0]
        T = Bl * S
        xf = x_loc.reshape(T, d)
        pf = probs_loc.reshape(T, E)
        top_w, top_i = topk_weights(pf, cfg)
        eo = jax.lax.axis_index(model_axis) * E_loc
        slot_tok, slot_w = _slot_maps(top_i, top_w, eo, E_loc, C, T, k, xf.dtype)
        xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])
        buf = xf_pad[slot_tok]
        hbuf = _expert_ffn(buf, wg, wu, wd, cfg.activation)
        contrib = hbuf * slot_w[..., None]
        out = jnp.zeros((T + 1, d), xf.dtype).at[slot_tok].add(contrib)[:T]
        return jax.lax.psum(out, model_axis).reshape(Bl, S, d)

    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    x_spec = P(bspec, None, None)
    e_spec = P(model_axis, None, None)
    fn = _shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(x_spec, x_spec, e_spec, e_spec, e_spec),
        out_specs=x_spec,
    )
    return fn(x, probs, p["wg"], p["wu"], p["wd"])
