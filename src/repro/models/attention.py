"""Attention: GQA/MQA/MHA and MLA (DeepSeek-style latent attention),
with full (train / prefill) and KV-cache decode paths.

Layout conventions
------------------
activations  x        : (B, S, d_model)
query        q        : (B, S, H, Dh)
key/value    k, v     : (B, T, KV, Dh)
GQA grouping          : H = KV * G; scores einsum keeps the group axis so
                        no KV repeat is materialized.
decode cache (gqa)    : {'k': (B, T, KV, Dh), 'v': ...}
decode cache (mla)    : {'ckv': (B, T, kv_lora), 'krope': (B, T, rope_dim)}
                        — the compressed cache is MLA's raison d'être.

``mla_absorb`` selects the decode formulation: naive (expand K/V from the
latent per step — the paper-faithful port of the reference implementation)
vs absorbed (fold W_uk into the query / W_uv into the output — the
production trick; see EXPERIMENTS.md §Perf for the roofline delta).
Softmax is always computed in f32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models.layers import apply_rotary, rms_norm, rotary_embedding


def _softmax_f32(scores: jax.Array, mask: jax.Array, dtype) -> jax.Array:
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    return jax.nn.softmax(scores, axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# chunked (flash-style) causal attention — O(S * C) live memory
# ---------------------------------------------------------------------------

CHUNK_THRESHOLD = 2048  # direct softmax below this sequence length
CHUNK_SIZE = 1024


def _chunked_causal(q, kv_chunk_fn, n_chunks, chunk, positions, dtype, v_dim=None):
    """Online-softmax attention over KV chunks (Rabe & Staats / FlashAttention
    schedule in pure lax.scan — the TPU-native replacement for materializing
    the (S, T) score matrix).

    q: (B, S, KV, G, Dh) pre-scaled.  kv_chunk_fn(i) -> (kc, vc) with
    kc/vc (B, C, KV, Dh).  positions (S,) absolute query positions; chunk c
    covers absolute positions [c*chunk, (c+1)*chunk).
    Returns (B, S, KV, G, Dh) in ``dtype``.
    """
    B, S, KV, G, Dh = q.shape
    Dv = Dh if v_dim is None else v_dim
    NEG = jnp.float32(-1e30)
    # score/probability tiles materialize in the ACTIVATION dtype (bf16 in
    # production) — the dominant HBM traffic of unfused attention halves;
    # the online-softmax statistics (m, l) and the accumulator stay f32
    # (EXPERIMENTS.md §Perf/H1-i2).  f32 activations (tests) stay exact.
    sdt = q.dtype

    def body(carry, c):
        m, l, acc = carry
        kc, vc = kv_chunk_fn(c)
        kpos = c * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bskgd,bckd->bkgsc", q, kc.astype(sdt),
            preferred_element_type=sdt,
        )
        mask = positions[:, None] >= kpos[None, :]  # (S, C)
        s32 = jnp.where(mask[None, None, None], s.astype(jnp.float32), NEG)
        m_new = jnp.maximum(m, jnp.max(s32, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s32 - m_new[..., None]).astype(sdt)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum(
            "bkgsc,bckd->bkgsd", p, vc.astype(sdt),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, KV, G, S), NEG, jnp.float32),
        jnp.zeros((B, KV, G, S), jnp.float32),
        jnp.zeros((B, KV, G, S, Dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(dtype)  # -> (B,S,KV,G,Dh)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_params_shapes(cfg: LMConfig) -> dict:
    """See models/transformer.py for the Param declarations; this documents
    the layout: wq (d, H, Dh), wk/wv (d, KV, Dh), wo (H, Dh, d)."""
    return {}


def gqa_attention(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: LMConfig,
    *,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    kv_length: Optional[jax.Array] = None,
):
    """Returns (out (B,S,d), new_cache or None).

    Full mode (cache=None): causal self-attention over x.
    Decode mode: x is (B, 1, d); cache holds T_max positions; cache_index is
    the scalar write position; kv_length = number of valid cache positions
    AFTER the update (== cache_index + 1 normally).
    """
    B, S, _ = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    dt = x.dtype

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], eps=cfg.norm_eps)

    sin, cos = rotary_embedding(positions, Dh, theta=cfg.rope_theta)
    q = apply_rotary(q, sin, cos)
    k = apply_rotary(k, sin, cos)

    scale = Dh ** -0.5
    q = q * scale

    if cache is None:
        # ---------------- full causal self-attention (positions: (S,))
        qg = q.reshape(B, S, KV, G, Dh)
        if S >= CHUNK_THRESHOLD and S % CHUNK_SIZE == 0:
            chunk = CHUNK_SIZE

            def kv_chunk(c):
                kc = jax.lax.dynamic_slice_in_dim(k, c * chunk, chunk, axis=1)
                vc = jax.lax.dynamic_slice_in_dim(v, c * chunk, chunk, axis=1)
                return kc, vc

            ctx = _chunked_causal(qg, kv_chunk, S // chunk, chunk, positions, dt)
            ctx = ctx.reshape(B, S, H, Dh)
        else:
            scores = jnp.einsum("bskgd,btkd->bkgst", qg, k)
            mask = (positions[:, None] >= positions[None, :])[None, None, None]
            probs = _softmax_f32(scores, mask, dt)
            ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v).reshape(B, S, H, Dh)
        out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(dt))
        return out, {"k": k, "v": v}

    # ---------------- decode against the cache (scalar cache_index/length)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
    T = k_cache.shape[1]
    qg = q.reshape(B, S, KV, G, Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_cache.astype(dt))
    length = (cache_index + S) if kv_length is None else kv_length
    mask = (jnp.arange(T) < length)[None, None, None, None, :]
    probs = _softmax_f32(scores, mask, dt)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v_cache.astype(dt)).reshape(B, S, H, Dh)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(dt))
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------

def mla_attention(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: LMConfig,
    *,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    kv_length: Optional[jax.Array] = None,
    absorb: bool = False,
):
    """DeepSeek-V2/V3 multi-head latent attention.

    Params: wdq (d, q_lora), q_norm (q_lora,), wuq (q_lora, H, nope+rope),
            wdkv (d, kv_lora + rope), kv_norm (kv_lora,),
            wuk (kv_lora, H, nope), wuv (kv_lora, H, v_dim),
            wo (H, v_dim, d).
    """
    assert cfg.mla is not None
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    dt = x.dtype

    # --- queries through the low-rank bottleneck
    cq = rms_norm(x @ p["wdq"].astype(dt), p["q_norm"], eps=cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    sin, cos = rotary_embedding(positions, rope, theta=cfg.rope_theta)
    q_rope = apply_rotary(q_rope, sin, cos)

    # --- compressed KV + shared rope key
    ckv_full = x @ p["wdkv"].astype(dt)  # (B, S, kv_lora + rope)
    ckv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_norm"], eps=cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank :][..., None, :]  # (B, S, 1, rope)
    k_rope = apply_rotary(k_rope, sin, cos)[..., 0, :]  # (B, S, rope)

    scale = (nope + rope) ** -0.5

    if cache is not None:
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_index, axis=1
        )
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), cache_index, axis=1
        )
        new_cache = {"ckv": ckv, "krope": k_rope}
        ckv = ckv.astype(dt)
        k_rope = k_rope.astype(dt)
        T = ckv.shape[1]
        length = (cache_index + S) if kv_length is None else kv_length
        mask = (jnp.arange(T) < length)[None, None, None, :]
    else:
        new_cache = {"ckv": ckv, "krope": k_rope}
        T = S
        mask = (positions[:, None] >= positions[None, :])[None, None]

    if absorb and cache is not None:
        # fold W_uk into q, W_uv into the output: never expand K/V to H heads
        qa = jnp.einsum("bshn,rhn->bshr", q_nope, p["wuk"].astype(dt))
        scores = (
            jnp.einsum("bshr,btr->bhst", qa, ckv)
            + jnp.einsum("bshr,btr->bhst", q_rope, k_rope)
        ) * scale
        probs = _softmax_f32(scores, mask, dt)
        ctxa = jnp.einsum("bhst,btr->bshr", probs, ckv)  # (B,S,H,kv_lora)
        ctx = jnp.einsum("bshr,rhv->bshv", ctxa, p["wuv"].astype(dt))
    elif cache is None and S >= CHUNK_THRESHOLD and S % CHUNK_SIZE == 0:
        # chunked prefill/train: expand K/V from the latent one chunk at a
        # time (never materializes the (S, T) scores or full expanded K/V)
        chunk = CHUNK_SIZE

        def kv_chunk(c):
            ckv_c = jax.lax.dynamic_slice_in_dim(ckv, c * chunk, chunk, axis=1)
            kr_c = jax.lax.dynamic_slice_in_dim(k_rope, c * chunk, chunk, axis=1)
            k_nope_c = jnp.einsum("btr,rhn->bthn", ckv_c, p["wuk"].astype(dt))
            kr_b = jnp.broadcast_to(kr_c[:, :, None, :], kr_c.shape[:2] + (H, rope))
            kc = jnp.concatenate([k_nope_c, kr_b], axis=-1)
            vc = jnp.einsum("btr,rhv->bthv", ckv_c, p["wuv"].astype(dt))
            return kc, vc

        # view (B,S,H,1,D): KV=H, G=1 grouping
        q5 = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :] * scale
        ctx = _chunked_causal(
            q5, kv_chunk, S // chunk, chunk, positions, dt, v_dim=vdim
        )[:, :, :, 0, :]
    else:
        # naive: expand per-head keys/values from the latent
        k_nope = jnp.einsum("btr,rhn->bthn", ckv, p["wuk"].astype(dt))
        v = jnp.einsum("btr,rhv->bthv", ckv, p["wuv"].astype(dt))
        scores = (
            jnp.einsum("bshn,bthn->bhst", q_nope, k_nope)
            + jnp.einsum("bshr,btr->bhst", q_rope, k_rope)
        ) * scale
        probs = _softmax_f32(scores, mask, dt)
        ctx = jnp.einsum("bhst,bthv->bshv", probs, v)

    out = jnp.einsum("bshv,hvd->bsd", ctx, p["wo"].astype(dt))
    return out, new_cache
