from repro.models import params  # noqa: F401
