"""Shared neural-net layers (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
             gemma_style: bool = False) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if gemma_style else scale.astype(jnp.float32)
    return (y * w).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, *, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


def rotary_embedding(positions: jax.Array, head_dim: int, *, theta: float = 10000.0):
    """Returns (sin, cos) of shape positions.shape + (head_dim // 2,)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rotary(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); sin/cos: (..., seq, head_dim//2).

    Angles are computed in f32 (rotary_embedding); the rotation itself runs
    in the activation dtype so forward values AND backward cotangents stay
    bf16 — an f32 upcast here makes every sequence-parallel K/V all-gather
    (and its bwd) move 2x the bytes (EXPERIMENTS.md §Perf/H2-i3).
    """
    x1, x2 = jnp.split(x, 2, axis=-1)
    s = sin[..., None, :].astype(x.dtype)  # broadcast over heads
    c = cos[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def glu_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
            *, activation: str = "swiglu") -> jax.Array:
    """Gated-linear-unit MLP: act(x W_g) * (x W_u) W_d."""
    g = x @ w_gate.astype(x.dtype)
    u = x @ w_up.astype(x.dtype)
    if activation == "swiglu":
        h = jax.nn.silu(g) * u
    elif activation == "geglu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        raise ValueError(activation)
    return h @ w_down.astype(x.dtype)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over valid positions; logits (..., V) f32-upcast inside."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
