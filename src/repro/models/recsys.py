"""RecSys CTR models: FM, DeepFM, xDeepFM (CIN), AutoInt.

Shared substrate: 39 categorical fields, one id per field, embedded through a
single row-sharded concatenated table (per-field offsets) — the lookup is the
hot path and runs through ``dist.embedlookup`` (sharded) or the Pallas
``kernels/bag`` embedding-bag (single device).  First-order weights use a
(V, 1) table, the FM trick ``0.5 * ((sum_f v)^2 - sum_f v^2)`` gives the
O(F·D) pairwise interaction.

``retrieval_score`` serves the ``retrieval_cand`` shape: one query embedding
against n_candidates item embeddings sharded over every mesh axis — local
top-k then a gathered global top-k (no loop, no all-to-all of scores).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.dist.embedlookup import embedding_lookup
from repro.dist.sharding import DistCtx, act
from repro.models.params import Param

PyTree = Any


def field_offsets(cfg: RecsysConfig) -> jnp.ndarray:
    vocabs = cfg.vocabs[: cfg.n_sparse]
    return jnp.asarray([0] + list(jnp.cumsum(jnp.asarray(vocabs))[:-1]), jnp.int32)


def _padded_vocab(cfg: RecsysConfig, multiple: int = 2048) -> int:
    v = cfg.total_vocab
    return v + (-v) % multiple


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

def recsys_decls(cfg: RecsysConfig) -> dict:
    V = _padded_vocab(cfg)
    D = cfg.embed_dim
    F = cfg.n_sparse
    decls: dict = {
        "table": Param((V, D), ("table", "edim"), scale=0.01),
        "linear": Param((V, 1), ("table", "edim"), scale=0.01),
        "bias": Param((1,), ("edim",), init="zeros"),
    }
    if cfg.interaction in ("fm", "cin", "self-attn") and cfg.mlp:
        dims = (F * D,) + tuple(cfg.mlp) + (1,)
        decls["mlp"] = [
            {
                "w": Param((dims[i], dims[i + 1]), ("hidden", "hidden")),
                "b": Param((dims[i + 1],), ("hidden",), init="zeros"),
            }
            for i in range(len(dims) - 1)
        ]
    if cfg.interaction == "cin":
        hs = (F,) + tuple(cfg.cin_layers)
        decls["cin"] = [
            {"w": Param((hs[i + 1], hs[i], F), ("cin", "cin", "fields"))}
            for i in range(len(cfg.cin_layers))
        ]
        decls["cin_out"] = Param((sum(cfg.cin_layers), 1), ("cin", "edim"))
    if cfg.interaction == "self-attn":
        layers = []
        d_in = D
        for _ in range(cfg.n_attn_layers):
            layers.append(
                {
                    "wq": Param((d_in, cfg.n_heads, cfg.d_attn), ("edim", "heads", "attn")),
                    "wk": Param((d_in, cfg.n_heads, cfg.d_attn), ("edim", "heads", "attn")),
                    "wv": Param((d_in, cfg.n_heads, cfg.d_attn), ("edim", "heads", "attn")),
                    "wres": Param((d_in, cfg.n_heads * cfg.d_attn), ("edim", "attn")),
                }
            )
            d_in = cfg.n_heads * cfg.d_attn
        decls["attn"] = layers
        decls["attn_out"] = Param((cfg.n_sparse * d_in, 1), ("hidden", "edim"))
    return decls


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def _embed_fields(params, ids, cfg, dctx):
    """ids (B, F) per-field -> (emb (B, F, D), lin (B, F))."""
    flat = ids + field_offsets(cfg)[None, :]
    emb = embedding_lookup(params["table"], flat, dctx)
    lin = embedding_lookup(params["linear"], flat, dctx)[..., 0]
    return emb, lin


def _fm_pairwise(emb: jax.Array) -> jax.Array:
    """0.5 * ((sum_f v)^2 - sum_f v^2) summed over D. emb (B, F, D) -> (B,)."""
    s = jnp.sum(emb, axis=1)
    s2 = jnp.sum(emb * emb, axis=1)
    return 0.5 * jnp.sum(s * s - s2, axis=-1)


def _mlp(params_list, x):
    h = x
    for i, layer in enumerate(params_list):
        h = h @ layer["w"] + layer["b"]
        if i < len(params_list) - 1:
            h = jax.nn.relu(h)
    return h


def _cin(params_list, x0: jax.Array) -> jax.Array:
    """Compressed Interaction Network (xDeepFM). x0 (B, F, D) -> (B, sum Hk)."""
    pooled = []
    xk = x0
    for layer in params_list:
        # z (B, Hk, F, D) = outer product of current row-features with x0
        z = jnp.einsum("bhd,bfd->bhfd", xk, x0)
        xk = jnp.einsum("bhfd,ghf->bgd", z, layer["w"])
        pooled.append(jnp.sum(xk, axis=-1))  # (B, Hk+1)
    return jnp.concatenate(pooled, axis=-1)


def _autoint(params_list, emb: jax.Array) -> jax.Array:
    """Self-attention over field tokens. emb (B, F, D) -> (B, F, H*dA)."""
    h = emb
    for layer in params_list:
        q = jnp.einsum("bfd,dha->bfha", h, layer["wq"])
        k = jnp.einsum("bfd,dha->bfha", h, layer["wk"])
        v = jnp.einsum("bfd,dha->bfha", h, layer["wv"])
        scores = jnp.einsum("bfha,bgha->bhfg", q, k) / math.sqrt(q.shape[-1])
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhfg,bgha->bfha", probs, v)
        B, F = h.shape[:2]
        ctx = ctx.reshape(B, F, -1)
        res = h @ layer["wres"]
        h = jax.nn.relu(ctx + res)
    return h


# ---------------------------------------------------------------------------
# forward / loss / serving
# ---------------------------------------------------------------------------

def recsys_forward(
    params: PyTree, ids: jax.Array, cfg: RecsysConfig,
    dctx: Optional[DistCtx] = None,
) -> jax.Array:
    """ids (B, F) -> logits (B,)."""
    ids = act(dctx, ids, "batch", "fields")
    emb, lin = _embed_fields(params, ids, cfg, dctx)
    emb = act(dctx, emb, "batch", "fields", "edim")
    logit = jnp.sum(lin, axis=1) + params["bias"][0]

    if cfg.interaction == "fm2":  # pure FM (Rendle)
        return logit + _fm_pairwise(emb)
    if cfg.interaction == "fm":  # DeepFM: FM + deep MLP
        deep = _mlp(params["mlp"], emb.reshape(emb.shape[0], -1))[:, 0]
        return logit + _fm_pairwise(emb) + deep
    if cfg.interaction == "cin":  # xDeepFM: CIN + deep MLP
        cin = _cin(params["cin"], emb) @ params["cin_out"]
        deep = _mlp(params["mlp"], emb.reshape(emb.shape[0], -1))[:, 0]
        return logit + cin[:, 0] + deep
    if cfg.interaction == "self-attn":  # AutoInt
        h = _autoint(params["attn"], emb)
        out = h.reshape(h.shape[0], -1) @ params["attn_out"]
        return logit + out[:, 0]
    raise ValueError(cfg.interaction)


def recsys_loss(
    params: PyTree, batch: dict, cfg: RecsysConfig,
    dctx: Optional[DistCtx] = None,
) -> tuple[jax.Array, dict]:
    """Binary cross-entropy CTR loss. batch: ids (B, F), labels (B,)."""
    logits = recsys_forward(params, batch["ids"], cfg, dctx)
    y = batch["labels"].astype(jnp.float32)
    ll = jax.nn.log_sigmoid(logits)
    lnl = jax.nn.log_sigmoid(-logits)
    loss = -jnp.mean(y * ll + (1.0 - y) * lnl)
    auc_proxy = jnp.mean((logits > 0) == (y > 0.5))
    return loss, {"loss": loss, "acc": auc_proxy}


def user_embedding(
    params: PyTree, ids: jax.Array, cfg: RecsysConfig,
    dctx: Optional[DistCtx] = None,
) -> jax.Array:
    """Pooled query-side embedding for retrieval: sum of field embeddings."""
    emb, _ = _embed_fields(params, ids, cfg, dctx)
    return jnp.sum(emb, axis=1)  # (B, D)


def retrieval_score(
    user: jax.Array,  # (B, D)
    cand: jax.Array,  # (N, D) sharded over every mesh axis
    *,
    k: int = 100,
    dctx: Optional[DistCtx] = None,
) -> tuple[jax.Array, jax.Array]:
    """Top-k candidates by inner product; batched dot, no loops."""
    cand = act(dctx, cand, "cand", None)
    scores = jnp.einsum("bd,nd->bn", user, cand)
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_s, top_i
