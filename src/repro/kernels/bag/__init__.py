from repro.kernels.bag import ops, ref  # noqa: F401
from repro.kernels.bag.bag import embedding_bag_pallas  # noqa: F401
