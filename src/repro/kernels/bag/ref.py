"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(
    table: jnp.ndarray,
    ids: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    *,
    combine: str = "sum",
) -> jnp.ndarray:
    """table (V, D), ids (B, S) int32 -> (B, D).

    ``combine`` in {'sum', 'mean'}; optional per-sample weights (B, S).
    Negative ids are padding and contribute zero (and don't count for mean).
    """
    valid = (ids >= 0).astype(table.dtype)  # (B, S)
    rows = table[jnp.maximum(ids, 0)]  # (B, S, D)
    w = valid if weights is None else weights * valid
    out = jnp.einsum("bs,bsd->bd", w, rows)
    if combine == "mean":
        denom = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        out = out / denom
    return out
