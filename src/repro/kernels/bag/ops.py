"""Jit'd public wrapper for the embedding-bag kernel, plus the pure-jnp
segment-sum formulation used by the sharded recsys models (the kernel is the
single-device fast path; the jnp path composes with shard_map)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bag.bag import embedding_bag_pallas

_INTERPRET = jax.default_backend() != "tpu"


def embedding_bag(
    table: jax.Array,
    ids: jax.Array,
    weights: jax.Array | None = None,
    *,
    combine: str = "sum",
    impl: str = "pallas",
) -> jax.Array:
    if impl == "pallas":
        return embedding_bag_pallas(
            table, ids, weights, combine=combine, interpret=_INTERPRET
        )
    from repro.kernels.bag.ref import embedding_bag_ref

    return embedding_bag_ref(table, ids, weights, combine=combine)
