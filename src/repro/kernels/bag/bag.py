"""Pallas TPU kernel: embedding bag (ragged gather + segment reduce).

The recsys hot path (kernel_taxonomy §B.6): JAX has no native EmbeddingBag, so
this kernel implements the idiomatic TPU pattern — bag indices are **scalar-
prefetched into SMEM** so the BlockSpec index_map can select which table row
to DMA for each grid step.  The MXU never sees the gather; rows stream
HBM -> VMEM one (1, D) block at a time and accumulate on the VPU.

grid = (B, S): step (b, s) DMAs ``table[ids[b, s]]`` and adds it into
``out[b]``.  Padding ids (< 0) are clamped to row 0 and masked by weight 0 —
the DMA still happens (static schedule), which is exactly how production TPU
embedding kernels keep the pipeline dense.

On real hardware one would add multiple-rows-per-step (S tiling) and a
revisiting-output accumulator; this shape is kept minimal because the
container validates in interpret mode only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _bag_kernel(ids_ref, w_ref, table_ref, o_ref, *, s_steps: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[0, 0]  # scalar weight for (b, s) — 0.0 for padding
    o_ref[...] += w * table_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("combine", "interpret"))
def embedding_bag_pallas(
    table: jax.Array,
    ids: jax.Array,
    weights: jax.Array | None = None,
    *,
    combine: str = "sum",
    interpret: bool = True,
) -> jax.Array:
    """table (V, D), ids (B, S) -> (B, D) with sum/mean combine."""
    V, D = table.shape
    B, S = ids.shape
    valid = (ids >= 0).astype(jnp.float32)
    w = valid if weights is None else weights.astype(jnp.float32) * valid
    safe_ids = jnp.maximum(ids, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # safe_ids lands in SMEM, visible to index_maps
        grid=(B, S),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, s, ids_sref: (b, s)),  # weight
            pl.BlockSpec((1, D), lambda b, s, ids_sref: (ids_sref[b, s], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, s, ids_sref: (b, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_bag_kernel, s_steps=S),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(safe_ids, w, table)
    if combine == "mean":
        denom = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        out = out / denom
    return out
