"""Pallas TPU kernels for the compute hot spots (DESIGN.md §4).

qpath — (min, combine) semiring matmul driving the canonical projection.
pdist — tiled pairwise distance matrices (MXU cross-term + fused epilogue).
bag   — embedding-bag gather/reduce with scalar-prefetched indices.

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, backend-resolved interpret flag), ref.py (pure-jnp oracle).
"""
