"""Version compatibility shared by every kernel package.

jax renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``; and the
kernels run compiled on TPU but interpreted elsewhere — both resolved here
so the policy lives in one place.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def default_interpret() -> bool:
    """Kernels compile through Mosaic on TPU, interpret everywhere else."""
    return jax.default_backend() != "tpu"
