"""Pure-jnp oracle for the fused distance + top-k kernel.

Materializes the full (m, n) matrix via ``kernels/pdist/ref`` and selects
with ``jax.lax.top_k`` — the semantics (ascending distances, lowest-index
tie-breaking, -1 indices past the valid candidate count) that both the
Pallas kernel and the blocked ``core/scan`` path must reproduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pdist.ref import pdist_ref


def topk_ref(
    X: jnp.ndarray,
    Y: jnp.ndarray,
    *,
    k: int,
    metric: str = "sqeuclidean",
    exclude_self: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    m, n = X.shape[0], Y.shape[0]
    D = pdist_ref(X, Y, metric=metric)
    if exclude_self:
        rows = jnp.arange(m)[:, None]
        cols = jnp.arange(n)[None, :]
        D = jnp.where(rows == cols, jnp.inf, D)
    if k > n:  # pad with +inf columns so top_k stays defined
        D = jnp.pad(D, ((0, 0), (0, k - n)), constant_values=jnp.inf)
    neg, idx = jax.lax.top_k(-D, k)
    # +inf slots (padding or masked candidates) are "no result": idx -1
    idx = jnp.where(jnp.isinf(-neg) | (idx >= n), -1, idx.astype(jnp.int32))
    return -neg, idx
