"""Jit'd public wrappers for the fused distance + top-k kernel.

Resolves interpret-vs-compiled from the backend (like ``pdist/ops``) and
picks tile sizes from the problem shape (m, n, d, k) with the same
lane-alignment rules as ``pdist``: 128-wide tiles, the elementwise-family
d-tile dropped to 32 to bound the VMEM cube, the int8 regime's query tile
sublane-aligned to the int8 minimum (32).

``topk`` serves the f32 regimes (now including masked scans — the ``valid``
operand); ``topk_quant`` serves the int8 corpus-code regime fed by
``core/quant.QuantStore.device_view()``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels._compat import default_interpret
from repro.kernels.topk.topk import (
    CUBE_METRICS,
    MATMUL_METRICS,
    QUANT_METRICS,
    SUPPORTED,
    topk_pallas,
    topk_quant_pallas,
)

_INTERPRET = default_interpret()

__all__ = [
    "topk", "topk_quant", "tile_config", "SUPPORTED", "MATMUL_METRICS",
    "CUBE_METRICS", "QUANT_METRICS",
]


def _round_up(x: int, mult: int) -> int:
    return x + (-x) % mult


def tile_config(m: int, n: int, d: int, k: int, metric: str,
                *, quantized: bool = False) -> dict:
    """(bm, bn, bk) for a (m, d) x (n, d) -> (m, k) scan.

    * bm: 128, shrunk (sublane-aligned) for small query batches so padding
      doesn't dominate — 8-aligned for f32 tiles, 32-aligned for the int8
      regime (the int8 minimum sublane tile).
    * bn: 128 by default; doubled for dataset-dominated MXU scans
      (n >= 64K) so the per-tile merge amortizes over more candidates.  The
      cube family keeps bn = 128 — widening it would blow the 2 MiB bound
      on the (bm, bk, bn) VPU intermediate.
    * bk: 128 for the MXU families (f32 and int8), 32 for the VPU cube
      family (bounds the (bm, bk, bn) cube at 2 MiB), shrunk for low-d data.
    """
    sub = 32 if quantized else 8
    bm = min(128, _round_up(max(m, 1), sub))
    bn = 256 if (n >= 65536 and metric not in CUBE_METRICS) else 128
    bk = 32 if metric in CUBE_METRICS else 128
    bk = min(bk, _round_up(max(d, 1), 8))
    return dict(bm=bm, bn=bn, bk=bk)


def topk(
    X: jax.Array,
    Y: jax.Array,
    *,
    k: int,
    metric: str = "sqeuclidean",
    exclude_self: bool = False,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    cfg = tile_config(X.shape[0], Y.shape[0], X.shape[1], k, metric)
    return topk_pallas(
        X, Y, k=k, metric=metric, exclude_self=exclude_self, valid=valid,
        interpret=_INTERPRET, **cfg,
    )


def topk_quant(
    Q: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    *,
    k: int,
    metric: str = "euclidean",
    valid: jax.Array | None = None,
    sqnorms: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Int8 fused scan over corpus codes (first pass of a quantized engine).
    ``sqnorms`` — per-row squared dequant norms — is recomputed when the
    caller has no ``QuantStore.device_view()`` at hand."""
    if sqnorms is None:
        dec = codes.astype(jnp.float32) * scales[None, :]
        sqnorms = jnp.sum(dec * dec, axis=1)
    cfg = tile_config(
        Q.shape[0], codes.shape[0], Q.shape[1], k, metric, quantized=True
    )
    return topk_quant_pallas(
        Q, codes, scales, sqnorms, k=k, metric=metric, valid=valid,
        interpret=_INTERPRET, **cfg,
    )
