"""Jit'd public wrapper for the fused distance + top-k kernel.

Resolves interpret-vs-compiled from the backend (like ``pdist/ops``) and
picks tile sizes from the problem shape (m, n, d, k) with the same
lane-alignment rules as ``pdist``: 128-wide tiles, the elementwise-family
d-tile dropped to 32 to bound the VMEM cube.
"""
from __future__ import annotations

from repro.kernels._compat import default_interpret
from repro.kernels.topk.topk import (
    CUBE_METRICS,
    MATMUL_METRICS,
    SUPPORTED,
    topk_pallas,
)

_INTERPRET = default_interpret()

__all__ = ["topk", "tile_config", "SUPPORTED", "MATMUL_METRICS", "CUBE_METRICS"]


def _round_up(x: int, mult: int) -> int:
    return x + (-x) % mult


def tile_config(m: int, n: int, d: int, k: int, metric: str) -> dict:
    """(bm, bn, bk) for a (m, d) x (n, d) -> (m, k) scan.

    * bm: 128, shrunk (sublane-aligned) for small query batches so padding
      doesn't dominate.
    * bn: 128 by default; doubled for dataset-dominated MXU scans
      (n >= 64K) so the per-tile merge amortizes over more candidates.  The
      cube family keeps bn = 128 — widening it would blow the 2 MiB bound
      on the (bm, bk, bn) VPU intermediate.
    * bk: 128 for the MXU family, 32 for the VPU cube family (bounds the
      (bm, bk, bn) cube at 2 MiB), shrunk for low-d data.
    """
    bm = min(128, _round_up(max(m, 1), 8))
    bn = 256 if (n >= 65536 and metric not in CUBE_METRICS) else 128
    bk = 32 if metric in CUBE_METRICS else 128
    bk = min(bk, _round_up(max(d, 1), 8))
    return dict(bm=bm, bn=bn, bk=bk)


def topk(
    X: jax.Array,
    Y: jax.Array,
    *,
    k: int,
    metric: str = "sqeuclidean",
    exclude_self: bool = False,
) -> tuple[jax.Array, jax.Array]:
    cfg = tile_config(X.shape[0], Y.shape[0], X.shape[1], k, metric)
    return topk_pallas(
        X, Y, k=k, metric=metric, exclude_self=exclude_self,
        interpret=_INTERPRET, **cfg,
    )
