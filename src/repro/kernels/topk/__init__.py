"""Fused pairwise-distance + streaming top-k kernel family (DESIGN.md §4.3)."""
from repro.kernels.topk.ops import SUPPORTED, tile_config, topk  # noqa: F401
from repro.kernels.topk.ref import topk_ref  # noqa: F401
from repro.kernels.topk.topk import topk_pallas  # noqa: F401
