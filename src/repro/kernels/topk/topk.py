"""Pallas TPU kernel: fused pairwise distance + streaming top-k (DESIGN.md §4.3).

Every scan path in the pipeline (kNN-graph build, brute-force ground truth,
IVF scoring, two-stage rerank) is "compute an (m, n) distance matrix, select
k" — but only (m, k) of the result survives.  Materializing the matrix costs
O(m·n) HBM writes + reads and an O(m·n·log n) host-side selection.  This
kernel never materializes it: the distance tile lives in VMEM, a running
(k-wide value, index) top-k accumulator lives in VMEM scratch across n-tiles,
and only the final (m, k) result is ever written to HBM.

Structure (grid = (m/bm, n/bn, d/bk), d innermost, n-then-d "arbitrary"):

* distance tile — same two regimes as ``kernels/pdist``: the matmul family
  (sqeuclidean / euclidean / cosine / dot) accumulates the MXU cross term +
  squared norms in f32 scratch across d-tiles; the elementwise family
  (manhattan / chebyshev) reduces the (bm, bk, bn) |x-y| cube on the VPU.
* streaming selection — at the last d-step the finished (bm, bn) tile is
  merged into the (bm, k) running top-k by k rounds of masked min-extraction
  over the (bm, k + bn) concatenation (a partition merge: each round peels
  the row minimum and poisons it with +inf).  Ties resolve to the lowest
  dataset index, matching ``jax.lax.top_k`` on the negated matrix.
* tile skipping — a tile whose global minimum is no better than every row's
  current k-th distance cannot change the accumulator; the merge is wrapped
  in ``pl.when`` so converged rows stream past most of the dataset at pure
  distance-compute cost.

Self-exclusion (kNN graphs: X scanned against itself) is an index mask
``global_row == global_col`` applied to the tile before the merge, so no
(n, n) eye matrix is ever built.

Two extensions (DESIGN.md §13):

* per-candidate ``valid`` mask — an optional (1, n) 0/1 operand tiled
  (1, bn) alongside the dataset; masked columns are +inf'd in the epilogue
  before the merge, so irregular candidate sets (IVF padded lists, filter
  predicates, live delta slots) run the fused kernel instead of falling
  back to the blocked jnp path.  An all-masked tile simply fails the
  can-improve bound and streams past at pure distance-compute cost.
* int8 regime (``topk_quant_pallas``) — the corpus arrives as per-dimension
  absmax codes (``core/quant``): the cross term is an int8 x int8 MXU
  matmul accumulated in int32 scratch (the query is folded against the
  corpus scales and row-quantized outside the kernel), and the epilogue
  dequantizes in f32 scratch: ``d2 = |q|^2 + |dec(c)|^2 - 2*alpha*acc``
  with both norm vectors precomputed operands.  HBM reads 1 byte/dim of
  corpus instead of 4 — the memory-bandwidth win the quantized engines
  are built on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant as quant_lib
from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels._compat import default_interpret

EPS = 1e-12
MATMUL_METRICS = ("sqeuclidean", "euclidean", "cosine", "dot")
CUBE_METRICS = ("manhattan", "chebyshev")
SUPPORTED = MATMUL_METRICS + CUBE_METRICS
#: metrics the int8 regime serves (the euclidean family — cross-term math)
QUANT_METRICS = ("sqeuclidean", "euclidean")


def _merge_topk(best_d_ref, best_i_ref, dtile, cols, *, k: int):
    """Merge a finished (bm, bn) distance tile into the (bm, k) running
    top-k: k rounds of min-extraction over the (bm, k + bn) concatenation."""
    cat_d = jnp.concatenate([best_d_ref[...], dtile], axis=1)
    cat_i = jnp.concatenate([best_i_ref[...], cols], axis=1)
    bm, width = cat_d.shape
    iot = jax.lax.broadcasted_iota(jnp.int32, (bm, width), 1)
    vals, idxs = [], []
    for _ in range(k):
        v = jnp.min(cat_d, axis=1)
        ismin = cat_d == v[:, None]
        pos = jnp.min(jnp.where(ismin, iot, width), axis=1)  # first minimum
        sel = iot == pos[:, None]
        idx = jnp.sum(jnp.where(sel, cat_i, 0), axis=1)
        vals.append(v)
        idxs.append(idx)
        cat_d = jnp.where(sel, jnp.inf, cat_d)
    best_d_ref[...] = jnp.stack(vals, axis=1)
    best_i_ref[...] = jnp.stack(idxs, axis=1)


def _mask_tile(dtile, i, j, vtile, *, bm, bn, n, exclude_self):
    """+inf out padded columns (global col >= n), masked candidates
    (``vtile`` (1, bn) 0/1, broadcast over query rows) and, for self-scans,
    the diagonal global_row == global_col."""
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    dtile = jnp.where(cols >= n, jnp.inf, dtile)
    if vtile is not None:
        dtile = jnp.where(vtile == 0.0, jnp.inf, dtile)
    if exclude_self:
        rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
        dtile = jnp.where(rows == cols, jnp.inf, dtile)
    return dtile, cols


def _select_and_store(best_d, best_i, o_d_ref, o_i_ref, dtile, i, j,
                      *, bm, bn, n, k, n_steps, exclude_self, vtile=None):
    """Shared epilogue: mask, conditional merge, final store."""
    dtile, cols = _mask_tile(
        dtile, i, j, vtile, bm=bm, bn=bn, n=n, exclude_self=exclude_self
    )
    # the k-th best of the worst row bounds what this tile could improve
    can_improve = jnp.min(dtile) < jnp.max(best_d[:, k - 1])

    @pl.when(can_improve)
    def _merge():
        _merge_topk(best_d, best_i, dtile, cols, k=k)

    @pl.when(j == n_steps - 1)
    def _store():
        o_d_ref[...] = best_d[...]
        o_i_ref[...] = best_i[...]


def _matmul_kernel(*refs, metric: str, k: int, n: int, k_steps: int,
                   n_steps: int, bm: int, bn: int, exclude_self: bool,
                   has_valid: bool):
    if has_valid:
        x_ref, y_ref, v_ref, o_d_ref, o_i_ref, acc, sx, sy, best_d, best_i = refs
    else:
        x_ref, y_ref, o_d_ref, o_i_ref, acc, sx, sy, best_d, best_i = refs
        v_ref = None
    i, j, ks = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((j == 0) & (ks == 0))
    def _init_best():
        best_d[...] = jnp.full_like(best_d, jnp.inf)
        best_i[...] = jnp.full_like(best_i, -1)

    @pl.when(ks == 0)
    def _init_acc():
        acc[...] = jnp.zeros_like(acc)
        sx[...] = jnp.zeros_like(sx)
        sy[...] = jnp.zeros_like(sy)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    y = y_ref[...].astype(jnp.float32)  # (bn, bk)
    acc[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    sx[...] += jnp.sum(x * x, axis=1, keepdims=True)
    sy[...] += jnp.sum(y * y, axis=1, keepdims=True)

    @pl.when(ks == k_steps - 1)
    def _epilogue():
        dotv = acc[...]
        if metric == "dot":
            dtile = -dotv
        elif metric == "cosine":
            nx = jnp.sqrt(jnp.maximum(sx[...], EPS))  # (bm, 1)
            ny = jnp.sqrt(jnp.maximum(sy[...], EPS))  # (bn, 1)
            dtile = 1.0 - dotv / (nx * ny.T)
        else:
            d2 = jnp.maximum(sx[...] + sy[...].T - 2.0 * dotv, 0.0)
            dtile = jnp.sqrt(d2) if metric == "euclidean" else d2
        _select_and_store(
            best_d, best_i, o_d_ref, o_i_ref, dtile, i, j, bm=bm, bn=bn,
            n=n, k=k, n_steps=n_steps, exclude_self=exclude_self,
            vtile=None if v_ref is None else v_ref[...],
        )


def _cube_kernel(*refs, metric: str, k: int, n: int, k_steps: int,
                 n_steps: int, bm: int, bn: int, exclude_self: bool,
                 has_valid: bool):
    if has_valid:
        x_ref, y_ref, v_ref, o_d_ref, o_i_ref, dist, best_d, best_i = refs
    else:
        x_ref, y_ref, o_d_ref, o_i_ref, dist, best_d, best_i = refs
        v_ref = None
    i, j, ks = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((j == 0) & (ks == 0))
    def _init_best():
        best_d[...] = jnp.full_like(best_d, jnp.inf)
        best_i[...] = jnp.full_like(best_i, -1)

    @pl.when(ks == 0)
    def _init_dist():
        dist[...] = jnp.zeros_like(dist)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    y = y_ref[...].astype(jnp.float32)  # (bn, bk)
    cube = jnp.abs(x[:, :, None] - y.T[None, :, :])  # (bm, bk, bn)
    if metric == "manhattan":
        dist[...] += jnp.sum(cube, axis=1)
    else:  # chebyshev
        dist[...] = jnp.maximum(dist[...], jnp.max(cube, axis=1))

    @pl.when(ks == k_steps - 1)
    def _epilogue():
        _select_and_store(
            best_d, best_i, o_d_ref, o_i_ref, dist[...], i, j, bm=bm, bn=bn,
            n=n, k=k, n_steps=n_steps, exclude_self=exclude_self,
            vtile=None if v_ref is None else v_ref[...],
        )


def _int8_kernel(*refs, metric: str, k: int, n: int, k_steps: int,
                 n_steps: int, bm: int, bn: int, exclude_self: bool,
                 has_valid: bool):
    """Int8 regime: codes arrive as int8, the cross term runs on the MXU in
    int8 x int8 -> int32, and dequantization happens once per finished tile
    in f32: ``d2 = |q|^2 + |dec(c)|^2 - 2 * alpha_row * acc`` (alpha is the
    per-query scale of the scale-folded, row-quantized query; both squared
    norms are precomputed operands)."""
    if has_valid:
        (x_ref, y_ref, alpha_ref, xn_ref, yn_ref, v_ref,
         o_d_ref, o_i_ref, acc, best_d, best_i) = refs
    else:
        (x_ref, y_ref, alpha_ref, xn_ref, yn_ref,
         o_d_ref, o_i_ref, acc, best_d, best_i) = refs
        v_ref = None
    i, j, ks = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((j == 0) & (ks == 0))
    def _init_best():
        best_d[...] = jnp.full_like(best_d, jnp.inf)
        best_i[...] = jnp.full_like(best_i, -1)

    @pl.when(ks == 0)
    def _init_acc():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(ks == k_steps - 1)
    def _epilogue():
        cross = acc[...].astype(jnp.float32) * alpha_ref[...]  # (bm, bn)
        d2 = jnp.maximum(xn_ref[...] + yn_ref[...] - 2.0 * cross, 0.0)
        dtile = jnp.sqrt(d2) if metric == "euclidean" else d2
        _select_and_store(
            best_d, best_i, o_d_ref, o_i_ref, dtile, i, j, bm=bm, bn=bn,
            n=n, k=k, n_steps=n_steps, exclude_self=exclude_self,
            vtile=None if v_ref is None else v_ref[...],
        )


def _call_common(M, N, grid, k, bm, bn, bk, interpret):
    """Grid/spec/output plumbing shared by the f32 and int8 entry points.
    Operand order: X-like (bm, bk), Y-like (bn, bk), [extras...], and —
    when masked — the (1, bn) valid tile riding immediately before the
    outputs."""
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
        pl.BlockSpec((bn, bk), lambda i, j, s: (j, s)),
    ]
    return dict(
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j, s: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j, s: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, k), jnp.float32),
            jax.ShapeDtypeStruct((M, k), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )


_VALID_SPEC = lambda bn: pl.BlockSpec((1, bn), lambda i, j, s: (0, j))


def _pad_valid(valid, n, N):
    """(n,) bool-ish -> (1, N) f32 0/1 operand (padding columns 0 — they
    are also masked by the col >= n guard, belt and braces)."""
    v = jnp.asarray(valid).astype(jnp.float32).reshape(1, n)
    return jnp.pad(v, ((0, 0), (0, N - n)))


@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "bm", "bn", "bk", "exclude_self", "interpret"),
)
def topk_pallas(
    X: jax.Array,
    Y: jax.Array,
    *,
    k: int,
    metric: str = "sqeuclidean",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    exclude_self: bool = False,
    valid: jax.Array | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused scan: k nearest rows of Y for every row of X.

    Returns (dists (m, k) f32 ascending, idxs (m, k) int32; -1 where fewer
    than k valid candidates exist).  The (m, n) distance matrix is never
    materialized in HBM.  ``exclude_self`` masks global_row == global_col
    (callers must pass X is Y row-aligned for it to mean "self");
    ``valid`` (n,) bool masks candidates out entirely — they surface only
    as (-1, +inf) "no result" slots, exactly like the jnp scan path.
    """
    if metric not in SUPPORTED:
        raise ValueError(f"topk kernel does not support metric {metric!r}")
    if interpret is None:
        interpret = default_interpret()
    m, d = X.shape
    n, d2 = Y.shape
    assert d == d2, (X.shape, Y.shape)
    k = int(k)
    if metric in CUBE_METRICS:
        bk = min(bk, 32)

    pm, pn, pk = (-m) % bm, (-n) % bn, (-d) % bk
    Xp = jnp.pad(X, ((0, pm), (0, pk)))
    Yp = jnp.pad(Y, ((0, pn), (0, pk)))
    M, N, K = Xp.shape[0], Yp.shape[0], Xp.shape[1]
    grid = (M // bm, N // bn, K // bk)

    has_valid = valid is not None
    kw = dict(
        metric=metric, k=k, n=n, k_steps=grid[2], n_steps=grid[1],
        bm=bm, bn=bn, exclude_self=exclude_self, has_valid=has_valid,
    )
    common = _call_common(M, N, grid, k, bm, bn, bk, interpret)
    args = (Xp, Yp)
    if has_valid:
        common["in_specs"].append(_VALID_SPEC(bn))
        args = args + (_pad_valid(valid, n, N),)
    select_scratch = [
        pltpu.VMEM((bm, k), jnp.float32),  # running top-k distances
        pltpu.VMEM((bm, k), jnp.int32),  # running top-k indices
    ]
    if metric in MATMUL_METRICS:
        dists, idxs = pl.pallas_call(
            functools.partial(_matmul_kernel, **kw),
            scratch_shapes=[
                pltpu.VMEM((bm, bn), jnp.float32),
                pltpu.VMEM((bm, 1), jnp.float32),
                pltpu.VMEM((bn, 1), jnp.float32),
            ] + select_scratch,
            **common,
        )(*args)
    else:
        dists, idxs = pl.pallas_call(
            functools.partial(_cube_kernel, **kw),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)] + select_scratch,
            **common,
        )(*args)
    dists, idxs = dists[:m], idxs[:m]
    # selections from padded columns (possible only when k > #valid) -> -1
    idxs = jnp.where(idxs >= n, -1, idxs)
    return dists, idxs


@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "bm", "bn", "bk", "interpret"),
)
def topk_quant_pallas(
    Q: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    sqnorms: jax.Array,
    *,
    k: int,
    metric: str = "euclidean",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    valid: jax.Array | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused int8 scan: k nearest corpus codes for every f32 query row.

    ``codes`` (n, d) int8 / ``scales`` (d,) f32 / ``sqnorms`` (n,) f32 are
    a ``core/quant.QuantStore.device_view()``.  The query side is prepared
    here, once per batch: fold the corpus scales into the query
    (``x~ = q * s``, so the cross term becomes an integer matmul against
    the raw codes) and row-quantize it with its own absmax ``alpha``.  The
    kernel then computes ``d^2 ~= |q|^2 + |dec(c)|^2 - 2*alpha*(xq . c)``
    per tile — approximate by one extra query-side quantization step vs
    the jnp dequant fallback, which the engines' exact f32 rerank absorbs.
    """
    if metric not in QUANT_METRICS:
        raise ValueError(f"int8 topk regime does not support metric {metric!r}")
    if interpret is None:
        interpret = default_interpret()
    m, d = Q.shape
    n, d2 = codes.shape
    assert d == d2, (Q.shape, codes.shape)
    k = int(k)

    Q = Q.astype(jnp.float32)
    xs = Q * scales[None, :]
    alpha = quant_lib.absmax_scales(xs, axis=1, keepdims=True)  # (m, 1)
    xq = quant_lib.encode(xs, alpha)
    xn = jnp.sum(Q * Q, axis=1, keepdims=True)  # (m, 1)

    pm, pn, pk = (-m) % bm, (-n) % bn, (-d) % bk
    Xq = jnp.pad(xq, ((0, pm), (0, pk)))
    Yq = jnp.pad(codes, ((0, pn), (0, pk)))
    M, N, K = Xq.shape[0], Yq.shape[0], Xq.shape[1]
    grid = (M // bm, N // bn, K // bk)

    has_valid = valid is not None
    kw = dict(
        metric=metric, k=k, n=n, k_steps=grid[2], n_steps=grid[1],
        bm=bm, bn=bn, exclude_self=False, has_valid=has_valid,
    )
    common = _call_common(M, N, grid, k, bm, bn, bk, interpret)
    common["in_specs"].extend([
        pl.BlockSpec((bm, 1), lambda i, j, s: (i, 0)),  # alpha
        pl.BlockSpec((bm, 1), lambda i, j, s: (i, 0)),  # |q|^2
        pl.BlockSpec((1, bn), lambda i, j, s: (0, j)),  # |dec(c)|^2
    ])
    args = (
        Xq, Yq,
        jnp.pad(alpha, ((0, pm), (0, 0)), constant_values=1.0),
        jnp.pad(xn, ((0, pm), (0, 0))),
        jnp.pad(sqnorms.reshape(1, n), ((0, 0), (0, pn))),
    )
    if has_valid:
        common["in_specs"].append(_VALID_SPEC(bn))
        args = args + (_pad_valid(valid, n, N),)
    dists, idxs = pl.pallas_call(
        functools.partial(_int8_kernel, **kw),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),  # int8 MXU accumulator
            pltpu.VMEM((bm, k), jnp.float32),
            pltpu.VMEM((bm, k), jnp.int32),
        ],
        **common,
    )(*args)
    dists, idxs = dists[:m], idxs[:m]
    idxs = jnp.where(idxs >= n, -1, idxs)
    return dists, idxs
