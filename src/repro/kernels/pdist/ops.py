"""Jit'd public wrapper for the pairwise-distance kernel."""
from __future__ import annotations

import jax

from repro.kernels._compat import default_interpret
from repro.kernels.pdist.pdist import pdist_pallas

_INTERPRET = default_interpret()

SUPPORTED = ("sqeuclidean", "euclidean", "cosine", "dot", "manhattan", "chebyshev")


def pdist(X: jax.Array, Y: jax.Array, *, metric: str = "sqeuclidean") -> jax.Array:
    return pdist_pallas(X, Y, metric=metric, interpret=_INTERPRET)
