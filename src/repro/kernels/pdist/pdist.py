"""Pallas TPU kernel: tiled pairwise distance matrix (query x dataset).

Feeds the kNN-graph build, IVF scoring and the two-stage rerank.  Two kernel
regimes (DESIGN.md §4):

* matmul family (sqeuclidean / euclidean / cosine / dot):
  ``|x|^2 + |y|^2 - 2 x.yT`` — the cross term runs on the MXU; squared norms
  accumulate in f32 VMEM scratch across d-tiles; the epilogue (norm add,
  clamp, sqrt / cosine normalize) is fused into the final k-step so the
  distance matrix is written to HBM exactly once.
* elementwise family (manhattan / chebyshev):
  (bm, bk, bn) |x - y| cube reduced on the VPU, accumulated directly into the
  output tile across k-steps.

grid = (m/bm, n/bn, d/bk), k innermost.  Defaults (128, 128, 128) keep every
tile lane-aligned; the cube path drops bk to 32 to bound the VMEM cube at
128*32*128*4B = 2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels._compat import default_interpret

EPS = 1e-12
_MATMUL = ("sqeuclidean", "euclidean", "cosine", "dot")
_CUBE = ("manhattan", "chebyshev")


def _matmul_kernel(x_ref, y_ref, o_ref, acc, sx, sy, *, metric: str, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        sx[...] = jnp.zeros_like(sx)
        sy[...] = jnp.zeros_like(sy)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    y = y_ref[...].astype(jnp.float32)  # (bn, bk)
    acc[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    sx[...] += jnp.sum(x * x, axis=1, keepdims=True)
    sy[...] += jnp.sum(y * y, axis=1, keepdims=True)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        dotv = acc[...]
        if metric == "dot":
            o_ref[...] = -dotv
        elif metric == "cosine":
            nx = jnp.sqrt(jnp.maximum(sx[...], EPS))  # (bm, 1)
            ny = jnp.sqrt(jnp.maximum(sy[...], EPS))  # (bn, 1)
            o_ref[...] = 1.0 - dotv / (nx * ny.T)
        else:
            d2 = jnp.maximum(sx[...] + sy[...].T - 2.0 * dotv, 0.0)
            o_ref[...] = jnp.sqrt(d2) if metric == "euclidean" else d2


def _cube_kernel(x_ref, y_ref, o_ref, *, metric: str, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    y = y_ref[...].astype(jnp.float32)  # (bn, bk)
    cube = jnp.abs(x[:, :, None] - y.T[None, :, :])  # (bm, bk, bn)
    if metric == "manhattan":
        o_ref[...] += jnp.sum(cube, axis=1)
    else:  # chebyshev
        o_ref[...] = jnp.maximum(o_ref[...], jnp.max(cube, axis=1))


@functools.partial(
    jax.jit, static_argnames=("metric", "bm", "bn", "bk", "interpret")
)
def pdist_pallas(
    X: jax.Array,
    Y: jax.Array,
    *,
    metric: str = "sqeuclidean",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    # benchmarks call the kernel directly (not via ops.pdist): resolve the
    # interpret default from the backend so TPU runs compiled by default.
    if interpret is None:
        interpret = default_interpret()
    m, d = X.shape
    n, d2 = Y.shape
    assert d == d2, (X.shape, Y.shape)
    if metric in _CUBE:
        bk = min(bk, 32)

    pm, pn, pk = (-m) % bm, (-n) % bn, (-d) % bk
    # zero padding in d is exact for every supported metric; padded rows are
    # sliced off after the call.
    Xp = jnp.pad(X, ((0, pm), (0, pk)))
    Yp = jnp.pad(Y, ((0, pn), (0, pk)))
    M, N, K = Xp.shape[0], Yp.shape[0], Xp.shape[1]
    grid = (M // bm, N // bn, K // bk)

    common = dict(
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )
    if metric in _MATMUL:
        out = pl.pallas_call(
            functools.partial(_matmul_kernel, metric=metric, k_steps=grid[2]),
            scratch_shapes=[
                pltpu.VMEM((bm, bn), jnp.float32),
                pltpu.VMEM((bm, 1), jnp.float32),
                pltpu.VMEM((bn, 1), jnp.float32),
            ],
            **common,
        )(Xp, Yp)
    elif metric in _CUBE:
        out = pl.pallas_call(
            functools.partial(_cube_kernel, metric=metric, k_steps=grid[2]),
            **common,
        )(Xp, Yp)
    else:
        raise ValueError(f"pdist kernel does not support metric {metric!r}")
    return out[:m, :n]
