from repro.kernels.pdist import ops, ref  # noqa: F401
from repro.kernels.pdist.pdist import pdist_pallas  # noqa: F401
