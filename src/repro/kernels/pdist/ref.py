"""Pure-jnp oracle for the pairwise-distance kernel."""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-12


def pdist_ref(X: jnp.ndarray, Y: jnp.ndarray, *, metric: str) -> jnp.ndarray:
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    if metric in ("sqeuclidean", "euclidean"):
        d2 = (
            jnp.sum(X * X, -1)[:, None]
            + jnp.sum(Y * Y, -1)[None, :]
            - 2.0 * (X @ Y.T)
        )
        d2 = jnp.maximum(d2, 0.0)
        return d2 if metric == "sqeuclidean" else jnp.sqrt(d2)
    if metric == "cosine":
        nx = jnp.maximum(jnp.linalg.norm(X, axis=-1), EPS)
        ny = jnp.maximum(jnp.linalg.norm(Y, axis=-1), EPS)
        return 1.0 - (X @ Y.T) / (nx[:, None] * ny[None, :])
    if metric == "dot":
        return -(X @ Y.T)
    if metric == "manhattan":
        return jnp.sum(jnp.abs(X[:, None, :] - Y[None, :, :]), axis=-1)
    if metric == "chebyshev":
        return jnp.max(jnp.abs(X[:, None, :] - Y[None, :, :]), axis=-1)
    raise ValueError(metric)
