"""Pure-jnp oracle for the q-path semiring matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def qpath_matmul_ref(A: jnp.ndarray, B: jnp.ndarray, *, mode: str) -> jnp.ndarray:
    """C[i, j] = min_k combine(A[i, k], B[k, j]).

    mode in {'minplus', 'minmax', 'logminplus'} — see core.qmetric.
    Naive (m, k, n) broadcast; callers keep shapes small.
    """
    a = A[:, :, None]
    b = B[None, :, :]
    if mode == "minplus":
        c = a + b
    elif mode == "minmax":
        c = jnp.maximum(a, b)
    elif mode == "logminplus":
        c = jnp.logaddexp(a, b)
    else:
        raise ValueError(mode)
    return jnp.min(c, axis=1)
