"""Jit'd public wrapper for the q-path semiring matmul.

On this CPU container the kernel always runs in interpret mode; on a real TPU
``interpret=False`` compiles through Mosaic.  The flag is resolved once from
the backend so callers never pass it.
"""
from __future__ import annotations

import jax

from repro.kernels.qpath.qpath import qpath_matmul_pallas

_INTERPRET = jax.default_backend() != "tpu"


def qpath_matmul(A: jax.Array, B: jax.Array, *, mode: str = "minmax") -> jax.Array:
    return qpath_matmul_pallas(A, B, mode=mode, interpret=_INTERPRET)
