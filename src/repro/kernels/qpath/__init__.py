from repro.kernels.qpath import ops, ref  # noqa: F401
from repro.kernels.qpath.qpath import qpath_matmul_pallas  # noqa: F401
