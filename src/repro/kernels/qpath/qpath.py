"""Pallas TPU kernel: blocked semiring matmul over (min, combine).

This is the hot loop of the canonical q-metric projection (DESIGN.md §3.1):
one path-doubling sweep is ``M <- min(M, M (*) M)`` where

    (A (*) B)[i, j] = min_k combine(A[i, k], B[k, j])

with combine in {+, max, logaddexp}.  A (min, +) semiring product has no MXU
mapping (it is not a ring), so the kernel is VPU-bound by design; the tiling
goal is to keep the (bm, bk, bn) combine cube resident in VMEM and stream k
tiles from HBM exactly once per (i, j) output tile.

Tiling
------
grid = (m/bm, n/bn, k/bk), k innermost ("arbitrary" semantics) so the output
tile acts as the running-min accumulator across k steps.  Default tile
(bm, bn, bk) = (128, 128, 8): the combine cube is 128*8*128*4B = 512 KiB and
the A/B tiles are lane-aligned (last dim 128).  bk is the sublane axis of the
broadcast — kept small so cube + tiles + accumulator fit comfortably in the
~16 MiB of VMEM alongside double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels._compat import default_interpret


def _combine(a, b, mode: str):
    if mode == "minplus":
        return a + b
    if mode == "minmax":
        return jnp.maximum(a, b)
    if mode == "logminplus":
        return jnp.logaddexp(a, b)
    raise ValueError(mode)


def _qpath_kernel(a_ref, b_ref, o_ref, *, mode: str, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    cube = _combine(a[:, :, None], b[None, :, :], mode)  # (bm, bk, bn)
    tile_min = jnp.min(cube, axis=1)  # (bm, bn)
    o_ref[...] = jnp.minimum(o_ref[...], tile_min)


@functools.partial(
    jax.jit, static_argnames=("mode", "bm", "bn", "bk", "interpret")
)
def qpath_matmul_pallas(
    A: jax.Array,
    B: jax.Array,
    *,
    mode: str = "minmax",
    bm: int = 128,
    bn: int = 128,
    bk: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Semiring matmul via pallas_call.  Shapes padded to tile multiples with
    +inf (the min identity), so arbitrary (m, k) x (k, n) are supported."""
    if interpret is None:
        interpret = default_interpret()
    m, kdim = A.shape
    k2, n = B.shape
    assert kdim == k2, (A.shape, B.shape)
    dtype = jnp.float32
    A = A.astype(dtype)
    B = B.astype(dtype)

    pm, pk, pn = (-m) % bm, (-kdim) % bk, (-n) % bn
    Ap = jnp.pad(A, ((0, pm), (0, pk)), constant_values=jnp.inf)
    Bp = jnp.pad(B, ((0, pk), (0, pn)), constant_values=jnp.inf)
    M, K, N = Ap.shape[0], Ap.shape[1], Bp.shape[1]
    grid = (M // bm, N // bn, K // bk)

    out = pl.pallas_call(
        functools.partial(_qpath_kernel, mode=mode, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(Ap, Bp)
    return out[:m, :n]
