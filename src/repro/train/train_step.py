"""Family-generic train / serve step factories.

``make_train_step`` returns a jit-able ``(params, opt_state, batch, rng) ->
(params, opt_state, metrics)`` closure for any of the three model families,
with optional microbatch gradient accumulation (lax.scan over microbatches —
XLA overlaps each microbatch's reduce-scatter with the next one's compute)
and optional int8 gradient compression on the cross-pod axis.

``make_serve_step`` / ``make_decode_step`` build the inference closures the
dry-run lowers for the serve shapes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.dist import compression as comp_lib
from repro.train import optimizer as opt_lib

PyTree = Any


def _loss_fn_for(family: str):
    if family == "lm":
        from repro.models.transformer import lm_loss

        return lm_loss
    if family == "gnn":
        from repro.models.gnn import gcn_loss

        return gcn_loss
    if family == "recsys":
        from repro.models.recsys import recsys_loss

        return recsys_loss
    raise KeyError(family)


def make_train_step(
    cfg,
    family: str,
    opt: opt_lib.Optimizer,
    dctx=None,
    *,
    microbatches: int = 1,
    grad_compression: Optional[str] = None,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = _loss_fn_for(family)

    def forward(params, batch):
        loss, metrics = loss_fn(params, batch, cfg, dctx)
        return loss, metrics

    grad_fn = jax.value_and_grad(forward, has_aux=True)

    def compute_grads(params, batch):
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        # split the leading batch dim into microbatches and scan-accumulate
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree_util.tree_map(split, batch)

        def body(acc, one):
            (loss, metrics), grads = grad_fn(params, one)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), acc, grads
            )
            return acc, metrics

        # accumulate in the parameter dtype: for bf16-param giants the f32
        # accumulator would double gradient memory (EXPERIMENTS §Perf/H2);
        # f32 params keep f32 accumulation.
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, p.dtype), params
        )
        acc, metrics = jax.lax.scan(body, zeros, mb)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, acc)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        if grad_compression == "int8":
            grads = comp_lib.fake_int8_roundtrip(grads)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, metrics

    return train_step


def make_serve_step(cfg, family: str, dctx=None) -> Callable:
    """Forward-only scoring step (recsys serve_*, gnn inference)."""
    if family == "recsys":
        from repro.models.recsys import recsys_forward

        def serve(params, batch):
            logits = recsys_forward(params, batch["ids"], cfg, dctx)
            return jax.nn.sigmoid(logits)

        return serve
    if family == "gnn":
        from repro.models.gnn import gcn_forward

        def serve(params, batch):
            return gcn_forward(params, batch["x"], batch["edges"], cfg, dctx)

        return serve
    raise KeyError(family)


def make_retrieval_step(cfg, dctx=None, *, k: int = 100) -> Callable:
    """recsys retrieval_cand: query ids -> top-k of n_candidates."""
    from repro.models.recsys import retrieval_score, user_embedding

    def retrieve(params, batch):
        u = user_embedding(params, batch["ids"], cfg, dctx)
        return retrieval_score(u, batch["candidates"], k=k, dctx=dctx)

    return retrieve


def make_decode_step(cfg, dctx=None, *, mla_absorb: bool = False) -> Callable:
    """LM decode: one token for every sequence in the batch."""
    from repro.models.transformer import lm_decode_step

    def decode(params, cache, tokens, pos):
        logits, cache = lm_decode_step(
            params, cache, tokens, pos, cfg, dctx, mla_absorb=mla_absorb
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode


def make_prefill_step(cfg, dctx=None, *, max_len: Optional[int] = None) -> Callable:
    from repro.models.transformer import lm_prefill

    def prefill(params, tokens):
        return lm_prefill(params, tokens, cfg, dctx, max_len=max_len)

    return prefill
