"""Fault-tolerant sharded checkpointing (no tensorstore in this container —
the format is per-leaf .npy shards + a JSON manifest with content hashes,
written atomically).

Layout:
    <dir>/step_000120/
        manifest.json        # step, leaf paths, shapes, dtypes, sha256
        leaf_00000.npy ...   # one file per pytree leaf
    <dir>/LATEST             # atomic pointer (rename) to the newest step

Guarantees:
  * atomic publish — a checkpoint is visible only after its manifest and the
    LATEST pointer have been renamed into place; a crash mid-write leaves the
    previous checkpoint intact;
  * integrity — sha256 per leaf, verified on restore;
  * elasticity — restore() materializes onto ANY mesh: leaves are saved as
    full (unsharded) arrays and re-sharded by the caller's NamedShardings
    (re-mesh after shrinking from 2 pods to 1 is a restore with the new
    mesh's shardings);
  * async — ``AsyncCheckpointer`` double-buffers device->host transfers and
    writes on a background thread so the train loop never blocks on disk.

At 1000+ node scale the same protocol applies per-host with a per-host shard
manifest; this implementation centralizes IO because the container is a
single host (DESIGN.md §6).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _tree_leaves_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def _path_str(path) -> str:
    return "/".join(str(p) for p in path)


def save(ckpt_dir: str, step: int, tree: PyTree, *, extra: Optional[dict] = None) -> str:
    """Blocking save. Returns the published step directory."""
    flat, _ = _tree_leaves_with_paths(tree)
    step_name = f"step_{step:08d}"
    tmp = tempfile.mkdtemp(prefix=f".{step_name}.tmp", dir=_ensure(ckpt_dir))
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        # numpy can't serialize ml_dtypes (bf16/f8) natively: store the raw
        # bits as uintN and record the logical dtype in the manifest
        store = arr
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.uint32, np.int8, np.uint8, np.int16,
                             np.uint16, np.uint64, np.float16, np.bool_):
            store = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        np.save(fpath, store)
        with open(fpath, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append(
            {
                "path": _path_str(path),
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": digest,
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    final = os.path.join(ckpt_dir, step_name)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _write_latest(ckpt_dir, step_name)
    return final


def _ensure(d: str) -> str:
    os.makedirs(d, exist_ok=True)
    return d


def _write_latest(ckpt_dir: str, step_name: str) -> None:
    tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(step_name)
    os.rename(tmp, os.path.join(ckpt_dir, "LATEST"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(
    ckpt_dir: str,
    target_tree: PyTree,
    *,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
    verify: bool = True,
) -> tuple[PyTree, int]:
    """Restore into the structure of ``target_tree`` (shapes must match).
    ``shardings`` (same structure) re-shards each leaf onto the current mesh
    — this is the elastic-re-mesh path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = _tree_leaves_with_paths(target_tree)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_flat = None
    if shardings is not None:
        shard_flat = treedef.flatten_up_to(shardings)
    out = []
    for i, (path, leaf) in enumerate(flat):
        key = _path_str(path)
        entry = by_path[key]
        fpath = os.path.join(d, entry["file"])
        if verify:
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != entry["sha256"]:
                raise IOError(f"checksum mismatch for {key} in step {step}")
        arr = np.load(fpath)
        if str(arr.dtype) != entry["dtype"]:
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"])))
        expect = tuple(np.shape(leaf)) if hasattr(leaf, "shape") else None
        if expect is not None and tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs {expect}")
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), step


def garbage_collect(ckpt_dir: str, keep: int = 3) -> None:
    steps = sorted(
        [d for d in os.listdir(ckpt_dir) if d.startswith("step_")], reverse=True
    )
    for d in steps[keep:]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


class AsyncCheckpointer:
    """Double-buffered background saver: ``maybe_save`` snapshots to host
    (device_get) synchronously — cheap — and writes on a worker thread."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: PyTree, *, extra: Optional[dict] = None) -> None:
        self.wait()  # one in flight at a time
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra=extra)
                garbage_collect(self.ckpt_dir, self.keep)
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
