"""Fault-tolerance supervisor (DESIGN.md §6).

The train loop runs under a ``Supervisor`` that implements the policies a
1000-node deployment needs; on this single host the failure signals are
injected by tests / the launcher, but the state machine is the production
one:

  * step deadline (straggler detection) — a step exceeding
    ``deadline_factor x`` the trailing-median step time is flagged; after
    ``max_stragglers`` consecutive flags the supervisor requests a restart
    (on a real fleet: reschedule the slow host, restore, continue).
  * NaN/Inf guard — a non-finite loss or gradient norm skips the update
    (the step function receives a zero-scaled gradient) and after
    ``max_nan_skips`` consecutive skips restores from the last checkpoint.
  * elastic re-mesh — on pod loss, ``ElasticPlan.shrink`` yields the
    next-smaller mesh (2x16x16 -> 16x16) and the restore path re-shards the
    checkpoint onto it (checkpoint.restore with new shardings).

The deadline/trip arithmetic lives in ``core/backoff`` (shared with the
serving controller in ``launch/serve.py`` — DESIGN.md §14): the trailing-
median straggler threshold is ``backoff.median_deadline`` and both
consecutive-failure trips are ``backoff.RunCounter``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import backoff as backoff_lib


@dataclasses.dataclass
class SupervisorConfig:
    deadline_factor: float = 3.0
    window: int = 32
    max_stragglers: int = 3
    max_nan_skips: int = 3


class Supervisor:
    def __init__(self, cfg: SupervisorConfig = SupervisorConfig()):
        self.cfg = cfg
        self.step_times: list[float] = []
        self._stragglers = backoff_lib.RunCounter(cfg.max_stragglers)
        self._nans = backoff_lib.RunCounter(cfg.max_nan_skips)
        self.restarts = 0

    # the run lengths stay public — the launcher's log lines read them
    @property
    def straggler_run(self) -> int:
        return self._stragglers.run

    @property
    def nan_run(self) -> int:
        return self._nans.run

    # --- straggler detection -------------------------------------------------
    def observe_step_time(self, seconds: float) -> str:
        """Returns 'ok' | 'straggler' | 'restart'."""
        hist = self.step_times[-self.cfg.window :]
        self.step_times.append(seconds)
        deadline = backoff_lib.median_deadline(
            hist, factor=self.cfg.deadline_factor)
        if deadline is None:  # too few samples to call anything slow
            return "ok"
        slow = seconds > deadline
        if self._stragglers.observe(slow):
            self.restarts += 1
            return "restart"
        return "straggler" if slow else "ok"

    # --- NaN guard ------------------------------------------------------------
    def observe_loss(self, loss: float) -> str:
        """Returns 'ok' | 'skip' | 'restore'."""
        bad = not np.isfinite(loss)
        if self._nans.observe(bad):
            self.restarts += 1
            return "restore"
        return "skip" if bad else "ok"


@dataclasses.dataclass
class ElasticPlan:
    """Mesh downgrade ladder for pod loss."""

    ladder: tuple = ((2, 16, 16), (16, 16))
    level: int = 0

    def current_shape(self):
        return self.ladder[self.level]

    def shrink(self):
        if self.level + 1 >= len(self.ladder):
            raise RuntimeError("no smaller mesh available — abort")
        self.level += 1
        return self.ladder[self.level]


class Heartbeat:
    """Deadline-based liveness check for host processes (the launcher pings
    it from the data-loading and checkpoint threads)."""

    def __init__(self, timeout_s: float = 300.0):
        self.timeout_s = timeout_s
        self._last: dict[str, float] = {}

    def ping(self, name: str) -> None:
        self._last[name] = time.monotonic()

    def dead(self) -> list[str]:
        now = time.monotonic()
        return [k for k, t in self._last.items() if now - t > self.timeout_s]
