"""Optimizers (pure-pytree, optax-free — the container is offline).

AdamW and Adafactor over arbitrary parameter pytrees, plus global-norm
clipping and cosine/linear schedules.  State layout mirrors the parameter
tree so the distribution layer can shard optimizer state with the same
PartitionSpecs as the parameters (ZeRO-1: ``shard_opt_like_params``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


class AdafactorState(NamedTuple):
    step: jax.Array
    # per-leaf dict: {'vr': row stats, 'vc': col stats} for >=2D, {'v': full} for <2D
    stats: PyTree


class Optimizer(NamedTuple):
    init: Callable[[PyTree], Any]
    update: Callable[[PyTree, Any, PyTree], tuple[PyTree, Any]]  # (grads, state, params)


def _global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def f(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        return base_lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))

    return f


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.float32(base_lr)


def adamw(
    lr: float | Callable = 1e-3,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = 1.0,
) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads: PyTree, state: AdamWState, params: PyTree):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr_t = sched(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v)

    return Optimizer(init=init, update=update)


def adafactor(
    lr: float | Callable = 1e-2,
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Factored second-moment optimizer — O(rows+cols) state for matrices.

    The memory-frugal choice for the 100B+ configs: optimizer state for a
    (r, c) matrix is r + c floats instead of 2*r*c.
    """
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params: PyTree) -> AdafactorState:
        def leaf(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            stats=jax.tree_util.tree_map(leaf, params),
        )

    def update(grads: PyTree, state: AdafactorState, params: PyTree):
        step = state.step + 1
        lr_t = sched(step)
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                vhat = (
                    vr[..., :, None] * vc[..., None, :]
                    / jnp.maximum(denom[..., None], eps)
                )
                u = g32 / jnp.sqrt(jnp.maximum(vhat, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 / jnp.sqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr_t * (u + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_s

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.stats)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_s = treedef.unflatten([o[1] for o in out])
        return new_p, AdafactorState(step=step, stats=new_s)

    return Optimizer(init=init, update=update)


def sgd(lr: float | Callable = 1e-2, *, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum:
            return (
                jnp.zeros((), jnp.int32),
                jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            )
        return (jnp.zeros((), jnp.int32), None)

    def update(grads, state, params):
        step, vel = state
        step = step + 1
        lr_t = sched(step)
        if momentum:
            vel = jax.tree_util.tree_map(
                lambda v, g: momentum * v + g.astype(jnp.float32), vel, grads
            )
            params = jax.tree_util.tree_map(
                lambda p, v: (p.astype(jnp.float32) - lr_t * v).astype(p.dtype),
                params,
                vel,
            )
        else:
            params = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32) - lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params,
                grads,
            )
        return params, (step, vel)

    return Optimizer(init=init, update=update)


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}
